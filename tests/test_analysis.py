"""Comm-graph analyzer: classification, lint reasons, schedule verifier.

Everything here is static — models are traced on ``ShapeDtypeStruct``
leaves (no arrays allocated, no collectives executed).  The executed
rewrite path is covered by ``test_auto_fuse.py``.
"""
import jax
import numpy as np

from repro.analysis import (build_comm_graph, explain_comm, plan_rewrites,
                            schedule_violations, verify_schedules)
from repro.analysis import commgraph as cg
from repro.configs.registry import get_arch
from repro.core.degrade import (DegradationPolicy, DegradeConfig,
                                set_degradation_policy)
from repro.core.scheduling import expected_send_cover, sub_chunk_send_events
from repro.launch.mesh import make_host_mesh
from repro.launch.train import make_batches
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree)


def _trace(arch, mode="auto", batch=8, seq=16):
    ctx = make_host_mesh(fusion=FusionConfig(mode=mode))
    bundle = get_arch(arch).reduced()
    params = jax.eval_shape(
        lambda k: split_params(bundle.init_params(k))[0],
        jax.random.PRNGKey(0))
    batch0 = _sds(next(iter(make_batches(bundle, batch, seq))))
    closed = jax.make_jaxpr(bundle.loss_fn(ctx))(params, batch0)
    return ctx, bundle, params, batch0, closed


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------
def test_detects_four_fused_families_across_registry():
    """The acceptance sweep: over three registry configs the analyzer
    must classify and rewrite at least four distinct fused-op families."""
    rewritten = set()
    for arch in ("chatglm3-6b", "dbrx-132b", "dlrm"):
        ctx, _, _, _, closed = _trace(arch)
        plan = plan_rewrites(build_comm_graph(closed, ctx), ctx)
        rewritten.update(r.family for r in plan.reports if r.rewritten)
    assert {cg.ALLGATHER_MATMUL, cg.MATMUL_REDUCESCATTER,
            cg.MOE_DISPATCH_COMBINE, cg.EMBEDDING_A2A} <= rewritten
    assert len(rewritten) >= 4


def test_transformer_sites_and_paths():
    ctx, _, _, _, closed = _trace("chatglm3-6b")
    graph = build_comm_graph(closed, ctx)
    fam = graph.families()
    assert fam[cg.ALLGATHER_MATMUL] == 2          # qkv + FFN up
    assert fam[cg.MATMUL_REDUCESCATTER] == 1      # FFN down
    assert fam[cg.KV_ALLGATHER] == 1
    # the per-layer sites live under the layer-stacked scan + remat
    layer = [s for s in graph.sites if s.family == cg.ALLGATHER_MATMUL][0]
    assert layer.pathstr == "scan/remat2"
    assert layer.rewritable


def test_moe_and_embedding_detection():
    ctx, _, _, _, closed = _trace("dbrx-132b")
    graph = build_comm_graph(closed, ctx)
    assert graph.families()[cg.MOE_DISPATCH_COMBINE] == 1
    site = [s for s in graph.sites
            if s.family == cg.MOE_DISPATCH_COMBINE][0]
    assert site.detail["axis"] == ctx.tp_axis

    ctx, _, _, _, closed = _trace("dlrm")
    graph = build_comm_graph(closed, ctx)
    assert graph.families()[cg.EMBEDDING_A2A] == 1
    site = [s for s in graph.sites if s.family == cg.EMBEDDING_A2A][0]
    # flattened-world ring: multi-axis collective
    assert len(site.axes) > 1


def test_already_fused_sites_are_left_alone():
    """Hand-fused ppermute rings (CE loss, embedding rings) must be
    recognized and never rewritten."""
    ctx, _, _, _, closed = _trace("chatglm3-6b")
    plan = plan_rewrites(build_comm_graph(closed, ctx), ctx)
    fused = [r for r in plan.reports if r.family == cg.ALREADY_FUSED]
    assert fused and all(not r.rewritten for r in fused)
    assert all("already fused" in r.reason for r in fused)


# ---------------------------------------------------------------------------
# lint reasons
# ---------------------------------------------------------------------------
def test_kv_allgather_reports_reassociation_reason():
    ctx, _, _, _, closed = _trace("chatglm3-6b")
    plan = plan_rewrites(build_comm_graph(closed, ctx), ctx)
    kv = [r for r in plan.reports if r.family == cg.KV_ALLGATHER]
    assert kv and not kv[0].rewritten
    assert "not value-preserving" in kv[0].reason


def test_quarantined_key_is_not_rewritten():
    """A key jailed by the degradation policy must stay bulk — the
    analyzer consults the same ledger as the hand-fused call sites."""
    ctx, _, _, _, closed = _trace("chatglm3-6b")
    plan = plan_rewrites(build_comm_graph(closed, ctx), ctx)
    target = [r for r in plan.reports
              if r.family == cg.ALLGATHER_MATMUL and r.rewritten][0]
    key = ("allgather_matmul",
           tuple(target.shapes[0]) + tuple(target.shapes[1]))
    pol = DegradationPolicy(DegradeConfig(max_failures=1))
    prev = set_degradation_policy(pol)
    try:
        assert pol.record_failure(key) == [key]
        assert pol.quarantined_keys() == (key,)
        plan2 = plan_rewrites(build_comm_graph(closed, ctx), ctx)
        jailed = [r for r in plan2.reports
                  if r.family == cg.ALLGATHER_MATMUL
                  and tuple(r.shapes[0]) + tuple(r.shapes[1]) == key[1]]
        assert jailed and all(not r.rewritten for r in jailed)
        assert all("quarantined" in r.reason for r in jailed)
        # the other families are unaffected
        assert any(r.rewritten for r in plan2.reports
                   if r.family == cg.MATMUL_REDUCESCATTER)
    finally:
        set_degradation_policy(prev)


def test_disabled_flag_reports_reason():
    ctx, _, _, _, closed = _trace("chatglm3-6b")
    ctx_off = ctx.with_fusion(FusionConfig(mode="auto",
                                           fuse_ag_matmul=False))
    plan = plan_rewrites(build_comm_graph(closed, ctx_off), ctx_off)
    ag = [r for r in plan.reports if r.family == cg.ALLGATHER_MATMUL]
    assert ag and all(not r.rewritten for r in ag)
    assert all("fuse_ag_matmul" in r.reason for r in ag)


def test_report_renders_families_and_savings():
    ctx, bundle, params, batch0, _ = _trace("chatglm3-6b")
    text = explain_comm(ctx, bundle.loss_fn(ctx), params, batch0)
    assert "comm-graph report" in text
    assert cg.ALLGATHER_MATMUL in text
    assert "modeled bulk" in text and "fusible: yes" in text
    assert "fusible: no" in text
    assert "site(s) rewritten" in text


def test_report_moe_kernel_availability():
    """--explain-comm reports device-initiated dispatch-kernel
    availability per MoE site: mesh-shape gate, quarantine, and the
    fp8->bf16 wire clamp."""
    ctx, bundle, params, batch0, closed = _trace("dbrx-132b")
    text = explain_comm(ctx, bundle.loss_fn(ctx), params, batch0)
    assert "kernel: available — device-initiated dispatch PUT ring" in text
    assert "mode='kernel'" in text

    # wire='fp8' is an XLA-path feature: the kernel note pins the clamp
    ctx8 = ctx.with_fusion(FusionConfig(mode="auto", wire="fp8"))
    plan = plan_rewrites(build_comm_graph(closed, ctx8), ctx8)
    moe = [r for r in plan.reports
           if r.family == cg.MOE_DISPATCH_COMBINE][0]
    assert "clamps to bf16" in moe.kernel

    # quarantined (op, shape) keys gate the kernel like the fused path
    graph = build_comm_graph(closed, ctx)
    site = [s for s in graph.sites
            if s.family == cg.MOE_DISPATCH_COMBINE][0]
    key = ("moe_a2a_kernel", tuple(site.detail["buf_shape"]))
    pol = DegradationPolicy(DegradeConfig(max_failures=1))
    set_degradation_policy(pol)
    try:
        assert pol.record_failure(key) == [key]
        plan = plan_rewrites(graph, ctx)
        moe = [r for r in plan.reports
               if r.family == cg.MOE_DISPATCH_COMBINE][0]
        assert "quarantined" in moe.kernel
    finally:
        set_degradation_policy(None)


def test_auto_mode_resolves_to_bulk_at_trace_time():
    f = FusionConfig(mode="auto")
    for fam in ("ag_matmul", "matmul_rs", "moe_a2a", "embed_a2a", "kv_ag"):
        assert f.resolve(fam) == "bulk"


# ---------------------------------------------------------------------------
# static schedule verifier
# ---------------------------------------------------------------------------
def test_schedule_sweep_is_clean():
    assert verify_schedules() == []


def test_expected_cover_matches_events():
    for world, q in ((4, 1), (8, 2), (8, 4)):
        want = expected_send_cover(world, q)
        for sends in sub_chunk_send_events(world, q):
            assert set(sends) == want


def test_verifier_rejects_dropped_send():
    """A schedule that silently drops one send event — the PR-3 bug
    class — must be flagged with the missing (dest, fine) pair."""
    def dropped(world, q, schedule, skew):
        ev = sub_chunk_send_events(world, q, schedule, skew)
        ev[1] = ev[1][:-1]
        return ev

    msgs = schedule_violations(8, 2, "comm_aware", 3, events_fn=dropped)
    assert msgs and any("never sent" in m for m in msgs)


def test_verifier_rejects_duplicate_and_misrouted_send():
    def duped(world, q, schedule, skew):
        ev = sub_chunk_send_events(world, q, schedule, skew)
        ev[0] = ev[0] + [ev[0][0]]          # duplicate
        return ev

    msgs = schedule_violations(4, 2, events_fn=duped)
    assert any("sent 2 times" in m for m in msgs)

    def misrouted(world, q, schedule, skew):
        ev = sub_chunk_send_events(world, q, schedule, skew)
        (d, f) = ev[2][0]
        ev[2] = [((d + 1) % world, f)] + ev[2][1:]   # wrong destination
        return ev

    msgs = schedule_violations(4, 1, events_fn=misrouted)
    assert any("spurious send" in m for m in msgs)


def test_verifier_rejects_bad_service_order():
    def bad_order(q, skew):
        return [0] * max(q, 1)

    msgs = schedule_violations(4, 4, order_fn=bad_order)
    assert any("not a permutation" in m for m in msgs)


def test_verifier_catches_skew_only_corruption():
    """A corruption that only manifests under nonzero skew is caught by
    the sweep (the exact dropped-skew regression shape)."""
    def skew_blind(world, q, schedule, skew):
        return sub_chunk_send_events(world, q, schedule, 0)

    # every individual point is a valid cover, so per-point checks pass…
    assert schedule_violations(8, 2, "comm_aware", 5,
                               events_fn=skew_blind) == []
    # …but a skew-dependent *order* corruption is caught: serve order
    def skew_blind_order(q, skew):
        from repro.core.scheduling import sub_chunk_service_order
        order = sub_chunk_service_order(q, 0)
        return order[:-1] + [order[0]] if skew else order

    msgs = verify_schedules(worlds=(4,), qs=(4,),
                            order_fn=skew_blind_order)
    assert any("not a permutation" in m for m in msgs)
