"""Cross-op parity matrix (the granularity contract, in one table).

Every fused-op family must equal its unfused reference at every supported
``chunks_per_rank``, in both model dtypes, on even *and* ragged shapes
(ragged = the requested q does not divide the chunked dim and must be
clamped to the largest feasible factor).  This one parametrized harness
replaces the per-op parity copies that used to live in
``test_granularity.py`` / ``test_fused_ops.py``.

References are bulk-mode (same dtype) where a bulk path exists — both
sides then share the operand rounding and only the decomposition is under
test — and a dense jnp formula for the CE loss (which has no bulk mode).
Reference results are cached per (op, dtype, shape) so the q sweep only
recompiles the fused side.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.fused import (allgather_matmul, embedding_all_to_all,
                              fused_expert_ffn_combine, fused_moe_kernel,
                              matmul_allreduce, matmul_reducescatter,
                              moe_dispatch_all_to_all, sharded_cross_entropy)
from repro.core.perfmodel import DCN, V5E
from repro.models.attention import context_attention
from repro.parallel.sharding import FusionConfig

F32, BF16 = np.float32, jnp.bfloat16
TOL = {"f32": dict(rtol=3e-4, atol=3e-4), "bf16": dict(rtol=3e-2, atol=3e-2)}
# wire-compression error bounds vs the *f32 reference*: one bf16 rounding
# per value (plus per-hop carry requantization) stays within bf16's ~2^-8;
# fp8 e4m3 carries ~2^-4 relative per value, accumulated over ring hops
WIRE_TOL = {"bf16": dict(rtol=3e-2, atol=3e-2),
            "fp8": dict(rtol=2e-1, atol=2e-1)}


def _dense_ce(x, e, y):
    lg = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                    e.astype(jnp.float32))
    m = lg.max(-1, keepdims=True)
    lse = jnp.log(jnp.exp(lg - m).sum(-1)) + m[..., 0]
    nll = lse - jnp.take_along_axis(lg, y[..., None], -1)[..., 0]
    return nll.mean()[None]


# ---------------------------------------------------------------------------
# op-family builders: (ctx, rng, dtype, ragged) -> (fused_fn(q), ref_fn())
# ---------------------------------------------------------------------------
def _mk_matmul_allreduce(ctx, rng, dtype, ragged):
    B, S, K, N = (2, 12, 32, 48) if ragged else (4, 16, 32, 64)
    x = rng.standard_normal((B, S, K)).astype(dtype)
    w = rng.standard_normal((K, N)).astype(dtype)
    return (lambda q: matmul_allreduce(ctx, x, w, mode="fused",
                                       chunks_per_rank=q),
            lambda: matmul_allreduce(ctx, x, w, mode="bulk"))


def _mk_gemv_cols(ctx, rng, dtype, ragged):
    # decode shape: rows < ring forces output-column sub-chunking
    B, K, N = (2, 32, 48) if ragged else (2, 32, 64)
    x = rng.standard_normal((B, 1, K)).astype(dtype)
    w = rng.standard_normal((K, N)).astype(dtype)
    return (lambda q: matmul_allreduce(ctx, x, w, mode="fused",
                                       chunks_per_rank=q),
            lambda: matmul_allreduce(ctx, x, w, mode="bulk"))


def _mk_matmul_reducescatter(ctx, rng, dtype, ragged):
    B, S, K, N = (2, 12, 32, 48) if ragged else (4, 16, 32, 64)
    x = rng.standard_normal((B, S, K)).astype(dtype)
    w = rng.standard_normal((K, N)).astype(dtype)
    return (lambda q: matmul_reducescatter(ctx, x, w, mode="fused",
                                           chunks_per_rank=q),
            lambda: matmul_reducescatter(ctx, x, w, mode="bulk"))


def _mk_allgather_matmul(ctx, rng, dtype, ragged):
    B, S, K, N = (2, 12, 32, 48) if ragged else (4, 16, 32, 64)
    x = rng.standard_normal((B, S, K)).astype(dtype)
    w = rng.standard_normal((K, N)).astype(dtype)
    return (lambda q: allgather_matmul(ctx, x, w, mode="fused",
                                       chunks_per_rank=q),
            lambda: allgather_matmul(ctx, x, w, mode="bulk"))


def _mk_moe_dispatch(ctx, rng, dtype, ragged):
    B, n_ep, E, C, D = (4, 4, 8, 6, 16) if ragged else (4, 4, 8, 8, 16)
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(dtype)
    return (lambda q: moe_dispatch_all_to_all(ctx, xd, mode="fused",
                                              chunks_per_rank=q),
            lambda: moe_dispatch_all_to_all(ctx, xd, mode="bulk"))


def _mk_moe_combine(ctx, rng, dtype, ragged):
    B, n_ep, E, C, D, F = (4, 4, 8, 6, 16, 24) if ragged \
        else (4, 4, 8, 8, 16, 24)
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(dtype)
    wu = rng.standard_normal((E, D, F)).astype(dtype)
    wg = rng.standard_normal((E, D, F)).astype(dtype)
    wd = rng.standard_normal((E, F, D)).astype(dtype)
    return (lambda q: fused_expert_ffn_combine(
                ctx, xd, wu, wg, wd, act=jax.nn.silu, mode="fused",
                chunks_per_rank=q),
            lambda: fused_expert_ffn_combine(
                ctx, xd, wu, wg, wd, act=jax.nn.silu, mode="bulk"))


def _mk_moe_dispatch_kernel(ctx, rng, dtype, ragged):
    """Device-initiated dispatch A2A (Pallas PUT ring) vs the bulk path.
    Runs on the session's 2-D (data, model) mesh — the kernel entry maps
    it through the flattened world under interpret mode."""
    B, n_ep, E, C, D = (4, 4, 8, 6, 16) if ragged else (4, 4, 8, 8, 16)
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(dtype)
    return (lambda q: moe_dispatch_all_to_all(
                ctx, xd, mode="kernel", chunks_per_rank=q,
                wire=ctx.fusion.wire),
            lambda: moe_dispatch_all_to_all(ctx, xd, mode="bulk"))


def _mk_moe_chain_kernel(ctx, rng, dtype, ragged):
    """Chained dispatch -> FFN -> combine kernel vs the two-step bulk
    combinator path (dispatch A2A then FFN+combine A2A)."""
    B, n_ep, E, C, D, F = (4, 4, 8, 6, 16, 24) if ragged \
        else (4, 4, 8, 8, 16, 24)
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(dtype)
    wu = rng.standard_normal((E, D, F)).astype(dtype)
    wg = rng.standard_normal((E, D, F)).astype(dtype)
    wd = rng.standard_normal((E, F, D)).astype(dtype)
    return (lambda q: fused_moe_kernel(
                ctx, xd, wu, wg, wd, act=jax.nn.silu,
                chunks_per_rank=1 if q is None else q,
                wire=ctx.fusion.wire),
            lambda: fused_expert_ffn_combine(
                ctx, moe_dispatch_all_to_all(ctx, xd, mode="bulk"),
                wu, wg, wd, act=jax.nn.silu, mode="bulk"))


def _mk_embedding_a2a(ctx, rng, dtype, ragged):
    B, T, L, V, D = (16, 8, 3, 32, 12) if ragged else (16, 8, 4, 32, 8)
    idx = rng.integers(0, V, size=(B, T, L)).astype(np.int32)
    tabs = rng.standard_normal((T, V, D)).astype(dtype)
    return (lambda q: embedding_all_to_all(ctx, idx, tabs, mode="fused",
                                           chunks_per_rank=q),
            lambda: embedding_all_to_all(ctx, idx, tabs, mode="bulk"))


def _mk_ring_attention(ctx, rng, dtype, ragged):
    B, S, Hq, Hkv, hd = (4, 48, 8, 2, 16) if ragged else (4, 64, 8, 2, 16)
    q_ = rng.standard_normal((B, S, Hq, hd)).astype(dtype)
    k_ = rng.standard_normal((B, S, Hkv, hd)).astype(dtype)
    v_ = rng.standard_normal((B, S, Hkv, hd)).astype(dtype)

    def run(mode, q=None):
        return context_attention(ctx, q_, k_, v_, causal=True, mode=mode,
                                 q_block=16, kv_block=16, chunks_per_rank=q)

    return (lambda q: run("fused", q), lambda: run("bulk"))


def _mk_ce_loss(ctx, rng, dtype, ragged):
    B, S, D, V = (4, 12, 32, 32) if ragged else (4, 16, 32, 64)
    x = rng.standard_normal((B, S, D)).astype(dtype)
    e = rng.standard_normal((V, D)).astype(dtype)
    y = rng.integers(0, V, (B, S)).astype(np.int32)
    return (lambda q: sharded_cross_entropy(ctx, x, e, y,
                                            chunks_per_rank=q)[None],
            lambda: _dense_ce(x, e, y))


OPS = {
    "matmul_allreduce": _mk_matmul_allreduce,
    "gemv_cols": _mk_gemv_cols,
    "matmul_reducescatter": _mk_matmul_reducescatter,
    "allgather_matmul": _mk_allgather_matmul,
    "moe_dispatch": _mk_moe_dispatch,
    "moe_dispatch_kernel": _mk_moe_dispatch_kernel,
    "moe_combine": _mk_moe_combine,
    "moe_chain_kernel": _mk_moe_chain_kernel,
    "embedding_a2a": _mk_embedding_a2a,
    "ring_attention": _mk_ring_attention,
    "ce_loss": _mk_ce_loss,
}

_REF_CACHE: dict = {}


def _reference(op, dtype_id, ragged, ref_fn):
    key = (op, dtype_id, ragged)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = np.asarray(jax.jit(ref_fn)(), np.float32)
    return _REF_CACHE[key]


@pytest.mark.parametrize("q", [1, 2, 4])
@pytest.mark.parametrize("ragged", [False, True], ids=["even", "ragged"])
@pytest.mark.parametrize("dtype", [F32, BF16], ids=["f32", "bf16"])
@pytest.mark.parametrize("op", sorted(OPS))
def test_parity(ctx, rng, op, dtype, ragged, q):
    dtype_id = "bf16" if dtype is BF16 else "f32"
    fused, ref_fn = OPS[op](ctx, rng, dtype, ragged)
    ref = _reference(op, dtype_id, ragged, ref_fn)
    y = np.asarray(jax.jit(lambda: fused(q))(), np.float32)
    tol = TOL[dtype_id]
    # ring-carried partials round once per hop, so the absolute error
    # scales with the accumulated magnitude — anchor atol to the ref scale
    atol = tol["atol"] * max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(y, ref, rtol=tol["rtol"], atol=atol)


# ---------------------------------------------------------------------------
# wire-dtype axis: wire="f32" is bit-identical to the default path; the
# compressed wires (bf16, fp8 + per-chunk scale) stay within the bounded
# relative error of one (bf16) / a few (fp8 ring-carry) roundings
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("op", sorted(OPS))
def test_wire_f32_bit_identical(ctx, op):
    """The uncompressed wire setting must not move a single bit: the
    wire machinery is pure passthrough at wire='f32'."""
    fused, _ = OPS[op](ctx, np.random.default_rng(0), F32, False)
    base = np.asarray(jax.jit(lambda: fused(2))(), np.float32)
    c2 = ctx.with_fusion(FusionConfig(wire="f32"))
    fused2, _ = OPS[op](c2, np.random.default_rng(0), F32, False)
    y = np.asarray(jax.jit(lambda: fused2(2))(), np.float32)
    assert (y == base).all()


@pytest.mark.parametrize("q", [1, 2])
@pytest.mark.parametrize("wire", ["bf16", "fp8"])
@pytest.mark.parametrize("op", sorted(OPS))
def test_wire_parity_bounded(ctx, rng, op, wire, q):
    c2 = ctx.with_fusion(FusionConfig(wire=wire))
    fused, ref_fn = OPS[op](c2, rng, F32, False)
    ref = _reference(op, "f32", False, ref_fn)
    y = np.asarray(jax.jit(lambda: fused(q))(), np.float32)
    tol = WIRE_TOL[wire]
    atol = tol["atol"] * max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(y, ref, rtol=tol["rtol"], atol=atol)


@pytest.mark.parametrize("wire", ["bf16", "fp8"])
def test_wire_ring_attention_grad_parity(ctx, rng, wire):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    qq = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    kk = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    vv = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    co = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)

    def loss(mode, w=None):
        return lambda q_, k_, v_: (context_attention(
            ctx, q_, k_, v_, causal=True, mode=mode, q_block=16,
            kv_block=16, chunks_per_rank=2,
            wire=w).astype(jnp.float32) * co).sum()

    gb = jax.jit(jax.grad(loss("bulk"), argnums=(0, 1, 2)))(qq, kk, vv)
    gf = jax.jit(jax.grad(loss("fused", wire), argnums=(0, 1, 2)))(qq, kk, vv)
    tol = WIRE_TOL[wire]
    for a, b in zip(gf, gb):
        atol = tol["atol"] * max(1.0, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol["rtol"], atol=atol)


@pytest.mark.parametrize("wire", ["bf16", "fp8"])
def test_wire_ce_loss_grad_parity(ctx, rng, wire):
    B, S, D, V = 4, 16, 32, 64
    x = rng.standard_normal((B, S, D)).astype(np.float32)
    e = rng.standard_normal((V, D)).astype(np.float32)
    y = rng.integers(0, V, (B, S)).astype(np.int32)
    g = jax.jit(jax.grad(lambda x, e: sharded_cross_entropy(
        ctx, x, e, y, chunks_per_rank=2, wire=wire), argnums=(0, 1)))(x, e)
    gr = jax.grad(lambda x, e: _dense_ce(x, e, y)[0], argnums=(0, 1))(x, e)
    tol = WIRE_TOL[wire]
    for a, b in zip(g, gr):
        atol = tol["atol"] * max(1e-3, float(np.abs(np.asarray(b)).max()))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol["rtol"], atol=atol)


def test_wire_auto_follows_axis_hardware_model():
    """'auto' resolves per mesh axis: on a fast ICI axis the wire hides
    behind compute (exactness wins -> f32); on a slow DCN axis the wire
    is exposed and halving its bytes pays (-> bf16); fp8 joins only when
    the link model declares support."""
    import dataclasses

    autotune.clear_cache()
    kw = dict(dtype_bytes=4, n_dev=8, chunk_dim=4096, wire="auto")
    fast = autotune.tune_matmul_allreduce(4096, 32768, 4096, **kw, hw=V5E)
    slow = autotune.tune_matmul_allreduce(4096, 32768, 4096, **kw, hw=DCN)
    assert fast.wire == "f32"
    assert slow.wire == "bf16"
    # once bf16 already hides the (mildly exposed) wire, fp8's extra
    # halving is under the adoption margin — bf16 sticks even on an
    # fp8-capable link
    dcn8 = dataclasses.replace(DCN, fp8_wire=True)
    slow8 = autotune.tune_matmul_allreduce(4096, 32768, 4096, **kw, hw=dcn8)
    assert slow8.wire == "bf16"
    # a wire-dominated workload on the same fp8-capable link does take fp8
    deep8 = autotune.tune_matmul_allreduce(4096, 1024, 4096, **kw, hw=dcn8)
    assert deep8.wire == "fp8"
    # the profiles memoize under different keys (hw is in the TuneKey)
    assert len({k.hw for k in autotune.cache_info()}) == 3
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# oblivious-schedule parity (the matrix runs the default comm-aware order;
# the Fig. 14 baseline order must stay numerically identical too)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [1, 2])
@pytest.mark.parametrize("op", ["matmul_allreduce", "matmul_reducescatter",
                                "moe_dispatch", "moe_combine"])
def test_parity_oblivious_schedule(ctx, rng, op, q):
    kw = dict(mode="fused", schedule="oblivious", chunks_per_rank=q)
    if op == "matmul_allreduce" or op == "matmul_reducescatter":
        fn = matmul_allreduce if op == "matmul_allreduce" \
            else matmul_reducescatter
        x = rng.standard_normal((4, 16, 32)).astype(np.float32)
        w = rng.standard_normal((32, 64)).astype(np.float32)
        y = jax.jit(lambda: fn(ctx, x, w, **kw))()
        ref = jax.jit(lambda: fn(ctx, x, w, mode="bulk"))()
    else:
        B, n_ep, E, C, D, F = 4, 4, 8, 8, 16, 24
        xd = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
        if op == "moe_dispatch":
            y = jax.jit(lambda: moe_dispatch_all_to_all(ctx, xd, **kw))()
            ref = jax.jit(lambda: moe_dispatch_all_to_all(
                ctx, xd, mode="bulk"))()
        else:
            wu = rng.standard_normal((E, D, F)).astype(np.float32)
            wg = rng.standard_normal((E, D, F)).astype(np.float32)
            wd = rng.standard_normal((E, F, D)).astype(np.float32)
            y = jax.jit(lambda: fused_expert_ffn_combine(
                ctx, xd, wu, wg, wd, act=jax.nn.silu, **kw))()
            ref = jax.jit(lambda: fused_expert_ffn_combine(
                ctx, xd, wu, wg, wd, act=jax.nn.silu, mode="bulk"))()
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# grad parity for the two custom-VJP rings (fwd parity is in the matrix)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q", [1, 2, 4, "auto"])
def test_ring_attention_grad_parity(ctx, rng, q):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    qq = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    kk = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    vv = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    co = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)

    def loss(mode, cpr=None):
        return lambda q_, k_, v_: (context_attention(
            ctx, q_, k_, v_, causal=True, mode=mode, q_block=16,
            kv_block=16, chunks_per_rank=cpr).astype(jnp.float32) * co).sum()

    gb = jax.jit(jax.grad(loss("bulk"), argnums=(0, 1, 2)))(qq, kk, vv)
    gf = jax.jit(jax.grad(loss("fused", q), argnums=(0, 1, 2)))(qq, kk, vv)
    for a, b in zip(gf, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("q", [1, 2, 4, "auto"])
def test_ce_loss_grad_parity(ctx, rng, q):
    B, S, D, V = 4, 16, 32, 64
    x = rng.standard_normal((B, S, D)).astype(np.float32)
    e = rng.standard_normal((V, D)).astype(np.float32)
    y = rng.integers(0, V, (B, S)).astype(np.int32)
    g = jax.jit(jax.grad(lambda x, e: sharded_cross_entropy(
        ctx, x, e, y, chunks_per_rank=q), argnums=(0, 1)))(x, e)
    gr = jax.grad(lambda x, e: _dense_ce(x, e, y)[0], argnums=(0, 1))(x, e)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# "auto" resolves a per-op decision through one FusionConfig switch
# ---------------------------------------------------------------------------
def test_auto_granularity_resolves_per_op(ctx, rng):
    autotune.clear_cache()
    c2 = ctx.with_fusion(FusionConfig(granularity="auto"))
    for op in ["matmul_allreduce", "allgather_matmul", "moe_combine",
               "embedding_a2a", "ring_attention", "ce_loss"]:
        fused, ref_fn = OPS[op](c2, np.random.default_rng(0), F32, False)
        y = np.asarray(jax.jit(lambda: fused(None))(), np.float32)
        ref = _reference(op, "f32", False, ref_fn)
        np.testing.assert_allclose(y, ref, **TOL["f32"])
    ops_seen = {k.op for k in autotune.cache_info()}
    # every ring family keyed its own decision (per-op "auto" values)
    assert {"matmul_allreduce", "allgather_matmul", "all_to_all",
            "ring_attention", "ce_ring"} <= ops_seen
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# device-initiated MoE kernel chain: bit-identity, skew, dispatch grads
# ---------------------------------------------------------------------------
def _chain_operands(ctx, rng, ragged=True):
    B, n_ep, E, C, D, F = (4, 4, 8, 6, 16, 24) if ragged \
        else (4, 4, 8, 8, 16, 24)
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)
    return xd, wu, wg, wd


@pytest.mark.parametrize("ragged", [False, True], ids=["even", "ragged"])
def test_moe_chain_kernel_bit_identical_2d(ctx, rng, ragged):
    """Acceptance: the chained dispatch->FFN->combine kernel path is
    bit-identical (f32 wire) to the combinator path on the 2-D mesh."""
    xd, wu, wg, wd = _chain_operands(ctx, rng, ragged)
    yk = jax.jit(lambda: fused_moe_kernel(
        ctx, xd, wu, wg, wd, act=jax.nn.silu))()
    ref = jax.jit(lambda: fused_expert_ffn_combine(
        ctx, moe_dispatch_all_to_all(ctx, xd, mode="bulk"),
        wu, wg, wd, act=jax.nn.silu, mode="bulk"))()
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(ref))


@pytest.mark.parametrize("skew", [1, 2])
def test_moe_chain_kernel_skew_parity(ctx, rng, skew):
    """A skew-rotated remote PUT order reorders only the wire traffic,
    never the math: still bit-identical at wire='f32'."""
    xd, wu, wg, wd = _chain_operands(ctx, rng)
    yk = jax.jit(lambda: fused_moe_kernel(
        ctx, xd, wu, wg, wd, act=jax.nn.silu, skew=skew,
        chunks_per_rank=2))()
    ref = jax.jit(lambda: fused_expert_ffn_combine(
        ctx, moe_dispatch_all_to_all(ctx, xd, mode="bulk"),
        wu, wg, wd, act=jax.nn.silu, mode="bulk"))()
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(ref))


@pytest.mark.parametrize("skew", [1, 2])
def test_moe_dispatch_kernel_skew_parity(ctx, rng, skew):
    xd, _, _, _ = _chain_operands(ctx, rng)
    yk = jax.jit(lambda: moe_dispatch_all_to_all(
        ctx, xd, mode="kernel", skew=skew, chunks_per_rank=2))()
    ref = jax.jit(lambda: moe_dispatch_all_to_all(ctx, xd, mode="bulk"))()
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(ref))


def test_moe_dispatch_kernel_grad_exact(ctx, rng):
    """Grads flow through the device-initiated dispatch boundary: the A2A
    is self-adjoint on the shard axis, so the custom VJP is the same
    kernel on the cotangent — bit-identical to the bulk path's grad."""
    xd, _, _, _ = _chain_operands(ctx, rng)

    def loss(mode):
        return lambda v: (moe_dispatch_all_to_all(
            ctx, v, mode=mode) ** 2).sum()

    gk = jax.jit(jax.grad(loss("kernel")))(xd)
    gb = jax.jit(jax.grad(loss("bulk")))(xd)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(gb))


def test_moe_chain_kernel_grad_parity(ctx, rng):
    """The chained kernel is trainable: its VJP differentiates the pure
    reference of the same math, so grads track the bulk path's."""
    xd, wu, wg, wd = _chain_operands(ctx, rng)

    def loss_kernel(v, a, b, c):
        return (fused_moe_kernel(
            ctx, v, a, b, c, act=jax.nn.silu) ** 2).sum()

    def loss_bulk(v, a, b, c):
        disp = moe_dispatch_all_to_all(ctx, v, mode="bulk")
        return (fused_expert_ffn_combine(
            ctx, disp, a, b, c, act=jax.nn.silu, mode="bulk") ** 2).sum()

    gk = jax.jit(jax.grad(loss_kernel, argnums=(0, 1, 2, 3)))(xd, wu, wg, wd)
    gb = jax.jit(jax.grad(loss_bulk, argnums=(0, 1, 2, 3)))(xd, wu, wg, wd)
    for a, b in zip(gk, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
