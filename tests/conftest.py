import os

# 8 local CPU devices for multi-device shard_map tests (NOT the 512-device
# production mesh — that is exercised only by launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.parallel.sharding import FusionConfig, ParallelContext  # noqa: E402
from repro.compat import make_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh():
    return make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def ctx(mesh):
    return ParallelContext.from_mesh(mesh)


@pytest.fixture(scope="session")
def ctx1d():
    m = make_mesh((8,), ("model",))
    return ParallelContext.from_mesh(m)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
