"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_pool.ops import embedding_pool
from repro.kernels.embedding_pool.ref import embedding_pool_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce
from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.gemv.ops import gemv
from repro.kernels.gemv.ref import gemv_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref

TOL = dict(rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("m,k,n", [(64, 96, 128), (128, 128, 64), (32, 64, 32),
                                   (16, 256, 16)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gemm_sweep(rng, m, k, n, dtype):
    x = rng.standard_normal((m, k)).astype(dtype)
    w = rng.standard_normal((k, n)).astype(dtype)
    got = np.asarray(gemm(x, w), np.float32)
    want = np.asarray(gemm_ref(x, w), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 3e-3,
                               atol=2e-2 if dtype == jnp.bfloat16 else 3e-3)


@pytest.mark.parametrize("k,n", [(96, 128), (256, 64), (64, 32)])
@pytest.mark.parametrize("batched", [False, True])
def test_gemv_sweep(rng, k, n, batched):
    w = rng.standard_normal((k, n)).astype(np.float32)
    x = rng.standard_normal((4, k) if batched else (k,)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(gemv(x, w)),
                               np.asarray(gemv_ref(x, w)), **TOL)


@pytest.mark.parametrize("v,d,b,L", [(50, 16, 8, 5), (128, 32, 4, 7), (16, 8, 2, 1)])
def test_embedding_pool_sweep(rng, v, d, b, L):
    tab = rng.standard_normal((v, d)).astype(np.float32)
    idx = rng.integers(0, v, (b, L)).astype(np.int32)
    np.testing.assert_allclose(np.asarray(embedding_pool(tab, idx)),
                               np.asarray(embedding_pool_ref(tab, idx)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s,hd", [(64, 16), (32, 32), (128, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, s, hd, causal):
    B, H = 2, 3
    q = rng.standard_normal((B, s, H, hd)).astype(np.float32)
    k = rng.standard_normal((B, s, H, hd)).astype(np.float32)
    v = rng.standard_normal((B, s, H, hd)).astype(np.float32)
    out = flash_attention(q, k, v, causal=causal, bq=16, bkv=16)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, s, hd)
    ref = np.asarray(flash_attention_ref(fold(q), fold(k), fold(v),
                                         scale=hd ** -0.5, causal=causal))
    ref = ref.reshape(B, H, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), ref, **TOL)


@pytest.mark.parametrize("t,n,chunk", [(32, 8, 8), (64, 16, 16), (16, 8, 4)])
def test_wkv6_sweep(rng, t, n, chunk):
    b, h = 2, 2
    r = rng.standard_normal((b, t, h, n)).astype(np.float32)
    k = rng.standard_normal((b, t, h, n)).astype(np.float32) * 0.3
    v = rng.standard_normal((b, t, h, n)).astype(np.float32)
    w = np.exp(-np.exp(rng.standard_normal((b, t, h, n)).astype(np.float32)))
    u = rng.standard_normal((h, n)).astype(np.float32) * 0.1
    out = wkv6(r, k, v, w, u, chunk=chunk)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, n)
    lw = np.log(np.clip(w, 1e-8, 1.0))
    uu = np.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)
    ref = np.asarray(wkv6_ref(fold(r), fold(k), fold(v), fold(lw), uu))
    ref = ref.reshape(b, h, t, n).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), ref, **TOL)


@pytest.mark.parametrize("rows,k,n", [(4, 32, 64), (1, 64, 32), (8, 16, 128)])
@pytest.mark.parametrize("comm_aware", [True, False])
def test_fused_gemv_allreduce_kernel(ctx1d, rng, rows, k, n, comm_aware):
    """Device-initiated remote-DMA kernel vs plain matmul (1D mesh)."""
    x = rng.standard_normal((rows, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, comm_aware=comm_aware))(x, w)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("comm_aware", [True, False])
@pytest.mark.parametrize("t_loc,v,d,b,L", [(2, 32, 16, 16, 4), (1, 16, 8, 8, 2)])
def test_fused_embedding_a2a_kernel(ctx1d, rng, comm_aware, t_loc, v, d, b, L):
    """Device-initiated fused embedding+All-to-All (paper Fig. 6) on the
    1D interpret mesh: pooled fragments land in peers' output buffers."""
    from repro.kernels.fused_embedding_a2a.ops import fused_embedding_a2a

    n = 8
    T = n * t_loc
    idx = rng.integers(0, v, (b * n // n * n, T, L)).astype(np.int32)
    B = idx.shape[0]
    tabs = rng.standard_normal((T, v, d)).astype(np.float32)
    ref = tabs[np.arange(T)[None, :, None], idx, :].mean(axis=2)
    out = jax.jit(lambda i, t: fused_embedding_a2a(
        ctx1d, i, t, comm_aware=comm_aware))(idx, tabs)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)
