"""Sharded cross-entropy: forward + custom VJP vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.loss import sharded_cross_entropy


def _dense(x, e, y, cap=None):
    lg = jnp.einsum("bsd,vd->bsv", x, e)
    if cap:
        lg = jnp.tanh(lg / cap) * cap
    m = jax.lax.stop_gradient(lg.max(-1, keepdims=True))
    lse = jnp.log(jnp.exp(lg - m).sum(-1)) + m[..., 0]
    nll = lse - jnp.take_along_axis(lg, y[..., None], -1)[..., 0]
    return nll.mean()


@pytest.mark.parametrize("cap", [None, 20.0])
@pytest.mark.parametrize("S", [16, 2])  # seq-sharded and replicated paths
def test_ce_matches_dense(ctx, rng, cap, S):
    B, D, V = 4, 32, 64
    x = rng.standard_normal((B, S, D)).astype(np.float32)
    e = rng.standard_normal((V, D)).astype(np.float32)
    y = rng.integers(0, V, (B, S)).astype(np.int32)
    l1 = jax.jit(lambda x, e: sharded_cross_entropy(ctx, x, e, y,
                                                    logit_softcap=cap))(x, e)
    np.testing.assert_allclose(float(l1), float(_dense(x, e, y, cap)), rtol=1e-4)

    g = jax.jit(jax.grad(lambda x, e: sharded_cross_entropy(
        ctx, x, e, y, logit_softcap=cap), argnums=(0, 1)))(x, e)
    gr = jax.grad(lambda x, e: _dense(x, e, y, cap), argnums=(0, 1))(x, e)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)
