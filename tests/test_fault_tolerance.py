"""Supervisor: checkpoint/restart on injected node failure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor


def test_supervisor_restarts_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 7:  # injected node failure mid-run
            raise RuntimeError("simulated collective timeout")
        new = {"w": state["w"] + 1.0}
        return new, {"loss": jnp.asarray(float(new["w"][0]))}

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                         keep=2, max_restarts=2, async_save=False),
        step_fn)
    state = {"w": np.zeros((1,), np.float32)}
    batches = iter(lambda: {"x": 0}, None)
    final, step = sup.run(state, batches, num_steps=10)
    assert step == 10
    assert sup.restarts == 1
    # state advanced exactly 10 effective steps despite the failure
    assert float(final["w"][0]) == 10.0


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def step_fn(state, batch):
        raise RuntimeError("persistent failure")

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100,
                         max_restarts=2, async_save=False),
        step_fn)
    with pytest.raises(RuntimeError):
        sup.run({"w": np.zeros(1)}, iter(lambda: {}, None), num_steps=5)
    assert sup.restarts == 3
