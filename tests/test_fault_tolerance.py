"""Supervisor: checkpoint/restart on injected node failure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ReplayBuffer
from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor


def test_supervisor_restarts_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 7:  # injected node failure mid-run
            raise RuntimeError("simulated collective timeout")
        new = {"w": state["w"] + 1.0}
        return new, {"loss": jnp.asarray(float(new["w"][0]))}

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                         keep=2, max_restarts=2, async_save=False),
        step_fn)
    state = {"w": np.zeros((1,), np.float32)}
    batches = iter(lambda: {"x": 0}, None)
    final, step = sup.run(state, batches, num_steps=10)
    assert step == 10
    assert sup.restarts == 1
    # state advanced exactly 10 effective steps despite the failure
    assert float(final["w"][0]) == 10.0


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def step_fn(state, batch):
        raise RuntimeError("persistent failure")

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100,
                         max_restarts=2, async_save=False),
        step_fn)
    with pytest.raises(RuntimeError):
        sup.run({"w": np.zeros(1)}, iter(lambda: {}, None), num_steps=5)
    assert sup.restarts == 3


def _ok_step(state, batch):
    new = {"w": state["w"] + 1.0}
    return new, {"loss": jnp.asarray(float(new["w"][0]))}


def test_backoff_exponential_with_seeded_jitter(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] <= 4:
            raise RuntimeError("flaky start")
        return _ok_step(state, batch)

    sleeps = []
    cfg = SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=50,
                           max_restarts=8, async_save=False,
                           backoff_base_s=0.1, backoff_max_s=0.25,
                           backoff_jitter=0.5, seed=42)
    sup = TrainSupervisor(cfg, step_fn, sleep_fn=sleeps.append)
    _, step = sup.run({"w": np.zeros(1)}, iter(lambda: {}, None), num_steps=3)
    assert step == 3
    assert sup.backoffs == sleeps[:len(sup.backoffs)]
    # exponential-with-cap envelope: base*2^(k-1) <= delay <= cap*(1+jitter)
    for k, d in enumerate(sup.backoffs, start=1):
        lo = min(0.25, 0.1 * 2 ** (k - 1))
        assert lo <= d <= lo * 1.5
    assert sup.backoffs[2] <= 0.25 * 1.5  # the cap bit
    # seeded: a fresh supervisor replays the identical jitter sequence
    calls["n"] = 0
    sup2 = TrainSupervisor(cfg, step_fn, sleep_fn=lambda s: None)
    sup2.run({"w": np.zeros(1)}, iter(lambda: {}, None), num_steps=3)
    assert sup2.backoffs == sup.backoffs


def test_restart_budget_heals_after_sustained_health(tmp_path):
    """Sporadic transient faults over a long run must not exhaust the
    budget that guards against crash loops: every 8 healthy steps forgive
    one restart, so 4 spaced failures survive max_restarts=2."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] % 10 == 0 and calls["n"] <= 40:
            raise RuntimeError("sporadic fault")
        return _ok_step(state, batch)

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=5,
                         max_restarts=2, heal_after=8, async_save=False,
                         backoff_base_s=1e-4),
        step_fn, sleep_fn=lambda s: None)
    _, step = sup.run({"w": np.zeros(1)}, iter(lambda: {}, None),
                      num_steps=50)
    assert step == 50
    assert sup.restarts <= 2  # healed along the way, never exhausted


def test_nan_loss_restores_and_never_checkpoints_poison(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        new = {"w": state["w"] + 1.0}
        loss = float("nan") if calls["n"] == 5 else float(new["w"][0])
        return new, {"loss": jnp.asarray(loss)}

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2,
                         keep=10, max_restarts=3, async_save=False,
                         backoff_base_s=1e-4),
        step_fn, sleep_fn=lambda s: None)
    final, step = sup.run({"w": np.zeros(1)}, iter(lambda: {}, None),
                          num_steps=8)
    assert step == 8 and sup.restarts == 1
    assert float(final["w"][0]) == 8.0 and np.isfinite(final["w"]).all()
    # every checkpoint on disk holds a finite (never the poisoned) state
    from repro.checkpoint.checkpointer import restore_checkpoint
    import os
    for s in sup.manager.all_steps():
        path = os.path.join(str(tmp_path), f"step_{s:08d}")
        restored, _ = restore_checkpoint(path, {"w": np.zeros(1)})
        assert np.isfinite(restored["w"]).all(), f"poisoned ckpt at {s}"


def test_finite_iterator_drains_with_partial_checkpoint(tmp_path):
    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                         async_save=False),
        _ok_step, sleep_fn=lambda s: None)
    final, step = sup.run({"w": np.zeros(1)}, iter([{}] * 7), num_steps=20)
    # 7 batches < 20 steps: graceful drain, partial step count returned
    assert step == 7
    assert float(final["w"][0]) == 7.0
    # the partial step was checkpointed on the way out
    assert sup.manager.all_steps()[-1] == 7
    restored, s = sup.manager.restore_latest({"w": np.zeros(1)})
    assert s == 7 and float(restored["w"][0]) == 7.0


def test_replay_ledger_reserves_same_batches(tmp_path):
    seen = []
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        seen.append(batch["id"])
        if calls["n"] == 6:  # fails at step 5, after ckpt at 3
            raise RuntimeError("fault")
        return _ok_step(state, batch)

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path), checkpoint_every=3,
                         async_save=False, backoff_base_s=1e-4),
        step_fn, sleep_fn=lambda s: None)
    batches = ({"id": i} for i in range(100))
    _, step = sup.run({"w": np.zeros(1)}, batches, num_steps=8)
    assert step == 8
    # steps 0..4 ran, step 5 failed -> restore at 3, replay 3,4,5,... —
    # the restored run re-sees ids 3 and 4, never skips ahead
    assert seen == [0, 1, 2, 3, 4, 5, 3, 4, 5, 6, 7]


def test_replay_buffer_unit():
    rb = ReplayBuffer(iter(range(10)), base_step=2)
    assert [rb.next_batch() for _ in range(4)] == [0, 1, 2, 3]
    rb.rewind(3)
    assert rb.next_batch() == 1  # step 3 re-serves the second batch
    rb.commit(5)
    with pytest.raises(ValueError):
        rb.rewind(4)  # pre-commit batches are gone
    rb.rewind(5)
    assert rb.next_batch() == 3
    short = ReplayBuffer(iter(range(2)))
    short.next_batch(), short.next_batch()
    with pytest.raises(StopIteration):
        short.next_batch()


def test_rebuild_paths_reset_degradation_active_ledger(tmp_path):
    """Every supervisor rebuild calls begin_trace() first, so a blanket
    record_failure(None) blames only keys live in the current trace —
    not fused decisions left over from retired traces."""
    from repro.core.degrade import DegradationPolicy, DegradeConfig

    pol = DegradationPolicy(DegradeConfig(max_failures=1))
    pol.effective_mode("stale_op", (1, 2), "fused")   # from an old trace

    def rebuild():
        # a fresh trace re-registers only the ops actually in it
        pol.effective_mode("live_op", (3, 4), "fused")
        return lambda s, b: (s, {"loss": 0.0})

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path / "ck"),
                         async_save=False),
        lambda s, b: (s, {"loss": 0.0}),
        degradation=pol, rebuild_step=rebuild, sleep_fn=lambda s: None)

    # one strike quarantines stale_op -> dirty -> supervisor re-jits
    pol.record_failure(("stale_op", (1, 2)))
    sup._maybe_rebuild()
    assert ("live_op", (3, 4)) in pol._active
    assert ("stale_op", (1, 2)) not in pol._active
    # a NaN-loss blanket strike now blames only the live trace's key
    jailed = pol.record_failure(None)
    assert jailed == [("live_op", (3, 4))]
