"""HLO analysis parser: loop multipliers, dot flops, collective bytes."""
import numpy as np

from repro.launch.roofline import (hlo_analysis, model_flops,
                                   parse_collective_bytes, roofline_terms)

SYNTH = """\
HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %cp = f32[8,16]{1,0} collective-permute(%dot.1), source_target_pairs={{0,1},{1,0}}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %cp)
}

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%z, %a)
  %wh = (s32[], f32[8,16]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[8,16]{1,0} get-tuple-element(%wh), index=1
  ROOT %red = f32[8,16]{1,0} all-reduce(%ar), replica_groups={{0,1}}, to_apply=%cond.1
}
"""


def test_hlo_analysis_loop_multiplier():
    res = hlo_analysis(SYNTH)
    # dot: 2*8*16*16 flops, x10 loop trips
    assert res["flops"] == 2 * 8 * 16 * 16 * 10
    # collective-permute inside the loop: 8*16*4 bytes x10; all-reduce once
    assert res["colls"]["collective-permute"] == 8 * 16 * 4 * 10
    assert res["colls"]["all-reduce"] == 8 * 16 * 4
    assert res["counts"]["collective-permute"] == 10


def test_parse_collective_bytes_kinds():
    res = parse_collective_bytes(SYNTH)
    assert res["all-reduce"] == 8 * 16 * 4


def test_roofline_terms_dominance():
    r = roofline_terms(flops=197e12, bytes_accessed=0.0, collective_bytes=0.0)
    assert r["dominant"] == "compute" and abs(r["compute_s"] - 1.0) < 1e-9
    r = roofline_terms(flops=0.0, bytes_accessed=819e9, collective_bytes=0.0)
    assert r["dominant"] == "memory" and abs(r["memory_s"] - 1.0) < 1e-9
    r = roofline_terms(flops=0.0, bytes_accessed=0.0, collective_bytes=50e9)
    assert r["dominant"] == "collective" and abs(r["collective_s"] - 1.0) < 1e-9


def test_model_flops_moe_active():
    from repro.configs.registry import get_arch

    bundle = get_arch("deepseek-v3-671b")
    n = 671_000_000_000
    mf_train = model_flops(bundle, "train_4k", n)
    # active params ~37B -> 6*N_active*D must be far below 6*N*D
    assert mf_train < 6 * n * 4096 * 256 * 0.12
    assert mf_train > 6 * 20e9 * 4096 * 256
