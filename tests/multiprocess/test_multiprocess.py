"""The real multi-process jax.distributed lane (``pytest -m
multiprocess``).

Every test spawns coordinator-wired CPU worker processes (gloo
collectives, 4 local devices each -> a genuine 2x4 process-spanning
mesh) through :class:`repro.runtime.multiprocess.MultiprocessDriver`
and drives the elastic-respawn protocol with *real* faults: SIGKILL and
SIGSTOP delivered to live workers, detected by the heartbeat watchdog —
no FaultPlan injection anywhere in this file.

Asserted invariants:

* fused-op parity and the short training run hold across the process
  boundary (and match a same-mesh single-process run);
* a SIGKILLed peer surfaces as RankLost *from liveness*, survivors
  respawn on the shrunk world, and the recovered final state is
  bit-identical to a fault-free run on the same shrunk mesh;
* a SIGSTOPped peer surfaces as CollectiveTimeout, the driver reaps the
  wedged straggler, and a same-size respawn completes;
* the serve engine journals in-flight requests on a mid-drain kill and
  the respawned engine drains every request, tokens matching an
  uninterrupted reference;
* measured cross-process ring times produce a sane alpha-beta hardware
  model that drives the --calibrate sweep.
"""
import json
import os
import shutil
import signal
import sys

import numpy as np
import pytest

from repro.runtime.multiprocess import EXIT_OK, EXIT_RESHARD, EXIT_RESTART

pytestmark = [
    pytest.mark.multiprocess,
    pytest.mark.skipif(sys.platform != "linux",
                       reason="SIGSTOP/SIGKILL process drills are "
                              "linux-only"),
]

TRAIN_EXTRA = {"steps": 20, "batch": 8, "seq": 32, "ckpt_every": 3,
               "stall_after": 2.0}


def _read(path):
    with open(path) as f:
        return json.load(f)


def _result(res_dir, gen, rank):
    return _read(os.path.join(res_dir, f"result_g{gen}_r{rank}.json"))


# -- parity ----------------------------------------------------------------

def test_cross_process_parity(make_driver, mp_workdir):
    res_dir = os.path.join(mp_workdir, "parity_res")
    driver = make_driver("parity_worker.py", 2,
                         extra={"result_dir": res_dir})
    driver.launch_generation(0, 2)
    result = driver.wait_generation(timeout_s=420)
    assert result.codes == {0: EXIT_OK, 1: EXIT_OK}, result.codes
    out = _read(os.path.join(res_dir, "parity.json"))
    assert out["world"] == 2
    for arch, losses in out["losses"].items():
        assert np.isfinite(losses["fused"]) and np.isfinite(losses["bulk"])
    assert len(out["telemetry"]) == 8       # one entry per global device


def test_train_matches_single_process(make_driver, mp_workdir):
    """The same (2, 4) mesh computed by 2 processes and by 1 process is
    the same SPMD program: per-step losses must agree."""
    runs = {}
    for name, nproc, dpp in (("mp", 2, 4), ("sp", 1, 8)):
        res_dir = os.path.join(mp_workdir, f"{name}_res")
        extra = {**TRAIN_EXTRA, "steps": 8,
                 "ckpt_dir": os.path.join(mp_workdir, f"{name}_ckpt"),
                 "result_dir": res_dir}
        driver = make_driver("train_worker.py", nproc,
                             devices_per_proc=dpp, extra=extra)
        driver.launch_generation(0, nproc)
        result = driver.wait_generation(timeout_s=420)
        assert all(c == EXIT_OK for c in result.codes.values()), result.codes
        runs[name] = _result(res_dir, 0, 0)
    mp_losses = [r["loss"] for r in runs["mp"]["steps"]]
    sp_losses = [r["loss"] for r in runs["sp"]["steps"]]
    assert len(mp_losses) == len(sp_losses) == 8
    np.testing.assert_allclose(mp_losses, sp_losses, rtol=1e-5, atol=1e-6)


# -- SIGKILL: RankLost -> shrunk-world respawn -> pinned numerics ----------

def test_sigkill_elastic_recovery(make_driver, mp_workdir, log_reader):
    ckpt = os.path.join(mp_workdir, "ckpt")
    ckpt_ref = os.path.join(mp_workdir, "ckpt_ref")
    res_dir = os.path.join(mp_workdir, "res")
    extra = {**TRAIN_EXTRA, "ckpt_dir": ckpt, "result_dir": res_dir}
    driver = make_driver("train_worker.py", 2, extra=extra)

    def snapshot(d, result):
        # freeze the restore point the survivors will use, for the
        # fault-free reference run
        if result.generation == 0 and not os.path.exists(ckpt_ref):
            shutil.copytree(ckpt, ckpt_ref)

    report = driver.run_elastic(
        max_generations=3, gen_timeout_s=420,
        faults={0: lambda d: d.kill_at_step(1, 6)},   # never rank 0: it
        # hosts the gloo coordinator
        on_generation_end=snapshot)

    assert report.completed, [g.codes for g in report.generations]
    g0, g1 = report.generations[0], report.generations[1]
    assert g0.codes[1] == -signal.SIGKILL
    assert g0.codes[0] == EXIT_RESHARD
    assert g1.world == 1 and g1.codes == {0: EXIT_OK}
    assert len(report.events("kill")) == 1

    # the survivor's exit came from the liveness watchdog, not chaos
    log0 = log_reader(driver, 0, 0)
    assert "RankLost from liveness" in log0
    assert "liveness:" in log0
    assert "injected" not in log0               # no FaultPlan involved

    # the recovered run resumed from the checkpoint, not from scratch
    r1 = _result(res_dir, 1, 0)
    assert r1["start_step"] > 0
    assert r1["completed"] and r1["world"] == 1
    assert r1["steps"][-1]["step"] == TRAIN_EXTRA["steps"]

    # --- numerics pin: fault-free run on the same shrunk mesh ---------
    ref_res = os.path.join(mp_workdir, "ref_res")
    ref = make_driver("train_worker.py", 1, devices_per_proc=4,
                      extra={**extra, "ckpt_dir": ckpt_ref,
                             "result_dir": ref_res}, sub="ref")
    ref.launch_generation(1, 1)
    result = ref.wait_generation(timeout_s=420)
    assert result.codes == {0: EXIT_OK}

    rec = np.load(os.path.join(res_dir, "final_g1.npz"))
    exp = np.load(os.path.join(ref_res, "final_g1.npz"))
    assert sorted(rec.files) == sorted(exp.files)
    for k in rec.files:
        assert np.array_equal(rec[k], exp[k]), \
            f"recovered state diverged from fault-free reference at {k}"

    # and the per-step losses match too
    ref_r = _result(ref_res, 1, 0)
    np.testing.assert_allclose([s["loss"] for s in r1["steps"]],
                               [s["loss"] for s in ref_r["steps"]],
                               rtol=0, atol=0)


# -- SIGSTOP: CollectiveTimeout -> same-world respawn ----------------------

def test_sigstop_stall_restart(make_driver, mp_workdir, log_reader):
    res_dir = os.path.join(mp_workdir, "res")
    extra = {**TRAIN_EXTRA, "ckpt_dir": os.path.join(mp_workdir, "ckpt"),
             "result_dir": res_dir}
    driver = make_driver("train_worker.py", 2, extra=extra,
                         hang_grace_s=8.0)
    report = driver.run_elastic(
        max_generations=3, gen_timeout_s=420,
        faults={0: lambda d: d.kill_at_step(1, 6, sig=signal.SIGSTOP)})

    assert report.completed, [g.codes for g in report.generations]
    g0, g1 = report.generations[0], report.generations[1]
    # the healthy rank diagnosed a transient stall (pid alive, heartbeat
    # stale) and voted same-world restart
    assert g0.codes[0] == EXIT_RESTART
    # the wedged rank never exited on its own: the driver reaped it
    assert g0.codes[1] == -signal.SIGKILL
    assert len(report.events("reap")) >= 1
    log0 = log_reader(driver, 0, 0)
    assert "CollectiveTimeout from liveness" in log0
    assert "stalled" in log0

    # same-size respawn resumed from the checkpoint and finished
    assert g1.world == 2
    assert g1.codes == {0: EXIT_OK, 1: EXIT_OK}
    r1 = _result(res_dir, 1, 0)
    assert r1["start_step"] > 0 and r1["completed"]


# -- serve: mid-drain kill -> journal -> respawn drains everything ---------

def test_serve_drain_recovery(make_driver, mp_workdir, log_reader):
    res_dir = os.path.join(mp_workdir, "res")
    journal = os.path.join(mp_workdir, "journal.json")
    extra = {"result_dir": res_dir, "journal": journal, "requests": 12,
             "batch": 6, "max_new": 48, "stall_after": 2.0,
             "tick_sleep": 0.01}
    driver = make_driver("serve_worker.py", 2, extra=extra)
    report = driver.run_elastic(
        max_generations=3, gen_timeout_s=420,
        faults={0: lambda d: d.kill_at_step(1, 30)})

    assert report.completed, [g.codes for g in report.generations]
    g0, g1 = report.generations[0], report.generations[1]
    assert g0.codes[1] == -signal.SIGKILL and g0.codes[0] == EXIT_RESHARD
    assert g1.world == 1 and g1.codes == {0: EXIT_OK}
    log0 = log_reader(driver, 0, 0)
    assert "RankLost from liveness" in log0

    out0 = _read(os.path.join(res_dir, "tokens_g0.json"))
    out1 = _read(os.path.join(res_dir, "tokens_g1.json"))
    assert not out0["drained"] and out1["drained"]
    assert out0["journaled"], "kill landed after the drain finished — " \
        "nothing was in flight"

    # every request drained exactly once across the two generations
    merged = {**out0["tokens"], **out1["tokens"]}
    assert sorted(map(int, merged)) == list(range(extra["requests"]))

    # --- uninterrupted world=1 reference: same tokens for every uid ---
    ref_res = os.path.join(mp_workdir, "ref_res")
    ref = make_driver("serve_worker.py", 1, devices_per_proc=4,
                      extra={**extra, "result_dir": ref_res,
                             "journal": os.path.join(mp_workdir,
                                                     "ref_journal.json")},
                      sub="ref")
    ref.launch_generation(0, 1)
    result = ref.wait_generation(timeout_s=420)
    assert result.codes == {0: EXIT_OK}
    ref_out = _read(os.path.join(ref_res, "tokens_g0.json"))
    assert ref_out["drained"]
    assert merged == ref_out["tokens"], \
        "recovered drain produced different tokens than the " \
        "uninterrupted reference"


# -- measured hardware model ----------------------------------------------

def test_ring_measurement_feeds_hardware_model(make_driver, mp_workdir):
    res_dir = os.path.join(mp_workdir, "res")
    driver = make_driver("ring_worker.py", 2,
                         extra={"result_dir": res_dir})
    driver.launch_generation(0, 2)
    result = driver.wait_generation(timeout_s=420)
    assert result.codes == {0: EXIT_OK, 1: EXIT_OK}, result.codes

    out = _read(os.path.join(res_dir, "ring.json"))
    assert out["world"] == 2
    assert all(t > 0 for t in out["times_s"])
    assert out["alpha_s"] >= 0
    assert 1e6 < out["measured_bw"] < 1e13    # physically plausible
    # larger payloads take longer (the beta term dominates eventually)
    assert out["times_s"][-1] > out["times_s"][0]
    # the measured prediction reproduces the measured times far better
    # than a wildly wrong constant would; sanity-band the DCN ratio
    ratio = out["measured_pred_s"][-1] / out["dcn_pred_s"][-1]
    assert 1e-3 < ratio < 1e3
    assert out["calibrated_keys"] >= 0
