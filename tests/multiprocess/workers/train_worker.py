"""Multi-process training worker: short supervised run with real
liveness, checkpoint/restore, and the elastic-respawn protocol exits.

The driver's elastic drills exercise every path:

* generation 0 (world N): train with per-step heartbeats; a SIGKILLed
  peer surfaces as RankLost from the liveness monitor -> exit
  EXIT_RESHARD; a SIGSTOPped peer surfaces as CollectiveTimeout ->
  exit EXIT_RESTART.
* generation 1 (survivor world): restore the latest checkpoint from the
  shared directory, fast-forward the deterministic seeded batch stream
  to the restored step (the cross-process ReplayBuffer analogue), and
  finish the run.  Rank 0 writes the final state so the test can pin it
  bit-identical against a fault-free run on the same shrunk mesh.

extra keys: steps, batch, seq, ckpt_every, ckpt_dir, result_dir,
[arch, stall_after, lr].
"""
import json
import os
import time

from _common import arm, bootstrap, put_batch, write_json


def main():
    mp, cfg, rt = bootstrap()
    import jax
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import _shardings, make_batches
    from repro.models.common import split_params
    from repro.runtime.chaos import CollectiveTimeout, RankLost
    from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor
    from repro.train.optimizer import OptimizerConfig
    from repro.train.step import (TrainConfig, build_train_step,
                                  init_train_state, train_state_specs)

    x = cfg.extra
    steps = int(x.get("steps", 20))
    batch = int(x.get("batch", 8))
    seq = int(x.get("seq", 32))
    ckpt_dir = x["ckpt_dir"]
    result_dir = x["result_dir"]
    os.makedirs(result_dir, exist_ok=True)

    ctx = make_host_mesh()
    bundle = get_arch(x.get("arch", "chatglm3-6b")).reduced()
    params_p = bundle.init_params(jax.random.PRNGKey(0))
    params, param_specs = split_params(params_p)
    tc = TrainConfig(optimizer=OptimizerConfig(
        name=bundle.optimizer, lr=float(x.get("lr", 1e-3)),
        warmup_steps=2, total_steps=steps))
    state = init_train_state(tc, params)
    state_sh = _shardings(ctx, train_state_specs(tc, param_specs))
    state = rt.global_put(state, state_sh)

    raw_step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc),
                       donate_argnums=(0,))

    def step_fn(state, b):
        return raw_step(state, put_batch(ctx, batch, b))

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=ckpt_dir,
                         checkpoint_every=int(x.get("ckpt_every", 3)),
                         max_restarts=0, async_save=False),
        step_fn, state_shardings=state_sh, liveness=rt.monitor)

    # A fresh process must fast-forward the seeded batch stream to the
    # restored step itself (ReplayBuffer only covers in-process restarts).
    state, start = sup.maybe_restore(state)
    batches = iter(make_batches(bundle, batch, seq, seed=0))
    for _ in range(start):
        next(batches)
    print(f"worker r{cfg.rank}/g{cfg.generation}: world={cfg.world} "
          f"start_step={start} mesh={dict(ctx.mesh.shape)}", flush=True)

    records = []

    def on_metrics(step, metrics):
        records.append({"step": step, "loss": float(metrics["loss"]),
                        "t": time.time()})
        arm(rt, step=step)
        print(f"step {step} loss {records[-1]['loss']:.4f}", flush=True)

    result = {"rank": cfg.rank, "world": cfg.world,
              "generation": cfg.generation, "start_step": start,
              "steps": records, "completed": False, "exit_reason": None}

    def dump(reason):
        result["exit_reason"] = reason
        write_json(os.path.join(
            result_dir, f"result_g{cfg.generation}_r{cfg.rank}.json"), result)

    try:
        try:
            final, step = sup.run(state, batches, steps, start_step=start,
                                  on_metrics=on_metrics)
        except (RankLost, CollectiveTimeout):
            raise
        except Exception as e:
            # a peer dying inside a collective surfaces as a raw
            # transport error first — let the watchdog name the culprit
            rt.diagnose(e)
        result["completed"] = True
        host = rt.host_gather(final)
        if cfg.rank == 0:
            leaves = [np.asarray(v) for v in jax.tree.leaves(host)]
            np.savez(os.path.join(result_dir,
                                  f"final_g{cfg.generation}.npz"), *leaves)
        rt.barrier("train_done")
        dump("ok")
        rt.leave(mp.EXIT_OK)
    except RankLost as e:
        print(f"worker r{cfg.rank}: RankLost from liveness: {e}", flush=True)
        dump(f"rank_lost:{e.rank}")
        rt.leave(mp.EXIT_RESHARD)
    except CollectiveTimeout as e:
        print(f"worker r{cfg.rank}: CollectiveTimeout from liveness: {e}",
              flush=True)
        dump("collective_timeout")
        rt.leave(mp.EXIT_RESTART)


if __name__ == "__main__":
    main()
