"""Cross-process link measurement worker.

Times real all-reduces over the cross-process ``data`` axis, fits the
alpha-beta line, and validates the :class:`~repro.core.perfmodel.
MeshHardwareModel` story end to end:

1. measured ring times -> :func:`~repro.runtime.multiprocess.
   measured_hardware_model` (a HardwareModel with measured link
   constants) vs the static DCN constants;
2. the measured model slots into a per-axis ``MeshHardwareModel`` (the
   cross-process axis rides the measured link class, intra-process axes
   keep V5E) and drives the ``--calibrate`` measured-sweep path, which
   times real fused-op candidates over the same cross-process links.

Rank 0 writes ``result_dir/ring.json`` with both models' predictions.
"""
import dataclasses
import os

from _common import bootstrap, param_shardings, put_batch, write_json


def main():
    mp, cfg, rt = bootstrap()
    import jax
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.core.calibrate import warmup_and_calibrate
    from repro.core.perfmodel import DCN, V5E, MeshHardwareModel, resolve_hw
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_batches
    from repro.models.common import split_params
    from repro.parallel.sharding import FusionConfig

    x = cfg.extra
    result_dir = x["result_dir"]
    sizes = [int(s) for s in x.get("sizes", [1 << 20, 4 << 20, 16 << 20])]

    ctx = make_host_mesh(fusion=FusionConfig(mode="fused",
                                             granularity="auto"))
    times = mp.measure_ring(ctx.mesh, "data", sizes)
    alpha, beta = mp.fit_alpha_beta(sizes, times)
    measured = mp.measured_hardware_model(sizes, times)
    print(f"ring r{cfg.rank}: data-axis alpha={alpha * 1e6:.1f}us "
          f"bw={measured.ici_bw / 1e9:.3f} GB/s", flush=True)

    # the measured link class attaches to the cross-process axis; the
    # intra-process model axis keeps the chip's own ICI constants
    mhw = MeshHardwareModel.from_mapping({"data": measured}, default=V5E)
    ctx2 = dataclasses.replace(ctx, hw=mhw)
    assert resolve_hw(ctx2.hw, "data").ici_bw == measured.ici_bw
    assert resolve_hw(ctx2.hw, "model").ici_bw == V5E.ici_bw

    # drive the --calibrate measured sweep through the measured model:
    # candidate timing runs real fused collectives across the process
    # boundary (identical code on every process -> same collective order)
    bundle = get_arch(x.get("arch", "chatglm3-6b")).reduced()
    batch, seq = int(x.get("batch", 8)), int(x.get("seq", 32))
    params_p = bundle.init_params(jax.random.PRNGKey(0))
    params, param_specs = split_params(params_p)
    params = rt.global_put(params, param_shardings(ctx2, param_specs))
    b = put_batch(ctx2, batch,
                  next(iter(make_batches(bundle, batch, seq, seed=0))))
    loss = jax.jit(lambda p, bb: bundle.loss_fn(ctx2)(p, bb))
    decisions = warmup_and_calibrate(ctx2, loss, params, b, iters=1)

    rt.barrier("ring_done")
    if cfg.rank == 0:
        write_json(os.path.join(result_dir, "ring.json"), {
            "world": cfg.world,
            "sizes": sizes,
            "times_s": times,
            "alpha_s": alpha,
            "beta_s_per_byte": beta,
            "measured_bw": measured.ici_bw,
            "measured_lat": measured.ici_lat,
            "dcn_pred_s": [s / DCN.ici_bw + DCN.ici_lat for s in sizes],
            "measured_pred_s": [s / measured.ici_bw + measured.ici_lat
                                for s in sizes],
            "calibrated_keys": len(decisions),
        })
    rt.leave(mp.EXIT_OK)


if __name__ == "__main__":
    main()
