"""Shared bootstrap for the multiprocess lane workers.

These files are *scripts* run by :class:`repro.runtime.multiprocess.
MultiprocessDriver` (never imported by pytest): each process reads its
:class:`~repro.runtime.multiprocess.WorkerEnv` contract from the
environment, wires itself into the jax.distributed world, and exits
through the elastic-respawn protocol codes.

Import order matters: ``bootstrap()`` must run before anything touches
the jax backend (it sets the per-worker ``XLA_FLAGS`` device count), so
workers import jax and the model stack only *after* calling it.
"""
import json
import os


def bootstrap(**init_kw):
    """(multiprocess module, WorkerEnv, WorkerRuntime) for this process."""
    from repro.runtime import multiprocess as mp

    cfg = mp.WorkerEnv.from_env()
    if "stall_after" in cfg.extra:
        init_kw.setdefault("stall_after_s", float(cfg.extra["stall_after"]))
    rt = mp.init_worker(cfg, **init_kw)
    return mp, cfg, rt


def arm(rt, step=None):
    """Beat the heartbeat and arm the liveness monitor (call after the
    first successful step — never during compile)."""
    rt.writer.beat(step=step)
    rt.monitor.enabled = True


def put_batch(ctx, batch_size: int, batch):
    """Host-stage one data batch onto the global mesh: leaves with a
    leading batch dim shard over the dp axes, everything else replicates.
    Placement is collective-free (each process materializes only its
    addressable shards) — the gloo-safe recipe."""
    import jax
    import numpy as np

    from repro.checkpoint.checkpointer import host_to_device

    def put(a):
        a = np.asarray(a)
        if a.ndim >= 1 and a.shape[0] == batch_size:
            sh = ctx.sharding("batch", *([None] * (a.ndim - 1)))
        else:
            sh = ctx.sharding(*([None] * a.ndim))
        return host_to_device(a, sh)

    return jax.tree.map(put, batch)


def param_shardings(ctx, param_specs):
    import jax

    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return jax.tree.map(lambda s: ctx.sharding(*s), param_specs,
                        is_leaf=is_spec)


def write_json(path: str, obj) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)
