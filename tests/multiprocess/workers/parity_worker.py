"""Cross-process numerical parity worker.

Runs on the full coordinator-wired world and checks, across a *real*
process boundary, the invariants the tier-1 suite only proves on a
single process's 8-device mesh:

* fused vs bulk loss parity for a transformer (the ring collectives
  cross the gloo process boundary on the data axis);
* fused vs bulk parity for DLRM (the embedding all-to-all rings over
  the flattened *world* axis — every hop crosses processes);
* :class:`~repro.runtime.straggler.ProcessTelemetry` all-gathers one
  EWMA per process and spreads it over local devices.

Rank 0 writes the measured losses to ``result_dir/parity.json``.
"""
import os

from _common import bootstrap, param_shardings, put_batch, write_json


def _loss(ctx, bundle, params, batch):
    import jax

    fn = jax.jit(lambda p, b: bundle.loss_fn(ctx)(p, b))
    out = fn(params, batch)
    loss = out[0] if isinstance(out, tuple) else out
    return float(loss)


def main():
    mp, cfg, rt = bootstrap()
    import dataclasses

    import jax
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_batches
    from repro.models.common import split_params
    from repro.parallel.sharding import FusionConfig
    from repro.runtime.straggler import ProcessTelemetry, StragglerMonitor

    x = cfg.extra
    batch = int(x.get("batch", 8))
    seq = int(x.get("seq", 32))
    result_dir = x["result_dir"]
    out = {"world": cfg.world, "rank": cfg.rank, "losses": {}}

    for arch in ("chatglm3-6b", "dlrm"):
        ctx = make_host_mesh(fusion=FusionConfig(mode="fused"))
        bundle = get_arch(arch).reduced()
        params_p = bundle.init_params(jax.random.PRNGKey(0))
        params, param_specs = split_params(params_p)
        params = rt.global_put(params, param_shardings(ctx, param_specs))
        b = put_batch(ctx, batch,
                      next(iter(make_batches(bundle, batch, seq, seed=0))))

        fused = _loss(ctx, bundle, params, b)
        bulk = _loss(ctx.with_fusion(dataclasses.replace(
            ctx.fusion, mode="bulk")), bundle, params, b)
        out["losses"][arch] = {"fused": fused, "bulk": bulk}
        np.testing.assert_allclose(fused, bulk, rtol=2e-5, atol=2e-5,
                                   err_msg=f"{arch} fused!=bulk across "
                                           f"process boundary")
        print(f"parity r{cfg.rank}: {arch} fused={fused:.6f} "
              f"bulk={bulk:.6f}", flush=True)

    # per-process telemetry: each process contributes a distinct EWMA;
    # the gathered vector must have world-device length, process-major.
    mon = StragglerMonitor()
    mon.record(0.1 * (cfg.rank + 1))
    tel = ProcessTelemetry(mon, len(jax.devices()))
    times = tel(0.1 * (cfg.rank + 1))
    assert len(times) == len(jax.devices()), times
    per_proc = sorted(set(round(t, 6) for t in times))
    assert len(per_proc) == cfg.world, (per_proc, cfg.world)
    out["telemetry"] = times

    rt.barrier("parity_done")
    if cfg.rank == 0:
        write_json(os.path.join(result_dir, "parity.json"), out)
    rt.leave(mp.EXIT_OK)


if __name__ == "__main__":
    main()
