"""Multi-process serve worker: batched decode with the batch sharded
over the cross-process data axis, real liveness on every tick, and
journal-based drain recovery.

The decode wrapper pins the cross-process dataflow explicitly:

* tokens/positions shard over ``data`` (spanning processes), the KV
  cache shards on its batch dimension, and the logits are *replicated*
  — so every decode step ends in an all-gather across the process
  boundary (the fused GEMV+collective serving pattern);
* the cache is host-staged onto the global mesh once, on the first
  call (committed-array resharding across gloo processes is not
  supported), and then carried as a global array between steps;
* the engine reads logits as host numpy via the locally-addressable
  replica, so the engine code itself stays mesh-agnostic.

On a liveness raise (peer SIGKILLed mid-drain) rank 0 journals every
unfinished request — generated tokens intact — and exits EXIT_RESHARD;
the respawned generation resubmits the journal and every request still
drains to completion.

extra keys: result_dir, journal, [requests, batch, max_new, arch,
stall_after, tick_sleep].
"""
import os
import time

from _common import arm, bootstrap, param_shardings, write_json


class CrossProcessDecode:
    """decode(tokens [B,1], cache, pos [B]) -> (host logits, global cache)
    with the batch dim sharded over the data axis."""

    def __init__(self, decode, params, ctx, batch):
        import jax

        self.ctx = ctx
        self.batch = batch
        self._decode = decode
        self._params = params
        self._jit = None
        self._cache_is_global = False
        self._jax = jax

    def _cache_sharding(self, leaf):
        dims = [i for i, d in enumerate(leaf.shape) if d == self.batch]
        spec = [None] * leaf.ndim
        if dims:
            spec[dims[0]] = "batch"
        return self.ctx.sharding(*spec)

    def __call__(self, tokens, cache, pos):
        import numpy as np

        from repro.checkpoint.checkpointer import host_to_device
        jax = self._jax

        t = host_to_device(np.asarray(tokens),
                           self.ctx.sharding("batch", None))
        p = host_to_device(np.asarray(pos), self.ctx.sharding("batch"))
        if not self._cache_is_global:
            cache = jax.tree.map(
                lambda l: host_to_device(np.asarray(jax.device_get(l)),
                                         self._cache_sharding(l)), cache)
            self._cache_is_global = True
        if self._jit is None:
            cache_sh = jax.tree.map(lambda l: l.sharding, cache)
            logits_sh = self.ctx.sharding(None)   # replicated: the
            # cross-process all-gather every step
            # (params must be an argument — jit cannot close over an
            # array spanning non-addressable devices)
            self._jit = jax.jit(
                self._decode, out_shardings=(logits_sh, cache_sh))
        logits, cache = self._jit(self._params, t, cache, p)
        host = np.asarray(logits.addressable_data(0))
        return host, cache


def main():
    mp, cfg, rt = bootstrap()
    import jax
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.models.common import split_params
    from repro.runtime.chaos import CollectiveTimeout, RankLost
    from repro.serve.engine import (DecodeEngine, Request, request_journal,
                                    resubmit_journal)

    x = cfg.extra
    batch = int(x.get("batch", 6))
    n_requests = int(x.get("requests", 12))
    max_new = int(x.get("max_new", 48))
    result_dir = x["result_dir"]
    journal_path = x.get("journal")
    tick_sleep = float(x.get("tick_sleep", 0.0))

    ctx = make_host_mesh()
    bundle = get_arch(x.get("arch", "chatglm3-6b")).reduced()
    vocab = bundle.config.vocab
    params_p = bundle.init_params(jax.random.PRNGKey(0))
    params, param_specs = split_params(params_p)
    params = rt.global_put(params, param_shardings(ctx, param_specs))

    decode = CrossProcessDecode(bundle.decode_fn(ctx), params, ctx, batch)
    engine = DecodeEngine(decode, bundle.init_cache, batch,
                          max_seq=bundle.config.max_seq)

    tracked = {}
    if journal_path and os.path.exists(journal_path):
        with open(journal_path) as f:
            journal = __import__("json").load(f)
        n = resubmit_journal(engine, journal)
        tracked = {r.uid: r for r in engine.queue}
        print(f"serve r{cfg.rank}/g{cfg.generation}: resubmitted {n} "
              f"journaled requests", flush=True)
    else:
        rng = np.random.default_rng(0)
        for i in range(n_requests):
            req = Request(uid=i,
                          prompt=rng.integers(
                              0, vocab, size=int(rng.integers(2, 6))).tolist(),
                          max_new=max_new)
            tracked[i] = req
            engine.submit(req)

    # per-tick heartbeat steps so the driver can kill a peer "at tick k"
    orig_step = engine.step
    tick = [0]

    def step():
        out = orig_step()
        tick[0] += 1
        arm(rt, step=tick[0])
        if tick_sleep:
            time.sleep(tick_sleep)
        return out

    engine.step = step
    print(f"serve r{cfg.rank}/g{cfg.generation}: world={cfg.world} "
          f"mesh={dict(ctx.mesh.shape)} draining {len(tracked)} requests",
          flush=True)

    def finished_tokens():
        return {str(r.uid): list(r.tokens)
                for r in tracked.values() if r.done}

    try:
        try:
            res = engine.run_until_drained(max_steps=100_000,
                                           liveness=rt.monitor)
        except (RankLost, CollectiveTimeout):
            raise
        except Exception as e:
            # a peer dying inside a collective surfaces as a raw
            # transport error first — let the watchdog name the culprit
            rt.diagnose(e)
        assert res.drained, "engine stopped before draining"
        rt.barrier("serve_done")
        if cfg.rank == 0:
            write_json(os.path.join(result_dir,
                                    f"tokens_g{cfg.generation}.json"),
                       {"drained": True, "ticks": tick[0],
                        "tokens": finished_tokens()})
        rt.leave(mp.EXIT_OK)
    except (RankLost, CollectiveTimeout) as e:
        kind = "RankLost" if isinstance(e, RankLost) else "CollectiveTimeout"
        print(f"serve r{cfg.rank}: {kind} from liveness: {e}", flush=True)
        if cfg.rank == 0:
            journal = request_journal(engine)
            if journal_path:
                write_json(journal_path, journal)
            write_json(os.path.join(result_dir,
                                    f"tokens_g{cfg.generation}.json"),
                       {"drained": False, "ticks": tick[0],
                        "tokens": finished_tokens(),
                        "journaled": [e_["uid"] for e_ in journal]})
            print(f"serve r0: journaled {len(journal)} unfinished "
                  f"requests", flush=True)
        rt.leave(mp.EXIT_RESHARD if isinstance(e, RankLost)
                 else mp.EXIT_RESTART)


if __name__ == "__main__":
    main()
