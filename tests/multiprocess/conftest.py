"""Fixtures for the real multi-process jax.distributed lane.

Every test here spawns coordinator-wired worker subprocesses through
:class:`repro.runtime.multiprocess.MultiprocessDriver`; the lane is
marker-gated (``pytest -m multiprocess``) and deselected from the
default run by ``pytest.ini``.

``REPRO_MP_LOG_ROOT`` (set by the CI job) redirects every driver's
workdir under a stable path so per-process worker logs survive the test
run and can be uploaded as failure artifacts; without it artifacts land
in pytest's tmp_path.
"""
import itertools
import os
import re

import pytest

WORKERS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "workers")


@pytest.fixture
def mp_workdir(tmp_path, request):
    root = os.environ.get("REPRO_MP_LOG_ROOT")
    if not root:
        return str(tmp_path)
    safe = re.sub(r"[^\w.-]", "_", request.node.name)
    d = os.path.join(root, safe)
    os.makedirs(d, exist_ok=True)
    return d


@pytest.fixture
def make_driver(mp_workdir):
    """Factory for drivers with per-driver workdirs under the test's
    artifact root (a reference run and the elastic run must not share
    log/heartbeat directories)."""
    from repro.runtime.multiprocess import MultiprocessDriver

    counter = itertools.count()

    def make(script: str, nproc: int, *, devices_per_proc: int | None = None,
             extra: dict | None = None, sub: str | None = None, **kw):
        if devices_per_proc is None:
            # keep the global device count at 8 (the tier-1 mesh) so a
            # 2-proc world is 2x4 and a 1-proc world reuses all 8
            devices_per_proc = max(1, 8 // nproc)
        workdir = os.path.join(mp_workdir, sub or f"d{next(counter)}")
        os.makedirs(workdir, exist_ok=True)
        kw.setdefault("hang_grace_s", 10.0)
        return MultiprocessDriver([os.path.join(WORKERS, script)], nproc,
                                  devices_per_proc=devices_per_proc,
                                  workdir=workdir, extra=extra, **kw)

    return make


def read_log(driver, generation: int, rank: int) -> str:
    path = os.path.join(driver.workdir, "logs",
                        f"g{generation}_r{rank}.log")
    with open(path) as f:
        return f.read()


@pytest.fixture
def log_reader():
    return read_log
