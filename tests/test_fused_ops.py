"""Fused-op behaviours not covered by the parity matrix.

Bulk-vs-fused output parity for every op family (x dtype x
chunks_per_rank x shape) lives in ``test_parity_matrix.py``; this module
keeps the kernel-mode path, autodiff-through-fused checks, schedule
equivalence, and the decode MoE layout test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fused import (allgather_matmul, embedding_all_to_all,
                              matmul_allreduce, matmul_reducescatter)


def test_matmul_allreduce_kernel_mode_1d(ctx1d, rng):
    """Device-initiated Pallas kernel path (1D mesh: interpreter limit)."""
    x = rng.standard_normal((4, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    ref = x @ w
    y = jax.jit(lambda x, w: matmul_allreduce(ctx1d, x, w, mode="kernel"))(x, w)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


def test_fused_ops_differentiable(ctx, rng):
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    co = rng.standard_normal((4, 16, 64)).astype(np.float32)

    for op in [matmul_allreduce, allgather_matmul, matmul_reducescatter]:
        gf = jax.jit(jax.grad(lambda x, w: (op(ctx, x, w, mode="fused") * co).sum(),
                              argnums=(0, 1)))(x, w)
        gb = jax.jit(jax.grad(lambda x, w: (op(ctx, x, w, mode="bulk") * co).sum(),
                              argnums=(0, 1)))(x, w)
        for a, b in zip(gf, gb):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


def test_embedding_a2a_scheduling_equivalence(ctx, rng):
    idx = rng.integers(0, 32, size=(16, 8, 4)).astype(np.int32)
    tabs = rng.standard_normal((8, 32, 8)).astype(np.float32)
    ya = jax.jit(lambda i, t: embedding_all_to_all(ctx, i, t, mode="fused",
                                                   schedule="comm_aware"))(idx, tabs)
    yo = jax.jit(lambda i, t: embedding_all_to_all(ctx, i, t, mode="fused",
                                                   schedule="oblivious"))(idx, tabs)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yo), rtol=1e-6)


def test_moe_decode_ep_matches_dense(ctx, rng):
    """Weight-stationary EP-world decode MoE (serve layout) == dense ref."""
    from repro.models.common import split_params
    from repro.models.moe import MoEConfig, moe_apply, moe_init

    cfg = MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=16,
                    n_shared_experts=1, capacity_factor=8.0)  # no drops
    params, _ = split_params(moe_init(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = rng.standard_normal((4, 1, 32)).astype(np.float32)  # S=1 -> EP path

    toks = x.reshape(-1, 32)
    logits = toks @ np.asarray(params["router"])
    p = jax.nn.softmax(jnp.asarray(logits), -1)
    gw, gi = jax.lax.top_k(p, 2)
    gw = gw / gw.sum(-1, keepdims=True)
    ref = np.zeros_like(toks)
    for t in range(toks.shape[0]):
        for k in range(2):
            e = int(gi[t, k])
            g = jax.nn.silu(toks[t] @ np.asarray(params["w_gate"][e]))
            u = toks[t] @ np.asarray(params["w_up"][e])
            ref[t] += float(gw[t, k]) * np.asarray(
                (g * u) @ np.asarray(params["w_down"][e]))
    sh = params["shared"]
    ref = ref + np.asarray((jax.nn.silu(toks @ sh["w_gate"]) *
                            (toks @ sh["w_up"])) @ sh["w_down"])
    y = jax.jit(lambda x: moe_apply(ctx, params, x, cfg))(x)
    np.testing.assert_allclose(np.asarray(y), ref.reshape(x.shape),
                               rtol=2e-4, atol=2e-4)
