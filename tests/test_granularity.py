"""Tile-pipelined kernels (N and K dims), autotuner, measured sweep.

XLA-level ``chunks_per_rank`` parity for every fused-op family lives in
``test_parity_matrix.py``; this module owns the Pallas kernel pipelines
and the autotuner unit behaviour.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import (choose_chunks_per_rank, choose_tile_k,
                                 choose_tile_n, feasible_tile, measured_best,
                                 resolve_granularity)
from repro.core.collectives import feasible_chunks_per_rank
from repro.core.fused import fused_expert_ffn_combine, matmul_allreduce
from repro.core.perfmodel import V5E, model_bulk, model_fused
from repro.kernels.fused_gemm_a2a.ops import fused_gemm_a2a
from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce
from repro.kernels.fused_gemv_allreduce.ref import (
    fused_matmul_allreduce_ref_global)


# ---------------------------------------------------------------------------
# pipelined fused GEMV/GEMM+AllReduce kernel (interpret-mode parity vs ref)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-3), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("tile_n", [None, 4, 16])
@pytest.mark.parametrize("rows,k,n", [(4, 32, 128), (1, 64, 64)])
def test_pipelined_kernel_parity(ctx1d, rng, dtype, tol, tile_n, rows, k, n):
    x = rng.standard_normal((rows, k)).astype(dtype)
    w = rng.standard_normal((k, n)).astype(dtype)
    ref = fused_matmul_allreduce_ref_global(
        np.asarray(x, np.float32), np.asarray(w, np.float32))
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, tile_n=tile_n))(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_pipelined_kernel_ragged_tile_request(ctx1d, rng):
    """A requested tile that does not divide N/n_dev is clamped to the
    largest uniform divisor — parity must still hold."""
    x = rng.standard_normal((4, 32)).astype(np.float32)
    w = rng.standard_normal((32, 96)).astype(np.float32)  # bn = 12
    for req in [5, 7, 9, 100]:
        y = jax.jit(lambda x, w, t=req: fused_matmul_allreduce(
            ctx1d, x, w, tile_n=t))(x, w)
        np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


def test_pipelined_kernel_exceeds_old_vmem_block(ctx1d, rng):
    """[K, N] whose weight block exceeds what the old single-shot kernel
    staged in VMEM: the old kernel held the whole [K, N] panel; the
    pipeline holds two [K, tile_n] panels.  With tile_n=64 the streamed
    working set is 32x smaller than the full 256x2048 operand."""
    x = rng.standard_normal((2, 256)).astype(np.float32)
    w = rng.standard_normal((256, 2048)).astype(np.float32)
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, tile_n=64))(x, w)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# K-panel streaming: the contraction dim no longer caps at VMEM
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tile_k", [16, 32])
def test_kpanel_streaming_even_panels(ctx1d, rng, tile_k):
    x = rng.standard_normal((4, 64)).astype(np.float32)
    w = rng.standard_normal((64, 128)).astype(np.float32)
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, tile_n=8, tile_k=tile_k))(x, w)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("k,tile_k", [(56, 16), (72, 32), (40, 24)])
def test_kpanel_ragged_final_panel(ctx1d, rng, k, tile_k):
    """tile_k need not divide K: the final panel streams (and matmuls)
    only the K remainder — its copy descriptor is sized to the ragged
    rows, so the DMA byte accounting stays exact."""
    x = rng.standard_normal((4, k)).astype(np.float32)
    w = rng.standard_normal((k, 64)).astype(np.float32)
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, tile_n=8, tile_k=tile_k))(x, w)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


def test_kpanel_exceeds_vmem_budget(ctx1d, rng):
    """A shape whose full [K, tile_n] double-buffered panel exceeds the
    VMEM budget: the tuner must pick tile_k < K (K-panel streaming
    actually exercised) and parity must hold."""
    budget = 96 << 10
    K, N, tile_n = 256, 512, 64
    assert 2 * K * tile_n * 4 > budget        # full-K panels cannot fit
    tk = choose_tile_k(2, K, N, tile_n, n_dev=8, dtype_bytes=4,
                       vmem_budget_bytes=budget)
    assert 1 <= tk < K
    x = rng.standard_normal((2, K)).astype(np.float32)
    w = rng.standard_normal((K, N)).astype(np.float32)
    y = jax.jit(lambda x, w: fused_matmul_allreduce(
        ctx1d, x, w, tile_n=tile_n, vmem_budget_bytes=budget))(x, w)
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# device-initiated fused GEMM + All-to-All kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("comm_aware", [True, False])
def test_fused_gemm_a2a_kernel_matches_bulk(ctx1d, rng, comm_aware):
    B, n_ep, E, C, D, F = 4, 8, 8, 4, 16, 24
    xm = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)
    ref = jax.jit(lambda x: fused_expert_ffn_combine(
        ctx1d, x, wu, wg, wd, act=jax.nn.silu, mode="bulk"))(xm)
    y = jax.jit(lambda x: fused_gemm_a2a(
        ctx1d, x, wu, wg, wd, act=jax.nn.silu, comm_aware=comm_aware))(xm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    yk = jax.jit(lambda x: fused_expert_ffn_combine(
        ctx1d, x, wu, wg, wd, act=jax.nn.silu, mode="kernel"))(xm)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("tile_k,tile_f", [(8, 8), (12, 16), (16, 24)])
def test_gemm_a2a_contraction_panels(ctx1d, rng, tile_k, tile_f):
    """Both chained GEMMs stream their contraction in panels (ragged
    final panel when the tile does not divide D or F)."""
    B, n_ep, E, C, D, F = 4, 8, 8, 4, 16, 24
    xm = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)
    ref = jax.jit(lambda x: fused_expert_ffn_combine(
        ctx1d, x, wu, wg, wd, act=jax.nn.silu, mode="bulk"))(xm)
    y = jax.jit(lambda x: fused_gemm_a2a(
        ctx1d, x, wu, wg, wd, act=jax.nn.silu, tile_k=tile_k,
        tile_f=tile_f))(xm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_granularity_via_fusion_config(ctx, rng):
    """FusionConfig.granularity threads through without per-call args."""
    from repro.parallel.sharding import FusionConfig

    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    ref = np.einsum("bsk,kn->bsn", x, w)
    for gran in [2, "auto"]:
        c2 = ctx.with_fusion(FusionConfig(granularity=gran))
        y = jax.jit(lambda x, w: matmul_allreduce(c2, x, w))(x, w)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# autotuner unit behaviour
# ---------------------------------------------------------------------------
def test_autotune_cache_and_clamp():
    autotune.clear_cache()
    kw = dict(shape=(512, 1024, 2048), dtype_bytes=2, n_dev=8,
              flops=2.0 * 512 * 1024 * 2048, hbm_bytes=1024 * 2048 * 2.0,
              wire_bytes=512 * 2048 * 4.0)
    q1 = choose_chunks_per_rank("matmul_allreduce", **kw)
    assert q1 >= 1
    assert autotune.cache_info()  # memoized
    assert choose_chunks_per_rank("matmul_allreduce", **kw) == q1
    # divisor constraint honored
    q2 = choose_chunks_per_rank("matmul_allreduce",
                                **{**kw, "shape": (1, 2, 3)}, divisor_of=8)
    assert 8 % (8 * q2) == 0 or q2 == 1
    # A2A family: payload is per-destination already, so only q | sub_dim
    # constrains the split — a compute-dominated workload (wire hidable
    # behind GEMMs) must pick q > 1 even when sub_dim == n_dev
    # (regression: the n_dev*q constraint used to collapse candidates
    # to [1])
    a2a = dict(shape=(8, 8), dtype_bytes=4, n_dev=8,
               flops=2e12, hbm_bytes=4e6, wire_bytes=4e7, divisor_of=8)
    qa = choose_chunks_per_rank("all_to_all", **a2a, divisor_ring=1)
    assert qa > 1 and 8 % qa == 0
    # same shape under a different constraint must not share a cache slot
    qb = choose_chunks_per_rank("all_to_all", **a2a, divisor_ring=8)
    assert qb == 1
    autotune.clear_cache()


def test_autotune_cache_roundtrip(tmp_path):
    """TuneKey -> decision -> serialize -> reload -> identical decision."""
    autotune.clear_cache()
    kw = dict(shape=(512, 1024, 2048), dtype_bytes=2, n_dev=8,
              flops=2.0 * 512 * 1024 * 2048, hbm_bytes=1024 * 2048 * 2.0,
              wire_bytes=512 * 2048 * 4.0)
    q1 = choose_chunks_per_rank("matmul_allreduce", **kw)
    q2 = choose_chunks_per_rank("ce_ring", **{**kw, "divisor_of": 64},
                                divisor_ring=1)
    saved = dict(autotune.cache_info())
    path = str(tmp_path / "tune_cache.json")
    assert autotune.save_cache(path) == len(saved)

    autotune.clear_cache()
    assert not autotune.cache_info()
    assert autotune.load_cache(path) == len(saved)
    # decisions come back under the *same* keys (HardwareModel included)
    assert autotune.cache_info() == saved
    assert choose_chunks_per_rank("matmul_allreduce", **kw) == q1
    assert choose_chunks_per_rank("ce_ring", **{**kw, "divisor_of": 64},
                                  divisor_ring=1) == q2
    # a live in-process decision beats a stale file on collision, and
    # colliding entries do not count as loaded
    assert autotune.load_cache(path) == 0
    assert autotune.cache_info() == saved
    # the launcher-side preload treats a truncated/corrupt cache (killed
    # process mid-save) as a cold start, not a crash
    corrupt = str(tmp_path / "corrupt.json")
    with open(corrupt, "w") as f:
        f.write('{"version": 1, "entr')
    autotune.clear_cache()
    assert autotune.load_cache_if_exists(corrupt) == 0
    assert autotune.load_cache_if_exists(None) == 0
    assert not autotune.cache_info()
    autotune.clear_cache()


def test_feasibility_helpers():
    assert feasible_chunks_per_rank(64, 8, 4) == 4
    assert feasible_chunks_per_rank(24, 8, 4) == 3
    assert feasible_chunks_per_rank(8, 8, 16) == 1
    assert feasible_tile(12, 7) == 6
    assert feasible_tile(128, 128) == 128
    assert feasible_tile(12, 100) == 12
    with pytest.raises(ValueError):
        resolve_granularity(0, lambda: 1)
    assert resolve_granularity("auto", lambda: 3) == 3
    assert resolve_granularity(5, lambda: 3) == 5


def test_choose_tile_n_respects_budget():
    # huge K: whole-chunk tile cannot fit a tight budget -> smaller divisor
    tile = choose_tile_n(1, 4096, 8192, n_dev=8, dtype_bytes=4,
                         vmem_budget_bytes=1 << 20)
    bn = 8192 // 8
    assert bn % tile == 0
    assert 2 * 4096 * tile * 4 <= (1 << 20)
    # roomy budget: whole per-rank chunk in one tile
    assert choose_tile_n(1, 64, 512, n_dev=8, dtype_bytes=4) == 64
    # the tile-independent buffers (tx/rx staging ~ 2*n_dev*b*bn) must be
    # costed: with b large enough that they alone bust the budget, the
    # tuner falls to the smallest weight panel instead of claiming bn fits
    assert choose_tile_n(4096, 64, 512, n_dev=8, dtype_bytes=4,
                         vmem_budget_bytes=1 << 20) == 1


def test_choose_tile_k_respects_budget():
    # roomy budget: whole contraction in one panel
    assert choose_tile_k(1, 64, 512, 64, n_dev=8, dtype_bytes=4) == 64
    # a full-depth panel is never rounded down into a ragged tail
    assert choose_tile_k(1, 20, 512, 64, n_dev=8, dtype_bytes=4) == 20
    # tight budget: panels shrink below K, sublane-aligned
    tk = choose_tile_k(2, 4096, 512, 64, n_dev=8, dtype_bytes=4,
                       vmem_budget_bytes=1 << 20)
    assert 1 <= tk < 4096 and tk % 8 == 0
    # panels plus fixed buffers fit the budget
    fixed = (2 * 4096 + 2 * 512 + 7 * 2 * 64 + 8 * 2 * 64) * 4 \
        + 2 * 64 * 4 + 2 * 64 * 4
    assert fixed + 2 * tk * 64 * 4 <= (1 << 20)
    # degenerate budget still returns a positive panel depth
    assert choose_tile_k(2, 4096, 512, 64, n_dev=8, dtype_bytes=4,
                         vmem_budget_bytes=1) == 1


def test_model_fused_beats_bulk_when_overlappable():
    flops, hbm, wire = 2e9, 4e6, 4e6
    b = model_bulk(flops, hbm, wire)
    f = model_fused(flops, hbm, wire, 16)
    assert f < b
    assert V5E.compute_time(flops, hbm) <= b


def test_measured_best_picks_fastest():
    import time

    def build(q):
        def fn():
            time.sleep(0.02 * q)
            return jnp.zeros(())
        return fn

    best, times = measured_best(build, [1, 2, 4], iters=2, warmup=1)
    assert best == 1 and set(times) == {1, 2, 4}


def test_measured_best_falls_back_on_raising_candidates():
    def build_partial(q):
        if q == 1:
            raise RuntimeError("candidate cannot build")

        def fn():
            return jnp.zeros(())
        return fn

    # a raising candidate is excluded, the rest still compete
    best, times = measured_best(build_partial, [1, 2], iters=1, warmup=0,
                                fallback=7)
    assert best == 2 and set(times) == {2}

    def build_none(q):
        raise RuntimeError("no candidate builds")

    # every candidate raising -> the model decision is returned
    best, times = measured_best(build_none, [1, 2, 4], iters=1, warmup=0,
                                fallback=7)
    assert best == 7 and times == {}
    # ... and with no fallback the error propagates
    with pytest.raises(RuntimeError):
        measured_best(build_none, [1, 2], iters=1, warmup=0)
