"""Context/decode attention vs dense reference; ring custom VJP vs autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (cache_update, context_attention,
                                    decode_attention)


def _ref(q, k, v, causal=True, window=None, scale=None, cap=None):
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    scale = scale or hd ** -0.5
    q5 = q.reshape(B, S, Hkv, g, hd)
    s = np.einsum("bqhgd,bkhd->bhgqk", q5, k) * scale
    if cap is not None:
        s = np.tanh(s / cap) * cap
    i, j = np.arange(S)[:, None], np.arange(S)[None, :]
    mask = np.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bkhd->bhgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, hd)


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 24, None), (True, None, 30.0), (False, None, None)])
@pytest.mark.parametrize("mode", ["bulk", "fused"])
def test_context_attention(ctx, rng, causal, window, cap, mode):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    q = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    out = jax.jit(lambda q, k, v: context_attention(
        ctx, q, k, v, causal=causal, window=window, softcap_val=cap,
        mode=mode, q_block=16, kv_block=16))(q, k, v)
    np.testing.assert_allclose(np.asarray(out),
                               _ref(q, k, v, causal, window, cap=cap),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 24, None), (True, None, 30.0)])
def test_ring_attention_vjp(ctx, rng, causal, window, cap):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    q = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    k = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    v = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    co = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)

    def loss(mode):
        return lambda q, k, v: (context_attention(
            ctx, q, k, v, causal=causal, window=window, softcap_val=cap,
            mode=mode, q_block=16, kv_block=16).astype(jnp.float32) * co).sum()

    gf = jax.jit(jax.grad(loss("fused"), argnums=(0, 1, 2)))(q, k, v)
    gb = jax.jit(jax.grad(loss("bulk"), argnums=(0, 1, 2)))(q, k, v)
    for a, b in zip(gf, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_decode_matches_prefix(ctx, rng):
    B, S_max, Hq, Hkv, hd = 4, 64, 8, 2, 16
    pos = 37
    q = rng.standard_normal((B, S_max, Hq, hd)).astype(np.float32)
    k = rng.standard_normal((B, S_max, Hkv, hd)).astype(np.float32)
    v = rng.standard_normal((B, S_max, Hkv, hd)).astype(np.float32)
    kc = np.zeros_like(k)
    vc = np.zeros_like(v)
    kc[:, :pos + 1] = k[:, :pos + 1]
    vc[:, :pos + 1] = v[:, :pos + 1]
    ref = _ref(q[:, :pos + 1], k[:, :pos + 1], v[:, :pos + 1])[:, pos:pos + 1]
    out = jax.jit(lambda q, kc, vc, p: decode_attention(ctx, q, kc, vc, p))(
        q[:, pos:pos + 1], kc, vc, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_cache_update_touches_one_slot(ctx, rng):
    B, S_max, Hkv, hd = 4, 64, 2, 16
    cache = rng.standard_normal((B, S_max, Hkv, hd)).astype(np.float32)
    new = rng.standard_normal((B, 1, Hkv, hd)).astype(np.float32)
    out = jax.jit(lambda c, n, p: cache_update(ctx, c, n, p))(
        cache, new, jnp.int32(41))
    out = np.asarray(out)
    np.testing.assert_allclose(out[:, 41], new[:, 0], rtol=1e-6)
    np.testing.assert_allclose(np.delete(out, 41, 1), np.delete(cache, 41, 1),
                               rtol=1e-6)
