"""Training substrate: optimizer, compression, end-to-end loss decrease."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.data.synthetic import LMBatches
from repro.models.common import split_params
from repro.train.grad_compression import CompressionConfig, compress_decompress, init_residuals
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   adafactor_init, adafactor_update,
                                   clip_by_global_norm, lr_schedule)
from repro.train.step import TrainConfig, build_train_step, init_train_state


def test_lr_schedule():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_schedule(cfg, jnp.int32(100))) <= 1e-3 * cfg.min_lr_ratio + 1e-9


def test_clip():
    g = {"a": jnp.ones((4,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert np.allclose(np.asarray(clipped["a"]), 0.5, rtol=1e-4)


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(opt):
    cfg = OptimizerConfig(name=opt, lr=0.1, warmup_steps=0, total_steps=1000,
                          weight_decay=0.0)
    init, update = (adamw_init, adamw_update) if opt == "adamw" else \
        (adafactor_init, adafactor_update)
    params = {"w": jnp.ones((8, 8)) * 3.0}
    state = init(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(30):
        grads = jax.grad(loss)(params)
        params, state, _ = update(cfg, grads, state, params)
    assert float(loss(params)) < 0.2 * l0


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_error_feedback_unbiased_over_time(scheme):
    """With error feedback, the cumulative applied update converges to the
    cumulative true gradient (residual stays bounded)."""
    cfg = CompressionConfig(scheme=scheme, topk_ratio=0.25)
    g = {"w": jnp.array(np.random.default_rng(0).standard_normal((64,)),
                        jnp.float32)}
    res = init_residuals(cfg, g)
    applied = jnp.zeros((64,))
    for i in range(20):
        out, res = compress_decompress(cfg, g, res)
        applied = applied + out["w"]
    total_true = 20 * g["w"]
    err = float(jnp.abs(applied - total_true).max())
    assert err <= float(jnp.abs(res["w"]).max()) + 1e-3


def test_train_loss_decreases(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    tc = TrainConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                               total_steps=40))
    state = init_train_state(tc, params)
    step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc),
                   donate_argnums=(0,))
    it = LMBatches(bundle.config.vocab, 8, 32, seed=0)
    losses = []
    for i, batch in zip(range(40), it):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::8]


def test_microbatch_equivalence(ctx):
    """Grad accumulation == full-batch step (same update direction)."""
    bundle = get_arch("phi3-medium-14b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    batch = next(LMBatches(bundle.config.vocab, 8, 32, seed=1))
    out = {}
    for mb in [1, 2]:
        tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                                   total_steps=10),
                         microbatches=mb)
        state = init_train_state(tc, params)
        step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc))
        _, metrics = step(state, batch)
        out[mb] = float(metrics["loss"])
    assert abs(out[1] - out[2]) < 5e-3, out
