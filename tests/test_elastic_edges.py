"""Edge-case tests for :mod:`repro.runtime.elastic`: non-prefix survivor
sets (the lost process owned the *first* devices) and batch rescale
under grad accumulation."""
import warnings

import numpy as np
import pytest

from repro.parallel.sharding import ParallelContext
from repro.runtime.elastic import rescale_batch, shrink_context
from repro.compat import make_mesh


@pytest.fixture
def ctx24():
    return ParallelContext.from_mesh(make_mesh((2, 4), ("data", "model")))


class TestShrinkLostDevices:
    def test_default_keeps_prefix(self, ctx24):
        new = shrink_context(ctx24)
        old = np.asarray(ctx24.mesh.devices).reshape(-1)
        kept = np.asarray(new.mesh.devices).reshape(-1)
        assert [d.id for d in kept] == [d.id for d in old[:4]]

    def test_lost_prefix_process_keeps_tail(self, ctx24):
        # The process owning devices 0..3 died: the survivors are the
        # *tail* of the flattened world.  Blindly keeping the prefix
        # would rebuild the mesh around dead hardware.
        new = shrink_context(ctx24, lost=range(0, 4))
        old = np.asarray(ctx24.mesh.devices).reshape(-1)
        kept = np.asarray(new.mesh.devices).reshape(-1)
        assert [d.id for d in kept] == [d.id for d in old[4:]]
        assert dict(new.mesh.shape) == {"data": 1, "model": 4}

    def test_lost_interior_slice(self, ctx24):
        # losing the middle of the world: survivors are 0,1 then 6,7
        new = shrink_context(ctx24, lost=[2, 3, 4, 5])
        old = np.asarray(ctx24.mesh.devices).reshape(-1)
        kept = [d.id for d in np.asarray(new.mesh.devices).reshape(-1)]
        assert kept == [old[0].id, old[1].id, old[6].id, old[7].id]

    def test_lost_out_of_range_raises(self, ctx24):
        with pytest.raises(ValueError, match="outside the flattened world"):
            shrink_context(ctx24, lost=[99])

    def test_too_many_lost_raises(self, ctx24):
        # 6 dead of 8 leaves 2 survivors, but a factor-2 shrink of (2,4)
        # still needs 4 devices.
        with pytest.raises(ValueError, match="survive"):
            shrink_context(ctx24, lost=range(0, 6))

    def test_axes_and_hw_carry_over(self, ctx24):
        new = shrink_context(ctx24, lost=range(0, 4))
        assert new.mesh.axis_names == ctx24.mesh.axis_names
        assert new.hw is ctx24.hw


class TestRescaleBatchMicrobatches:
    def test_clean_rescale_no_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert rescale_batch(16, 4, 2, microbatches=2) == 8

    def test_shrink_below_microbatch_multiple_rounds_up(self):
        # per-device 2, dp 4 -> 1: new batch 2 does not divide into 4
        # microbatches — rounded up to 4 with a loud warning.
        with pytest.warns(RuntimeWarning, match="microbatches"):
            assert rescale_batch(8, 4, 1, microbatches=4) == 4

    def test_shrink_off_multiple_rounds_up(self):
        # batch 12 over dp 4 -> dp 3 gives 9, not a multiple of 2
        with pytest.warns(RuntimeWarning, match="rounding up"):
            assert rescale_batch(12, 4, 3, microbatches=2) == 10

    def test_microbatches_one_never_warns(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert rescale_batch(8, 4, 1, microbatches=1) == 2

    def test_indivisible_global_batch_still_warns(self):
        with pytest.warns(RuntimeWarning, match="does not divide"):
            rescale_batch(4, 8, 4)
