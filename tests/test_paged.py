"""Paged KV + chunked prefill: parity against the dense decode path.

The paged pool, block tables, and per-slot positions must reproduce the
dense cache's attention exactly — same logits, same greedy tokens — for
both the C=1 decode fast path and the chunked-prefill graph.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models.common import split_params
from repro.models.transformer import decode_step, serve_step

BS = 8          # tokens per block
PROMPTS = [[5, 3, 7], [2, 9, 4, 8], [1], [6, 6]]
N_GEN = 5


@pytest.fixture(scope="module")
def setup(ctx_module):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    return bundle, bundle.config, params


@pytest.fixture(scope="module")
def ctx_module(request):
    # module-scoped mirror of the conftest ctx (shared jit caches here)
    from jax.sharding import Mesh

    from repro.parallel.sharding import ParallelContext

    devs = np.array(jax.devices()[:8]).reshape(2, 4)
    return ParallelContext.from_mesh(Mesh(devs, ("data", "model")))


def _dense_reference(ctx, cfg, bundle, params):
    """Greedy generation through decode_step with per-slot positions."""
    B = len(PROMPTS)
    dj = jax.jit(lambda t, c, p: decode_step(ctx, params, cfg, t, c, p))
    cache = bundle.init_cache(B)
    pos = np.zeros(B, np.int32)
    toks = np.array([[p[0]] for p in PROMPTS], np.int32)
    consumed = [1] * B
    out = [[] for _ in range(B)]
    logits_log = []
    for _ in range(max(map(len, PROMPTS)) + N_GEN):
        lg, cache = dj(jnp.asarray(toks), cache, jnp.asarray(pos))
        lg = np.asarray(lg)[:, 0]
        logits_log.append(lg)
        for i in range(B):
            pos[i] += 1
            if consumed[i] < len(PROMPTS[i]):
                toks[i, 0] = PROMPTS[i][consumed[i]]
                consumed[i] += 1
            else:
                out[i].append(int(lg[i].argmax()))
                toks[i, 0] = out[i][-1]
    return out, logits_log


def _tables(cfg, B):
    MB = cfg.max_seq // BS
    return np.array([[i * MB + m for m in range(MB)] for i in range(B)],
                    np.int32), B * MB


def test_paged_decode_matches_dense_logits(ctx_module, setup):
    bundle, cfg, params = setup
    ctx = ctx_module
    B = len(PROMPTS)
    dense_out, dense_logits = _dense_reference(ctx, cfg, bundle, params)
    tables, NB = _tables(cfg, B)
    pool = bundle.init_paged_pool(NB, BS)
    sj = jax.jit(lambda t, pl, tb, p, n: serve_step(
        ctx, params, cfg, t, pl, tb, p, n))
    pos = np.zeros(B, np.int32)
    toks = np.array([[p[0]] for p in PROMPTS], np.int32)
    consumed = [1] * B
    out = [[] for _ in range(B)]
    for step in range(max(map(len, PROMPTS)) + N_GEN):
        lg, pool = sj(jnp.asarray(toks), pool, jnp.asarray(tables),
                      jnp.asarray(pos), jnp.ones(B, np.int32))
        lg = np.asarray(lg)
        np.testing.assert_allclose(lg, dense_logits[step], atol=2e-4)
        for i in range(B):
            pos[i] += 1
            if consumed[i] < len(PROMPTS[i]):
                toks[i, 0] = PROMPTS[i][consumed[i]]
                consumed[i] += 1
            else:
                out[i].append(int(lg[i].argmax()))
                toks[i, 0] = out[i][-1]
    assert out == dense_out


def test_chunked_prefill_matches_dense(ctx_module, setup):
    """One C=4 prefill chunk per prompt, then C=1 decode: the mixed graph
    reproduces the token-by-token dense generation exactly."""
    bundle, cfg, params = setup
    ctx = ctx_module
    B, C = len(PROMPTS), 4
    dense_out, _ = _dense_reference(ctx, cfg, bundle, params)
    tables, NB = _tables(cfg, B)
    pool = bundle.init_paged_pool(NB, BS)
    sj = jax.jit(lambda t, pl, tb, p, n: serve_step(
        ctx, params, cfg, t, pl, tb, p, n))
    tk = np.zeros((B, C), np.int32)
    nn = np.zeros(B, np.int32)
    for i, p in enumerate(PROMPTS):
        tk[i, :len(p)] = p
        nn[i] = len(p)
    lg, pool = sj(jnp.asarray(tk), pool, jnp.asarray(tables),
                  jnp.zeros(B, jnp.int32), jnp.asarray(nn))
    lg = np.asarray(lg)
    out = [[int(lg[i].argmax())] for i in range(B)]
    pos = np.array([len(p) for p in PROMPTS], np.int32)
    toks = np.array([[o[0]] for o in out], np.int32)
    for _ in range(1, N_GEN):
        lg, pool = sj(jnp.asarray(toks), pool, jnp.asarray(tables),
                      jnp.asarray(pos), jnp.ones(B, np.int32))
        lg = np.asarray(lg)
        for i in range(B):
            pos[i] += 1
            out[i].append(int(lg[i].argmax()))
            toks[i, 0] = out[i][-1]
    assert out == [d[:N_GEN] for d in dense_out]


def test_idle_and_sentinel_slots_stay_finite(ctx_module, setup):
    """n_new=0 slots and FREE_BLOCK (-1) tables must neither write the
    pool nor produce non-finite logits (all-masked flash rows)."""
    bundle, cfg, params = setup
    ctx = ctx_module
    B = 4
    tables, NB = _tables(cfg, B)
    tables = tables.copy()
    tables[2] = -1                     # unallocated slot: sentinel table
    pool = bundle.init_paged_pool(NB, BS)
    sj = jax.jit(lambda t, pl, tb, p, n: serve_step(
        ctx, params, cfg, t, pl, tb, p, n))
    before = jax.tree.map(np.asarray, pool)
    lg, pool = sj(jnp.zeros((B, 1), jnp.int32), pool, jnp.asarray(tables),
                  jnp.zeros(B, jnp.int32),
                  jnp.asarray([1, 1, 0, 1], np.int32))
    assert np.isfinite(np.asarray(lg)).all()
    # slot 2's (sentinel) write was dropped: no pool block changed beyond
    # the blocks owned by slots 0, 1, 3
    after = jax.tree.map(np.asarray, pool)
    MB = cfg.max_seq // BS
    owned = {int(b) for i in (0, 1, 3) for b in tables[i][:MB]}
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        changed = {int(i) for i in
                   np.unique(np.argwhere(b != a)[:, 1])} if b.ndim >= 2 else set()
        assert changed <= owned, changed - owned


def test_out_of_table_positions_are_dropped(ctx_module, setup):
    """A position past the table bound (MB * block) must be dropped by
    the scatter, not clamped onto the last block (the dense path's old
    silent-overwrite bug)."""
    bundle, cfg, params = setup
    ctx = ctx_module
    B = 4
    tables, NB = _tables(cfg, B)
    pool = bundle.init_paged_pool(NB, BS)
    sj = jax.jit(lambda t, pl, tb, p, n: serve_step(
        ctx, params, cfg, t, pl, tb, p, n))
    before = jax.tree.map(np.asarray, pool)
    pos = np.full(B, cfg.max_seq, np.int32)   # one past the last slot
    lg, pool = sj(jnp.ones((B, 1), jnp.int32), pool, jnp.asarray(tables),
                  jnp.asarray(pos), jnp.ones(B, np.int32))
    assert np.isfinite(np.asarray(lg)).all()
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(pool)):
        np.testing.assert_array_equal(b, np.asarray(a))
