"""Decode engine: continuous batching drains requests with sane tokens."""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models.common import split_params
from repro.serve.engine import DecodeEngine, PagedDecodeEngine, Request


def test_engine_drains(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 3).tolist(), max_new=5)
            for i in range(6)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run_until_drained(max_steps=60)
    assert len(finished) == 6
    for r in finished:
        assert len(r.tokens) == 5
        assert all(0 <= t < bundle.config.vocab for t in r.tokens)


def test_engine_greedy_determinism(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    outs = []
    for _ in range(2):
        engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=2)
        engine.submit(Request(uid=0, prompt=[1, 2, 3], max_new=6))
        fin = engine.run_until_drained(max_steps=40)
        outs.append(fin[0].tokens)
    assert outs[0] == outs[1]


def _fake_decode(tok, cache, pos):
    """Deterministic meshless decoder: argmax(logits) == (token+1) % 16."""
    b = tok.shape[0]
    logits = jnp.zeros((b, 1, 16))
    logits = logits.at[jnp.arange(b), 0, (tok[:, 0] + 1) % 16].set(1.0)
    return logits, cache


def test_empty_prompt_request_does_not_crash():
    """Regression: admission indexed prompt[0] unconditionally, so an
    empty prompt (unconditional generation) raised IndexError."""
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=2,
                          bos_id=5)
    engine.submit(Request(uid=0, prompt=[], max_new=4))
    engine.submit(Request(uid=1, prompt=[3], max_new=4))
    fin = engine.run_until_drained(max_steps=30)
    assert {r.uid for r in fin} == {0, 1}
    # generation walks from BOS: 5 -> 6, 7, 8, 9
    assert next(r for r in fin if r.uid == 0).tokens == [6, 7, 8, 9]
    assert next(r for r in fin if r.uid == 1).tokens == [4, 5, 6, 7]


def test_queue_is_fifo_and_consumed_is_request_state():
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=1)
    reqs = [Request(uid=i, prompt=[i], max_new=2) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    fin = engine.run_until_drained(max_steps=30)
    # one slot: strictly FIFO completion order
    assert [r.uid for r in fin] == [0, 1, 2]
    # prompt replay bookkeeping lives on the dataclass, not an ad-hoc attr
    assert all(r.consumed == len(r.prefix) for r in fin)
    assert not hasattr(fin[0], "_consumed")


# ---------------------------------------------------------------------------
# slot-reuse correctness (the cross-request KV contamination regression)
# ---------------------------------------------------------------------------
def _pos_aware_decode(tok, cache, pos):
    """Position-aware fake decoder: a [B, 32] cache of written tokens.

    Each step writes tok at the slot's own position and emits
    argmax = (sum of the slot's rows 0..pos) % 16 — so attending over a
    previous occupant's stale rows (the shared-position bug) changes the
    output.  The old ``_fake_decode`` ignored cache and pos entirely,
    which is why the contamination slipped through."""
    b = tok.shape[0]
    p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    cache = cache.at[jnp.arange(b), p].set(tok[:, 0])
    mask = jnp.arange(cache.shape[1])[None, :] <= p[:, None]
    nxt = jnp.where(mask, cache, 0).sum(axis=1) % 16
    logits = jax.nn.one_hot(nxt, 16)[:, None, :]
    return logits, cache


def _pos_cache(b):
    return jnp.zeros((b, 32), jnp.int32)


def test_slot_reuse_same_tokens_as_served_alone():
    """A request admitted into a freed slot must decode exactly as it
    does in a fresh engine — per-slot positions reset the causal window
    to the new request's own rows."""
    alone = DecodeEngine(_pos_aware_decode, _pos_cache, batch_size=1,
                         max_seq=32)
    alone.submit(Request(uid=1, prompt=[7, 2], max_new=5))
    want = alone.run_until_drained(max_steps=40)[0].tokens

    engine = DecodeEngine(_pos_aware_decode, _pos_cache, batch_size=1,
                          max_seq=32)
    # a longer first occupant leaves high-position stale rows behind
    engine.submit(Request(uid=0, prompt=[9, 9, 9, 9], max_new=6))
    engine.submit(Request(uid=1, prompt=[7, 2], max_new=5))
    fin = engine.run_until_drained(max_steps=60)
    assert [r.uid for r in fin] == [0, 1]
    assert fin[1].tokens == want


def test_slot_reuse_bit_identical_real_model(ctx):
    """Acceptance regression on the real reduced model: a request served
    after another retires (reused slot, dirty cache rows) produces
    bit-identical tokens to the same request in a fresh engine — for the
    dense engine and the paged engine both."""
    bundle = get_arch("chatglm3-6b").reduced()
    cfg = bundle.config
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    probe = Request(uid=1, prompt=[11, 3, 5], max_new=6)
    filler = Request(uid=0, prompt=[400, 401, 402, 403, 404], max_new=8)

    decode = bundle.decode_fn(ctx)
    dj = jax.jit(lambda t, c, p: decode(params, t, c, p))

    def dense_engine():
        return DecodeEngine(dj, bundle.init_cache, batch_size=1,
                            max_seq=cfg.max_seq)

    alone = dense_engine()
    alone.submit(dataclasses.replace(probe, tokens=[], prefix=[]))
    want = alone.run_until_drained(max_steps=100)[0].tokens

    reused = dense_engine()
    reused.submit(dataclasses.replace(filler, tokens=[], prefix=[]))
    reused.submit(dataclasses.replace(probe, tokens=[], prefix=[]))
    fin = reused.run_until_drained(max_steps=100)
    assert fin[1].tokens == want, "dense slot reuse changed the decode"

    serve = bundle.serve_step_fn(ctx)
    sj = jax.jit(lambda t, pl, tb, p, n: serve(params, t, pl, tb, p, n))

    def paged_engine():
        return PagedDecodeEngine(sj, bundle.init_paged_pool, batch_size=1,
                                 num_blocks=8, block_size=8,
                                 max_seq=cfg.max_seq, chunk=4,
                                 n_stripes=ctx.tp)

    p_alone = paged_engine()
    p_alone.submit(dataclasses.replace(probe, tokens=[], prefix=[]))
    assert p_alone.run_until_drained(max_steps=100)[0].tokens == want

    p_reused = paged_engine()
    p_reused.submit(dataclasses.replace(filler, tokens=[], prefix=[]))
    p_reused.submit(dataclasses.replace(probe, tokens=[], prefix=[]))
    p_fin = p_reused.run_until_drained(max_steps=100)
    assert p_fin[1].tokens == want, "paged slot reuse changed the decode"


# ---------------------------------------------------------------------------
# satellites: zero-budget requests, cache bound, drain truncation
# ---------------------------------------------------------------------------
def test_max_new_zero_retires_with_no_tokens():
    """A zero-budget request finishes with zero generated tokens (the old
    engine decoded one token before checking the budget)."""
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=2)
    engine.submit(Request(uid=0, prompt=[3], max_new=0))
    engine.submit(Request(uid=1, prompt=[3], max_new=2))
    fin = engine.run_until_drained(max_steps=20)
    z = next(r for r in fin if r.uid == 0)
    assert z.done and z.tokens == []
    assert next(r for r in fin if r.uid == 1).tokens == [4, 5]


def test_cache_bound_retires_truncated_not_overwrites():
    """A slot reaching max_seq retires with truncated=True instead of
    silently rewriting the last cache row forever."""
    engine = DecodeEngine(_pos_aware_decode, _pos_cache, batch_size=1,
                          max_seq=8)
    engine.submit(Request(uid=0, prompt=[1], max_new=100))
    fin = engine.run_until_drained(max_steps=50)
    assert len(fin) == 1 and fin[0].truncated
    # 8 cache writes fit (prompt at 0, generated tokens at 1..7); the
    # final step's logits still yield one more sampled token, so 8 tokens
    # come out and the 9th — which would need a 9th write — never does
    assert len(fin[0].tokens) == 8


def test_run_until_drained_surfaces_truncation(caplog):
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=1)
    for i in range(4):
        engine.submit(Request(uid=i, prompt=[1, 2], max_new=4))
    with caplog.at_level(logging.WARNING, logger="repro.serve"):
        fin = engine.run_until_drained(max_steps=3)
    assert not fin.drained
    assert any("TRUNCATED" in r.message for r in caplog.records)
    # and a clean drain reports drained=True with no warning
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.serve"):
        rest = engine.run_until_drained(max_steps=200)
    assert rest.drained
    assert not any("TRUNCATED" in r.message for r in caplog.records)


def test_paged_engine_ttft_timestamps_and_block_recycling(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    serve = bundle.serve_step_fn(ctx)
    sj = jax.jit(lambda t, pl, tb, p, n: serve(params, t, pl, tb, p, n))
    engine = PagedDecodeEngine(sj, bundle.init_paged_pool, batch_size=2,
                               num_blocks=8, block_size=8,
                               max_seq=bundle.config.max_seq, chunk=4,
                               n_stripes=ctx.tp)
    rng = np.random.default_rng(0)
    for i in range(5):
        engine.submit(Request(uid=i, prompt=rng.integers(0, 64, 3).tolist(),
                              max_new=4))
    fin = engine.run_until_drained(max_steps=200)
    assert fin.drained and len(fin) == 5
    for r in fin:
        assert r.t_submit is not None and r.t_first is not None
        assert r.t_submit <= r.t_first <= r.t_done
    # every retired request returned its blocks
    assert engine.kv.used_blocks == 0
    assert 0 < engine.kv.peak_blocks <= engine.kv.num_blocks
