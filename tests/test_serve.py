"""Decode engine: continuous batching drains requests with sane tokens."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models.common import split_params
from repro.serve.engine import DecodeEngine, Request


def test_engine_drains(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 3).tolist(), max_new=5)
            for i in range(6)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run_until_drained(max_steps=60)
    assert len(finished) == 6
    for r in finished:
        assert len(r.tokens) == 5
        assert all(0 <= t < bundle.config.vocab for t in r.tokens)


def test_engine_greedy_determinism(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    outs = []
    for _ in range(2):
        engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=2)
        engine.submit(Request(uid=0, prompt=[1, 2, 3], max_new=6))
        fin = engine.run_until_drained(max_steps=40)
        outs.append(fin[0].tokens)
    assert outs[0] == outs[1]


def _fake_decode(tok, cache, pos):
    """Deterministic meshless decoder: argmax(logits) == (token+1) % 16."""
    b = tok.shape[0]
    logits = jnp.zeros((b, 1, 16))
    logits = logits.at[jnp.arange(b), 0, (tok[:, 0] + 1) % 16].set(1.0)
    return logits, cache


def test_empty_prompt_request_does_not_crash():
    """Regression: admission indexed prompt[0] unconditionally, so an
    empty prompt (unconditional generation) raised IndexError."""
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=2,
                          bos_id=5)
    engine.submit(Request(uid=0, prompt=[], max_new=4))
    engine.submit(Request(uid=1, prompt=[3], max_new=4))
    fin = engine.run_until_drained(max_steps=30)
    assert {r.uid for r in fin} == {0, 1}
    # generation walks from BOS: 5 -> 6, 7, 8, 9
    assert next(r for r in fin if r.uid == 0).tokens == [6, 7, 8, 9]
    assert next(r for r in fin if r.uid == 1).tokens == [4, 5, 6, 7]


def test_queue_is_fifo_and_consumed_is_request_state():
    engine = DecodeEngine(_fake_decode, lambda b: None, batch_size=1)
    reqs = [Request(uid=i, prompt=[i], max_new=2) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    fin = engine.run_until_drained(max_steps=30)
    # one slot: strictly FIFO completion order
    assert [r.uid for r in fin] == [0, 1, 2]
    # prompt replay bookkeeping lives on the dataclass, not an ad-hoc attr
    assert all(r.consumed == len(r.prefix) for r in fin)
    assert not hasattr(fin[0], "_consumed")
