"""Decode engine: continuous batching drains requests with sane tokens."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models.common import split_params
from repro.serve.engine import DecodeEngine, Request


def test_engine_drains(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 3).tolist(), max_new=5)
            for i in range(6)]
    for r in reqs:
        engine.submit(r)
    finished = engine.run_until_drained(max_steps=60)
    assert len(finished) == 6
    for r in finished:
        assert len(r.tokens) == 5
        assert all(0 <= t < bundle.config.vocab for t in r.tokens)


def test_engine_greedy_determinism(ctx):
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
    outs = []
    for _ in range(2):
        engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=2)
        engine.submit(Request(uid=0, prompt=[1, 2, 3], max_new=6))
        fin = engine.run_until_drained(max_steps=40)
        outs.append(fin[0].tokens)
    assert outs[0] == outs[1]
