"""Chunked recurrences (WKV6 / SSD) == per-step scan references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import ssd_chunked, ssd_step
from repro.models.rwkv6 import wkv6_chunked, wkv6_step


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_wkv6_chunked_vs_step(rng, chunk):
    B, T, H, N = 2, 32, 3, 8
    r = rng.standard_normal((B, T, H, N)).astype(np.float32)
    k = rng.standard_normal((B, T, H, N)).astype(np.float32) * 0.3
    v = rng.standard_normal((B, T, H, N)).astype(np.float32)
    w = np.exp(-np.exp(rng.standard_normal((B, T, H, N)).astype(np.float32)))
    u = rng.standard_normal((H, N)).astype(np.float32) * 0.1
    o_c, S_c = jax.jit(lambda *a: wkv6_chunked(*a, chunk))(
        r, k, v, w, u, jnp.zeros((B, H, N, N)))
    S = jnp.zeros((B, H, N, N))
    outs = []
    for t in range(T):
        o, S = wkv6_step(r[:, t:t + 1], k[:, t:t + 1], v[:, t:t + 1],
                         w[:, t:t + 1], u, S)
        outs.append(np.asarray(o)[:, 0])
    np.testing.assert_allclose(np.asarray(o_c), np.stack(outs, 1),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_ssd_chunked_vs_step(rng, chunk):
    b, T, H, P, N = 2, 32, 3, 8, 4
    x = rng.standard_normal((b, T, H, P)).astype(np.float32)
    dt = np.abs(rng.standard_normal((b, T, H))).astype(np.float32) * 0.5
    A_log = rng.standard_normal(H).astype(np.float32) * 0.3
    B_ = rng.standard_normal((b, T, N)).astype(np.float32)
    C_ = rng.standard_normal((b, T, N)).astype(np.float32)
    y_c, S_c = jax.jit(lambda *a: ssd_chunked(*a, chunk))(
        x, dt, A_log, B_, C_, jnp.zeros((b, H, N, P)))
    S = jnp.zeros((b, H, N, P))
    ys = []
    for t in range(T):
        y, S = ssd_step(x[:, t:t + 1], dt[:, t:t + 1], A_log,
                        B_[:, t:t + 1], C_[:, t:t + 1], S)
        ys.append(np.asarray(y)[:, 0])
    np.testing.assert_allclose(np.asarray(y_c), np.stack(ys, 1),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S),
                               rtol=3e-4, atol=3e-4)
