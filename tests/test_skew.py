"""The skew feedback loop (Fig. 14): telemetry -> bucket -> schedules.

Covers: the StragglerMonitor baseline/flag-rate fixes, the cross-rank
SkewEstimator reduction, the schedule model invariants, skewed-schedule
parity across every fused-op family (fused == reference for skew in
{0, 1, n-1}; bit-identical across buckets for the independent-chain
families), the one-re-jit-per-bucket regression, indivisible sub-chunk
errors, TuneKey skew persistence, and the measured calibration pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.calibrate import measured_calibration_pass
from repro.core.collectives import direct_all_to_all_compute, split_ring_payload
from repro.core.embedding_all_to_all import embedding_all_to_all
from repro.core.loss import sharded_cross_entropy
from repro.core.matmul_allreduce import matmul_allreduce
from repro.core.moe_all_to_all import (fused_expert_ffn_combine,
                                       moe_dispatch_all_to_all)
from repro.core.allgather_matmul import matmul_reducescatter
from repro.core.scheduling import (best_skew_rotation, modeled_execution_skew,
                                   modeled_finish_times, ring_offsets,
                                   skew_statistic, sub_chunk_service_order)
from repro.models.attention import context_attention
from repro.parallel.sharding import FusionConfig
from repro.runtime.straggler import (SkewEstimator, SkewScheduler,
                                     StragglerMonitor)

LINKS = [1.0, 1.0, 1.0, 1.0, 4.0, 1.0, 1.0, 1.0]


# ---------------------------------------------------------------------------
# StragglerMonitor bugfixes
# ---------------------------------------------------------------------------
def test_monitor_baseline_excludes_current_sample():
    # baseline median (excluding the step) is 1.0, so 3.0 must flag; the
    # old median-over-window-including-self was 2.0, masking the outlier
    m = StragglerMonitor(window=20, threshold=1.5, min_baseline=9)
    for t in [1, 1, 1, 1, 1, 3, 3, 3, 3]:
        assert not m.record(t)  # baseline shorter than min_baseline
    assert m.record(3.0)


def test_monitor_flag_rate_decays_on_recovery():
    m = StragglerMonitor(window=10, threshold=1.5, min_baseline=5)
    for _ in range(6):
        m.record(1.0)
    for _ in range(3):
        assert m.record(10.0)
    assert m.flags == 3 and m.flag_rate > 0
    for _ in range(10):  # recovered: flag window fully refreshed
        m.record(1.0)
    assert m.flag_rate == 0.0
    assert m.flags == 3  # cumulative count is history, not state


def test_monitor_summary_has_rate_and_ewma():
    m = StragglerMonitor()
    m.record(1.0)
    s = m.summary()
    assert {"flag_rate", "ewma_s"} <= set(s)


# ---------------------------------------------------------------------------
# schedule model + estimator reduction
# ---------------------------------------------------------------------------
def test_modeled_skew_comm_aware_measured_beats_oblivious():
    times = [1.0] * 8
    times[5] = 1.5
    rot = best_skew_rotation(8, times, link_scale=LINKS)
    s_obl = modeled_execution_skew(8, "oblivious", 0, times, link_scale=LINKS)
    s_aw = modeled_execution_skew(8, "comm_aware", 0, times, link_scale=LINKS)
    s_me = modeled_execution_skew(8, "comm_aware", rot, times,
                                  link_scale=LINKS)
    assert s_me <= s_aw < s_obl
    assert rot != 0  # the measured feed-in actually moved the schedule


def test_best_rotation_uniform_times_is_zero():
    # homogeneous topology + uniform rates: no reason to rotate
    assert best_skew_rotation(8, [1.0] * 8) == 0
    # a slow link alone may justify a rotation, but never a worse one
    r = best_skew_rotation(8, [1.0] * 8, link_scale=LINKS)
    assert modeled_execution_skew(8, "comm_aware", r, [1.0] * 8,
                                  link_scale=LINKS) <= \
        modeled_execution_skew(8, "comm_aware", 0, [1.0] * 8,
                               link_scale=LINKS)


def test_modeled_finish_times_uniform_comm_aware_fully_hidden():
    fin = modeled_finish_times(8, "comm_aware", 0, [1.0] * 8)
    assert skew_statistic(fin) == 0.0  # wire hidden behind compute


def test_sub_chunk_service_order_is_rotation():
    assert sub_chunk_service_order(4, 0) == [0, 1, 2, 3]
    assert sub_chunk_service_order(4, 1) == [1, 2, 3, 0]
    assert sub_chunk_service_order(4, 6) == [2, 3, 0, 1]
    assert sub_chunk_service_order(1, 3) == [0]


def test_estimator_reduces_injected_delay_to_bench_rotation():
    est = SkewEstimator({"ring": 8}, link_scales={"ring": LINKS})
    times = [1.0] * 8
    times[5] = 1.5
    for _ in range(3):
        est.observe(times)
    assert est.rotation("ring") == best_skew_rotation(8, times,
                                                      link_scale=LINKS)
    assert est.axis_skew("ring") == pytest.approx(0.5)


def test_estimator_axis_reduction_on_2d_mesh():
    # mesh (data=2, model=4), flat row-major order; model-position 2 slow
    est = SkewEstimator({"data": 2, "model": 4})
    times = [1.0, 1.0, 1.4, 1.0, 1.0, 1.0, 1.4, 1.0]
    for _ in range(3):
        est.observe(times)
    assert est.axis_skew("model") == pytest.approx(0.4)
    assert est.axis_skew("data") == pytest.approx(0.0)


def test_estimator_rejects_bad_observations():
    est = SkewEstimator({"ring": 4})
    with pytest.raises(ValueError):
        est.observe([1.0, 1.0])  # wrong world
    with pytest.raises(ValueError):
        est.observe([1.0, 1.0, 0.0, 1.0])  # non-positive


# ---------------------------------------------------------------------------
# re-jit regression: exactly one build per bucket change
# ---------------------------------------------------------------------------
def test_skew_scheduler_rebuilds_once_per_bucket():
    est = SkewEstimator({"ring": 8}, link_scales={"ring": LINKS},
                        alpha=1.0, min_obs=1, hysteresis=0.0)
    builds = []

    def build(skew):
        builds.append(skew)
        return lambda: skew

    sched = SkewScheduler(build, est, axis="ring")
    assert sched.fn()() == 0 and builds == [0]
    slow = [1.0] * 8
    slow[5] = 1.5
    changed = sched.observe(slow)
    assert changed and sched.bucket != 0
    b1 = sched.bucket
    assert sched.fn()() == b1
    assert len(builds) == 2  # exactly one re-jit for the new bucket
    # same telemetry again: same bucket, no rebuild
    assert not sched.observe(slow)
    sched.fn()
    assert len(builds) == 2
    # shift the straggler: new bucket, exactly one more re-jit
    slow2 = [1.0] * 8
    slow2[0] = 1.5
    assert sched.observe(slow2)
    b2 = sched.bucket
    assert b2 != b1 and sched.fn()() == b2
    assert len(builds) == 3
    # straggler moves back: previously seen bucket is cached, no rebuild
    assert sched.observe(slow) and sched.bucket == b1
    assert sched.fn()() == b1
    assert len(builds) == 3


# ---------------------------------------------------------------------------
# parity: fused == reference for skew in {0, 1, n-1}, bit-identical across
# buckets for the independent-chain (reduce-scatter / A2A) families
# ---------------------------------------------------------------------------
def _skew_buckets(n):
    return [0, 1, n - 1]


def _assert_buckets(fused_fn, ref, n, *, exact=False, tol=3e-4):
    base = None
    for sk in _skew_buckets(n):
        y = np.asarray(jax.jit(lambda sk=sk: fused_fn(sk))(), np.float32)
        np.testing.assert_allclose(
            y, ref, rtol=tol, atol=tol * max(1.0, float(np.abs(ref).max())))
        if exact:
            base = y if base is None else base
            assert (y == base).all(), "schedule rotation changed the result"


def test_skew_parity_matmul_allreduce(ctx, rng):
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    ref = np.asarray(jax.jit(
        lambda: matmul_allreduce(ctx, x, w, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: matmul_allreduce(
        ctx, x, w, mode="fused", chunks_per_rank=2, skew=sk),
        ref, ctx.tp, exact=True)


def test_skew_parity_matmul_reducescatter(ctx, rng):
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    ref = np.asarray(jax.jit(
        lambda: matmul_reducescatter(ctx, x, w, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: matmul_reducescatter(
        ctx, x, w, mode="fused", chunks_per_rank=2, skew=sk),
        ref, ctx.tp, exact=True)


@pytest.mark.parametrize("schedule", ["comm_aware", "oblivious"])
def test_skew_parity_moe_a2a(ctx, rng, schedule):
    B, n_ep, E, C, D, F = 4, 4, 8, 8, 16, 24
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)
    ref_d = np.asarray(jax.jit(
        lambda: moe_dispatch_all_to_all(ctx, xd, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: moe_dispatch_all_to_all(
        ctx, xd, mode="fused", schedule=schedule, chunks_per_rank=2, skew=sk),
        ref_d, ctx.tp, exact=True)
    ref_c = np.asarray(jax.jit(lambda: fused_expert_ffn_combine(
        ctx, xd, wu, wg, wd, act=jax.nn.silu, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: fused_expert_ffn_combine(
        ctx, xd, wu, wg, wd, act=jax.nn.silu, mode="fused",
        schedule=schedule, chunks_per_rank=2, skew=sk), ref_c, ctx.tp)


def test_skew_parity_allgather_matmul(ctx, rng):
    from repro.core.allgather_matmul import allgather_matmul

    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    ref = np.asarray(jax.jit(
        lambda: allgather_matmul(ctx, x, w, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: allgather_matmul(
        ctx, x, w, mode="fused", chunks_per_rank=2, skew=sk),
        ref, ctx.tp, exact=True)


def test_skew_parity_embedding_a2a(ctx, rng):
    B, T, L, V, D = 16, 8, 4, 32, 8
    idx = rng.integers(0, V, size=(B, T, L)).astype(np.int32)
    tabs = rng.standard_normal((T, V, D)).astype(np.float32)
    ref = np.asarray(jax.jit(lambda: embedding_all_to_all(
        ctx, idx, tabs, mode="bulk"))(), np.float32)
    _assert_buckets(lambda sk: embedding_all_to_all(
        ctx, idx, tabs, mode="fused", chunks_per_rank=2, skew=sk),
        ref, ctx.world, exact=True)


def test_skew_parity_ring_attention(ctx, rng):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    q_ = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    k_ = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    v_ = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)

    def run(mode, sk=0):
        return context_attention(ctx, q_, k_, v_, causal=True, mode=mode,
                                 q_block=16, kv_block=16, chunks_per_rank=2,
                                 skew=sk)

    ref = np.asarray(jax.jit(lambda: run("bulk"))(), np.float32)
    _assert_buckets(lambda sk: run("fused", sk), ref, ctx.tp, tol=2e-3)


def test_skew_parity_ring_attention_grad(ctx, rng):
    B, S, Hq, Hkv, hd = 4, 64, 8, 2, 16
    qq = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)
    kk = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    vv = rng.standard_normal((B, S, Hkv, hd)).astype(np.float32)
    co = rng.standard_normal((B, S, Hq, hd)).astype(np.float32)

    def loss(mode, sk=0):
        return lambda q_, k_, v_: (context_attention(
            ctx, q_, k_, v_, causal=True, mode=mode, q_block=16, kv_block=16,
            chunks_per_rank=2, skew=sk).astype(jnp.float32) * co).sum()

    gb = jax.jit(jax.grad(loss("bulk"), argnums=(0, 1, 2)))(qq, kk, vv)
    gf = jax.jit(jax.grad(loss("fused", ctx.tp - 1),
                          argnums=(0, 1, 2)))(qq, kk, vv)
    for a, b in zip(gf, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_skew_parity_ce_loss(ctx, rng):
    B, S, D, V = 4, 16, 32, 64
    x = rng.standard_normal((B, S, D)).astype(np.float32)
    e = rng.standard_normal((V, D)).astype(np.float32)
    y = rng.integers(0, V, (B, S)).astype(np.int32)
    ref = np.asarray(jax.jit(lambda: sharded_cross_entropy(
        ctx, x, e, y, chunks_per_rank=2, skew=0))())
    for sk in _skew_buckets(ctx.tp):
        loss = np.asarray(jax.jit(lambda sk=sk: sharded_cross_entropy(
            ctx, x, e, y, chunks_per_rank=2, skew=sk))())
        # fwd stats land in disjoint slots: bit-identical under rotation
        assert loss == ref
        g = jax.jit(jax.grad(lambda x, e, sk=sk: sharded_cross_entropy(
            ctx, x, e, y, chunks_per_rank=2, skew=sk), argnums=(0, 1)))(x, e)
        gr = jax.jit(jax.grad(lambda x, e: sharded_cross_entropy(
            ctx, x, e, y, chunks_per_rank=2, skew=0), argnums=(0, 1)))(x, e)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)


def test_fusion_config_skew_drives_every_op(ctx, rng):
    """ctx.fusion.skew is the default skew for every tp-ring fused op,
    ctx.fusion.skew_world for the flattened-world embedding A2A."""
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    c2 = ctx.with_fusion(FusionConfig(granularity=2, skew=1))
    y_cfg = jax.jit(lambda: matmul_allreduce(c2, x, w, mode="fused"))()
    y_arg = jax.jit(lambda: matmul_allreduce(
        ctx, x, w, mode="fused", chunks_per_rank=2, skew=1))()
    assert (np.asarray(y_cfg) == np.asarray(y_arg)).all()

    B, T, L, V, D = 16, 8, 4, 32, 8
    idx = rng.integers(0, V, size=(B, T, L)).astype(np.int32)
    tabs = rng.standard_normal((T, V, D)).astype(np.float32)
    c3 = ctx.with_fusion(FusionConfig(granularity=2, skew_world=3))
    ye_cfg = jax.jit(lambda: embedding_all_to_all(c3, idx, tabs,
                                                  mode="fused"))()
    ye_arg = jax.jit(lambda: embedding_all_to_all(
        ctx, idx, tabs, mode="fused", chunks_per_rank=2, skew=3))()
    assert (np.asarray(ye_cfg) == np.asarray(ye_arg)).all()


# ---------------------------------------------------------------------------
# indivisible sub-chunking must raise, not truncate
# ---------------------------------------------------------------------------
def test_split_ring_payload_raises_on_indivisible():
    with pytest.raises(ValueError, match="does not divide"):
        split_ring_payload(jnp.zeros((2, 9)), 2)


def test_direct_a2a_raises_on_indivisible_sub_chunking(ctx1d):
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    def local_fn(xl):
        return direct_all_to_all_compute(
            lambda f: xl[0], jax.ShapeDtypeStruct((9,), jnp.float32),
            "model", chunks_per_rank=2, sub_axis=0)

    x = jnp.zeros((8, 9), jnp.float32)
    with pytest.raises(ValueError, match="does not divide"):
        jax.jit(shard_map(local_fn, mesh=ctx1d.mesh,
                          in_specs=(P("model", None),),
                          out_specs=P("model", None),
                          check_vma=False))(x)


# ---------------------------------------------------------------------------
# TuneKey skew bucket: keying + persistence
# ---------------------------------------------------------------------------
def test_tunekey_skew_separates_decisions(tmp_path):
    autotune.clear_cache()
    kw = dict(shape=(8, 8), dtype_bytes=4, n_dev=4, flops=1e6,
              hbm_bytes=1e3, wire_bytes=1e3, divisor_of=16)
    q0 = autotune.choose_chunks_per_rank("op_a", skew=0, **kw)
    autotune.choose_chunks_per_rank("op_a", skew=2, **kw)
    keys = list(autotune.cache_info())
    assert {k.skew for k in keys} == {0, 2}

    path = str(tmp_path / "cache.json")
    autotune.save_cache(path)
    autotune.clear_cache()
    assert autotune.load_cache(path) == 2
    assert {k.skew for k in autotune.cache_info()} == {0, 2}
    assert autotune.choose_chunks_per_rank("op_a", skew=0, **kw) == q0
    autotune.clear_cache()


def test_load_cache_defaults_skew_for_legacy_entries(tmp_path):
    import json

    autotune.clear_cache()
    autotune.choose_chunks_per_rank(
        "op_b", shape=(4,), dtype_bytes=4, n_dev=4, flops=1e6,
        hbm_bytes=1e3, wire_bytes=1e3)
    path = str(tmp_path / "legacy.json")
    autotune.save_cache(path)
    with open(path) as f:
        blob = json.load(f)
    for e in blob["entries"]:  # a cache written before the skew field
        del e["key"]["skew"]
    with open(path, "w") as f:
        json.dump(blob, f)
    autotune.clear_cache()
    assert autotune.load_cache(path) == 1
    assert all(k.skew == 0 for k in autotune.cache_info())
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# measured calibration pass
# ---------------------------------------------------------------------------
def test_measured_calibration_overwrites_hot_keys(ctx, rng):
    autotune.clear_cache()
    c2 = ctx.with_fusion(FusionConfig(granularity="auto"))
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    jax.eval_shape(lambda: matmul_allreduce(c2, x, w, mode="fused"))
    hot = list(autotune.cache_info())
    assert len(hot) == 1
    rep = measured_calibration_pass(c2, iters=1, warmup=1, max_q=2)
    (key,) = hot
    assert key in rep
    assert rep[key]["model_q"] == autotune.cache_info()[key] or \
        rep[key]["measured_q"] == autotune.cache_info()[key]
    assert autotune.cache_info()[key] in autotune.calibration_candidates(key, 2)
    # the measured winner must itself pass parity
    y = jax.jit(lambda: matmul_allreduce(c2, x, w, mode="fused"))()
    ref = jax.jit(lambda: matmul_allreduce(c2, x, w, mode="bulk"))()
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)
    autotune.clear_cache()


def test_calibration_skips_foreign_worlds(ctx):
    autotune.clear_cache()
    autotune.choose_chunks_per_rank(
        "matmul_allreduce", shape=(64, 32, 64), dtype_bytes=4, n_dev=64,
        flops=1e9, hbm_bytes=1e6, wire_bytes=1e6, divisor_of=64)
    rep = measured_calibration_pass(ctx, iters=1)
    assert rep == {}  # 64-rank key cannot run on the 8-device mesh
    autotune.clear_cache()


def test_supervisor_swaps_step_on_bucket_change(tmp_path):
    from repro.runtime.fault_tolerance import (SupervisorConfig,
                                               TrainSupervisor)

    est = SkewEstimator({"ring": 8}, link_scales={"ring": LINKS},
                        alpha=1.0, min_obs=1)
    ran_with = []

    def build(skew):
        def step(state, batch):
            ran_with.append(skew)
            return state, {"loss": jnp.float32(0.0)}
        return step

    sched = SkewScheduler(build, est, axis="ring")
    slow = [1.0] * 8
    slow[5] = 1.5
    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path / "ckpt"),
                         checkpoint_every=100, async_save=False),
        step_fn=None, skew_scheduler=sched,
        per_rank_times=lambda dt: slow)
    _, step = sup.run({"x": jnp.zeros(())}, iter([{}] * 4), 4)
    assert step == 4
    assert sched.bucket != 0
    assert sched.rebuilds == 2  # bucket 0 at start + one change, no churn
    # telemetry swapped the supervisor onto the re-jitted schedule
    assert ran_with[0] == 0 and ran_with[-1] == sched.bucket


def test_ring_offsets_skew_executes_what_model_says():
    # the executed A2A destination order is exactly ring_offsets(...)
    # (the deeper executed-order property lives in test_property.py)
    for skew in range(6):
        offs = ring_offsets(8, "comm_aware", skew)
        assert sorted(offs) == list(range(8)) and offs[-1] == 0
