"""Wire-dtype compression: helpers, cache compat, kernels, telemetry.

The cross-op numeric story (bit-identity at wire="f32", bounded error at
bf16/fp8, per-axis "auto") lives in ``test_parity_matrix.py``; this file
covers the plumbing around it — the cast helpers, the tune-cache's
backward compatibility with pre-wire (and pre-``MeshHardwareModel``)
serializations, the Pallas kernel PUT paths, the joint (q, wire)
calibration sweep, and the multi-host straggler-telemetry provider.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core.autotune import Decision, TuneKey
from repro.core.collectives import (FP8_MAX, wire_cast, wire_itemsize,
                                    wire_uncast)
from repro.core.perfmodel import DCN, V5E, MeshHardwareModel, resolve_hw


# ---------------------------------------------------------------------------
# cast helpers
# ---------------------------------------------------------------------------
def test_wire_cast_passthrough_identity():
    x = jnp.arange(8, dtype=jnp.float32)
    assert wire_cast(x, "f32") is x
    xb = x.astype(jnp.bfloat16)
    # never widen: a bf16 payload under a bf16 wire is untouched
    assert wire_cast(xb, "bf16") is xb
    # integer payloads stay exact under any wire
    xi = jnp.arange(8, dtype=jnp.int32)
    assert wire_cast(xi, "fp8") is xi


def test_wire_cast_bf16_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64),
                    jnp.float32)
    p = wire_cast(x, "bf16")
    assert p.dtype == jnp.bfloat16
    y = wire_uncast(p, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=8e-3)


def test_wire_cast_fp8_scale_rides_alongside():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64) * 100,
                    jnp.float32)
    p = wire_cast(x, "fp8")
    assert isinstance(p, tuple)
    q, scale = p
    assert q.dtype == jnp.float8_e4m3fn and scale.shape == (1,)
    # per-chunk max-abs scaling: the largest value maps to the fp8 max
    np.testing.assert_allclose(float(scale[0]),
                               float(jnp.abs(x).max()) / FP8_MAX, rtol=1e-6)
    y = wire_uncast(p, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=0.13,
                               atol=0.13 * float(jnp.abs(x).max()))


def test_wire_cast_rejects_unknown():
    with pytest.raises(ValueError, match="unknown wire dtype"):
        wire_cast(jnp.zeros(4), "int4")


def test_wire_itemsize_never_widens():
    assert wire_itemsize("f32", 4) == 4
    assert wire_itemsize("bf16", 4) == 2
    assert wire_itemsize("bf16", 2) == 2
    assert wire_itemsize("fp8", 2) == 1
    assert wire_itemsize("fp8", 1) == 1


# ---------------------------------------------------------------------------
# hierarchical hardware model
# ---------------------------------------------------------------------------
def test_mesh_hardware_model_per_axis_and_bottleneck():
    hw = MeshHardwareModel.for_mesh_axes(("pod", "data", "model"))
    assert hw.axis("pod").ici_bw == DCN.ici_bw
    assert hw.axis("model").ici_bw == V5E.ici_bw
    assert hw.axis("never_heard_of") == V5E
    # a world ring crossing every axis is governed by the slowest link
    world = hw.for_axes(("pod", "data", "model"))
    assert world.ici_bw == DCN.ici_bw
    assert world.ici_lat == max(DCN.ici_lat, V5E.ici_lat)
    # fp8 on the composite requires every crossed link class to take it
    hw8 = MeshHardwareModel.from_mapping(
        {"pod": dataclasses.replace(DCN, fp8_wire=True)},
        default=dataclasses.replace(V5E, fp8_wire=True))
    assert hw8.for_axes(("pod", "model")).fp8_wire
    assert not hw.for_axes(("pod", "model")).fp8_wire


def test_resolve_hw_accepts_flat_and_hierarchical():
    assert resolve_hw(V5E, "anything") == V5E
    hw = MeshHardwareModel.for_mesh_axes(("pod", "model"))
    assert resolve_hw(hw, "pod") == DCN
    assert resolve_hw(hw, None) == V5E


def test_parallel_context_carries_mesh_hw(ctx):
    # the (data, model) host mesh has no pod axis: every ring sees ICI
    assert ctx.hw_for("model") == V5E
    assert ctx.hw_for(("data", "model")).ici_bw == V5E.ici_bw


# ---------------------------------------------------------------------------
# tune-cache compat: pre-wire and pre-MeshHardwareModel serializations
# ---------------------------------------------------------------------------
def test_cache_roundtrip_preserves_wire_decision(tmp_path):
    autotune.clear_cache()
    kw = dict(shape=(512, 1024, 2048), dtype_bytes=4, n_dev=8,
              flops=2e11, hbm_bytes=1e7, wire_bytes=4e8)
    dec = autotune.choose_overlap("op_w", **kw, hw=DCN, wire="auto")
    assert dec.wire == "bf16"  # slow axis: compression pays
    path = str(tmp_path / "cache.json")
    autotune.save_cache(path)
    autotune.clear_cache()
    assert autotune.load_cache(path) == 1
    (key,) = autotune.cache_info()
    assert key.wire == "auto"
    assert autotune.cache_info()[key] == dec
    # the reloaded entry is served for the same request
    assert autotune.choose_overlap("op_w", **kw, hw=DCN, wire="auto") == dec
    autotune.clear_cache()


def test_legacy_cache_without_wire_loads_with_defaults(tmp_path):
    """A cache serialized before TuneKey.wire / the Decision wire field /
    HardwareModel.fp8_wire existed (the PR 3 'skew' pattern) must load
    with defaults instead of raising — including a foreign hw field this
    build does not know."""
    autotune.clear_cache()
    q = autotune.choose_chunks_per_rank(
        "op_legacy", shape=(64, 64), dtype_bytes=4, n_dev=8, flops=1e9,
        hbm_bytes=1e6, wire_bytes=1e6, divisor_of=64)
    path = str(tmp_path / "legacy.json")
    autotune.save_cache(path)
    with open(path) as f:
        blob = json.load(f)
    for e in blob["entries"]:
        del e["key"]["wire"]          # pre-wire key
        del e["key"]["fixed_q"]       # pre-wire pinned-q field
        del e["wire"]                 # pre-wire decision value
        del e["key"]["hw"]["fp8_wire"]  # flat pre-MeshHardwareModel dict
        e["key"]["hw"]["nvlink_bw"] = 1e12  # foreign field: dropped
    with open(path, "w") as f:
        json.dump(blob, f)
    autotune.clear_cache()
    assert autotune.load_cache(path) == 1
    (key,) = autotune.cache_info()
    assert key.wire == "f32" and key.hw == V5E
    assert autotune.cache_info()[key] == Decision(q, "f32")
    # the defaulted entry is a hit for the pre-wire call signature
    assert autotune.choose_chunks_per_rank(
        "op_legacy", shape=(64, 64), dtype_bytes=4, n_dev=8, flops=1e9,
        hbm_bytes=1e6, wire_bytes=1e6, divisor_of=64) == q
    autotune.clear_cache()


def test_pinned_q_decisions_do_not_collide():
    """A pinned chunks_per_rank under a wire-only sweep keys its own
    cache slot: pins of different values (and the free sweep) must not
    answer for each other (regression: fixed_q used to be absent from
    TuneKey, so the second pinned call returned the first pin's q)."""
    autotune.clear_cache()
    kw = dict(shape=(512, 1024, 2048), dtype_bytes=4, n_dev=8,
              flops=2e11, hbm_bytes=1e7, wire_bytes=4e8, divisor_of=512,
              hw=DCN, wire="auto")
    d2 = autotune.choose_overlap("op_pin", **kw, fixed_q=2)
    d4 = autotune.choose_overlap("op_pin", **kw, fixed_q=4)
    free = autotune.choose_overlap("op_pin", **kw)
    assert d2.q == 2 and d4.q == 4
    assert free == autotune.choose_overlap("op_pin", **kw)  # own slot
    # a pinned key's calibration ladder keeps the pin, sweeping only wire
    key2 = next(k for k in autotune.cache_info() if k.fixed_q == 2)
    assert {d.q for d in autotune.calibration_candidates(key2)} == {2}
    autotune.clear_cache()


def test_ring_all_gather_compute_wire():
    """The generic AG-consume combinator honors the wire knob: exact at
    f32, bounded error at bf16/fp8 (the forwarded shard rounds once)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core.collectives import ring_all_gather_compute

    mesh = make_mesh((8,), ("model",))
    x = np.random.default_rng(0).standard_normal((8, 4)).astype(np.float32)

    def run(wire):
        def local(xl):
            def consume(src, shard, acc):
                return acc + shard.astype(jnp.float32).sum()

            return ring_all_gather_compute(
                xl, consume, "model", out_init=jnp.float32(0.0),
                wire=wire)[None]

        return float(shard_map(local, mesh=mesh, in_specs=(P("model"),),
                               out_specs=P("model"), check_vma=False)(
                                   jnp.asarray(x))[0])

    exact = float(x.sum())
    assert run("f32") == pytest.approx(exact, rel=1e-6)
    assert run("bf16") == pytest.approx(exact, rel=2e-2, abs=2e-2)
    assert run("fp8") == pytest.approx(exact, rel=2e-1, abs=2e-1)


def test_calibration_candidates_cover_wire_ladder():
    key = TuneKey("matmul_allreduce", (8, 8, 8), 4, 8, 64, 8,
                  dataclasses.replace(DCN, fp8_wire=True), 0, "auto")
    cands = autotune.calibration_candidates(key, 2)
    assert set(cands) == {Decision(1, "f32"), Decision(2, "f32"),
                          Decision(1, "bf16"), Decision(2, "bf16"),
                          Decision(1, "fp8"), Decision(2, "fp8")}
    pinned = dataclasses.replace(key, wire="bf16")
    assert set(autotune.calibration_candidates(pinned, 2)) == {
        Decision(1, "bf16"), Decision(2, "bf16")}


def test_measured_calibration_sweeps_wire_jointly(ctx, rng):
    """A hot key recorded under wire='auto' is re-scored over the joint
    (q, wire) ladder and the measured winner lands in the cache."""
    from repro.core.calibrate import measured_calibration_pass
    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.parallel.sharding import FusionConfig

    autotune.clear_cache()
    c2 = ctx.with_fusion(FusionConfig(granularity="auto", wire="auto"))
    x = rng.standard_normal((4, 16, 32)).astype(np.float32)
    w = rng.standard_normal((32, 64)).astype(np.float32)
    jax.eval_shape(lambda: matmul_allreduce(c2, x, w, mode="fused"))
    hot = list(autotune.cache_info())
    assert len(hot) == 1 and hot[0].wire == "auto"
    rep = measured_calibration_pass(c2, iters=1, warmup=1, max_q=2)
    (key,) = hot
    assert key in rep
    winner = autotune.cache_info()[key]
    assert isinstance(winner, Decision)
    assert winner in autotune.calibration_candidates(key, 2)
    # measured times exist for both wire dtypes of the auto ladder
    assert {d.wire for d in rep[key]["times"]} >= {"f32", "bf16"}
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# Pallas kernel PUT paths (interpret mode, 1-D mesh)
# ---------------------------------------------------------------------------
def test_gemv_allreduce_kernel_bf16_wire(ctx1d, rng):
    from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce

    x = rng.standard_normal((4, 64)).astype(np.float32)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    ref = x @ w
    y32 = np.asarray(fused_matmul_allreduce(ctx1d, x, w, wire="f32"))
    yb = np.asarray(fused_matmul_allreduce(ctx1d, x, w, wire="bf16"))
    np.testing.assert_allclose(y32, ref, rtol=3e-4, atol=3e-4)
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(yb, ref, rtol=3e-2, atol=3e-2 * scale)
    # fp8 is an XLA-path format: the shard wrapper clamps it to bf16
    y8 = np.asarray(fused_matmul_allreduce(ctx1d, x, w, wire="fp8"))
    np.testing.assert_allclose(y8, yb)


def test_gemm_a2a_kernel_bf16_wire(ctx1d, rng):
    from repro.core.fused import fused_expert_ffn_combine
    from repro.kernels.fused_gemm_a2a.ops import fused_gemm_a2a

    B, n_ep, E, C, D, F = 2, 8, 8, 4, 16, 24
    xd = rng.standard_normal((B, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)
    ref = np.asarray(jax.jit(lambda: fused_expert_ffn_combine(
        ctx1d, xd, wu, wg, wd, act=jax.nn.silu, mode="bulk"))())
    yb = np.asarray(fused_gemm_a2a(ctx1d, xd, wu, wg, wd, act=jax.nn.silu,
                                   wire="bf16"))
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(yb, ref, rtol=3e-2, atol=3e-2 * scale)


def test_kernel_rejects_fp8_wire():
    from repro.kernels.fused_gemv_allreduce.kernel import (
        fused_matmul_allreduce_pallas)

    with pytest.raises(ValueError, match="f32.*bf16|bf16.*f32"):
        fused_matmul_allreduce_pallas(
            jnp.zeros((2, 8)), jnp.zeros((8, 8)), jnp.int32(0), n_dev=1,
            axis_name="model", wire="fp8")


# ---------------------------------------------------------------------------
# multi-host telemetry provider (ROADMAP leftover)
# ---------------------------------------------------------------------------
def test_process_telemetry_single_process_replicates_ewma():
    from repro.runtime.straggler import ProcessTelemetry, StragglerMonitor

    mon = StragglerMonitor()
    mon.record(0.1)
    mon.record(0.2)
    pt = ProcessTelemetry(mon, world=8)
    times = pt(0.5)
    assert len(times) == 8 and len(set(times)) == 1
    assert times[0] == pytest.approx(mon.ewma)


def test_process_telemetry_spreads_process_gather_over_devices():
    from repro.runtime.straggler import ProcessTelemetry, StragglerMonitor

    mon = StragglerMonitor()
    mon.record(0.1)
    # injected gather: two processes, the second 2x slower
    pt = ProcessTelemetry(mon, world=8, allgather=lambda t: [t, 2 * t])
    times = pt(0.1)
    assert times == [0.1] * 4 + [0.2] * 4
    bad = ProcessTelemetry(mon, world=8, allgather=lambda t: [t] * 3)
    with pytest.raises(ValueError, match="process multiple"):
        bad(0.1)


def test_process_telemetry_falls_back_to_dt_before_first_sample():
    from repro.runtime.straggler import ProcessTelemetry, StragglerMonitor

    pt = ProcessTelemetry(StragglerMonitor(), world=4)
    assert pt(0.25) == [0.25] * 4


def test_supervisor_process_sentinel_installs_provider(tmp_path):
    from repro.runtime.fault_tolerance import (SupervisorConfig,
                                               TrainSupervisor)
    from repro.runtime.straggler import (ProcessTelemetry, SkewEstimator,
                                         SkewScheduler)

    est = SkewEstimator({"ring": 8}, link_scales={"ring": [1.0] * 8})
    sched = SkewScheduler(lambda s: (lambda state, batch: (state, batch)),
                          est, axis="ring")
    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(tmp_path)),
        step_fn=None, skew_scheduler=sched, per_rank_times="process")
    assert isinstance(sup.per_rank_times, ProcessTelemetry)
    # the provider reads the supervisor's own monitor
    assert sup.per_rank_times.monitor is sup.straggler
    assert sup.per_rank_times.world == 8
    sup.straggler.record(0.125)
    sup._feed_skew(0.125)
    assert est.ewma == [0.125] * 8
    with pytest.raises(ValueError, match="skew_scheduler"):
        TrainSupervisor(SupervisorConfig(checkpoint_dir=str(tmp_path)),
                        step_fn=None, per_rank_times="process")
