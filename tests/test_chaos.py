"""Chaos scenarios: seeded fault injection through the real recovery loop.

Every test here is deterministic (seeded plans, seeded data) and marked
``chaos`` so CI re-runs the lane in isolation.  The headline scenarios
pin the acceptance semantics: a transient ring fault or NaN wire payload
recovers to a final state *bit-identical* to the fault-free run (restore
+ batch replay reruns the identical trace on identical state), and a
permanent rank loss completes through the elastic shrink (allclose — the
smaller mesh re-partitions the reductions, so bit-identity is out of
scope there).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.degrade import (DegradationPolicy, DegradeConfig,
                                degrade_mode, set_degradation_policy)
from repro.core.matmul_allreduce import matmul_allreduce
from repro.runtime.chaos import (CollectiveTimeout, FaultEvent, FaultPlan,
                                 RankLost, parse_chaos_spec, wire_faults)
from repro.runtime.elastic import reshard_tree, shrink_context
from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor

pytestmark = pytest.mark.chaos

B, S, K = 2, 8, 16


def _batches(n, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        yield (rng.standard_normal((B, S, K)) * 0.1).astype(np.float32)


def _w0():
    return {"w": (np.random.default_rng(1).standard_normal((K, K))
                  * 0.1).astype(np.float32)}


def _builder(ctx):
    """Zero-arg step factory: a fresh closure per call, so every build
    re-traces (required for the trace-time chaos/degrade hooks)."""
    def build():
        def raw(state, batch):
            y = matmul_allreduce(ctx, batch, state["w"])
            g = jnp.einsum("bsk,bsn->kn", batch, jnp.tanh(y))
            return ({"w": state["w"] - 0.01 * g},
                    {"loss": jnp.mean(y * y)})

        return jax.jit(raw)

    return build


def _supervisor(ckpt_dir, build, **kw):
    return TrainSupervisor(
        SupervisorConfig(checkpoint_dir=str(ckpt_dir), checkpoint_every=3,
                         keep=3, max_restarts=8, async_save=False,
                         backoff_base_s=1e-4, backoff_max_s=1e-3),
        build(), rebuild_step=build, sleep_fn=lambda s: None, **kw)


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------
def test_fault_plan_seeded_determinism():
    a = FaultPlan.from_rate(3, 0.3, 100,
                            kinds=("timeout", "slow_link", "nan_wire"))
    b = FaultPlan.from_rate(3, 0.3, 100,
                            kinds=("timeout", "slow_link", "nan_wire"))
    assert a.events == b.events and len(a) > 0
    c = FaultPlan.from_rate(4, 0.3, 100,
                            kinds=("timeout", "slow_link", "nan_wire"))
    assert c.events != a.events  # a different seed moves the schedule
    assert all(e.kind != "rank_loss" for e in a.events)


def test_parse_chaos_spec_forms():
    p = parse_chaos_spec("rate=0.2,seed=5,kinds=timeout+nan_wire,delay=0.5",
                         num_steps=50)
    assert p.seed == 5 and len(p) > 0
    assert {e.kind for e in p.events} <= {"timeout", "nan_wire"}
    q = parse_chaos_spec("at=7:timeout+20:nan_wire+40:rank_loss",
                         num_steps=50)
    assert q.at(7)[0].kind == "timeout"
    assert q.at(20)[0].kind == "nan_wire"
    assert q.at(40)[0].kind == "rank_loss"
    assert q.at(8) == ()
    with pytest.raises(ValueError):
        parse_chaos_spec("delay=0.1", num_steps=10)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="meteor_strike")


# ---------------------------------------------------------------------------
# wire-fault injection at the collectives boundary
# ---------------------------------------------------------------------------
def test_wire_fault_poisons_fused_ring(ctx, rng):
    x = (rng.standard_normal((B, S, K)) * 0.1).astype(np.float32)
    w = (rng.standard_normal((K, K)) * 0.1).astype(np.float32)
    clean = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w))(x, w)
    assert np.isfinite(np.asarray(clean)).all()
    with wire_faults(nth_send=0) as inj:
        # the fresh jit inside the context is what bakes the fault in
        poisoned = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w))(x, w)
        assert inj.fired
    assert np.isnan(np.asarray(poisoned)).any()
    # hook removal restores clean traces (and the trace cache was never
    # poisoned for this fresh closure)
    clean2 = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w))(x, w)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(clean2))


# ---------------------------------------------------------------------------
# headline scenarios: recover to the fault-free state
# ---------------------------------------------------------------------------
def _run(ckpt_dir, ctx, plan=None, num_steps=8, **kw):
    sup = _supervisor(ckpt_dir, _builder(ctx), fault_plan=plan, **kw)
    state, step = sup.run(_w0(), _batches(num_steps), num_steps)
    return np.asarray(state["w"]), step, sup


def test_transient_fault_bit_identity(tmp_path, ctx):
    w_clean, step, _ = _run(tmp_path / "clean", ctx)
    assert step == 8
    plan = FaultPlan([FaultEvent(step=4, kind="timeout"),
                      FaultEvent(step=6, kind="slow_link", delay_s=0.0),
                      FaultEvent(step=6, kind="rank_fail")])
    w_chaos, step, sup = _run(tmp_path / "chaos", ctx, plan)
    assert step == 8
    assert sup.restarts == 2 and sup.faults_injected == 3
    np.testing.assert_array_equal(w_clean, w_chaos)


def test_nan_wire_bit_identity(tmp_path, ctx):
    w_clean, _, _ = _run(tmp_path / "clean", ctx)
    plan = FaultPlan([FaultEvent(step=5, kind="nan_wire", nth_send=0)])
    w_chaos, step, sup = _run(tmp_path / "chaos", ctx, plan)
    assert step == 8
    # the poisoned trace really produced a NaN loss -> NonFiniteLoss ->
    # restore; the poisoned state was never checkpointed
    assert sup.restarts == 1
    np.testing.assert_array_equal(w_clean, w_chaos)
    assert np.isfinite(w_chaos).all()


def test_rank_loss_elastic_shrink(tmp_path, ctx):
    w_clean, _, _ = _run(tmp_path / "clean", ctx)
    cur = {"ctx": ctx}

    def on_rank_loss(state, exc):
        assert isinstance(exc, RankLost) and exc.rank == 3
        cur["ctx"] = shrink_context(cur["ctx"])
        state, _ = reshard_tree(state, {"w": (None, None)}, cur["ctx"])
        return state, _builder(cur["ctx"])()

    plan = FaultPlan([FaultEvent(step=5, kind="rank_loss", rank=3)])
    sup = _supervisor(tmp_path / "chaos", _builder(ctx), fault_plan=plan,
                      on_rank_loss=on_rank_loss)
    state, step = sup.run(_w0(), _batches(8), 8)
    assert step == 8 and sup.rank_losses == 1
    # the dp axis halved; the survivors carried the job to completion
    assert cur["ctx"].mesh.shape["data"] == ctx.mesh.shape["data"] // 2
    assert cur["ctx"].world == ctx.world // 2
    # same batches replayed, but the smaller mesh re-partitions the
    # reductions: allclose is the contract here, not bit-identity
    np.testing.assert_allclose(w_clean, np.asarray(state["w"]),
                               rtol=1e-5, atol=1e-6)


def test_rank_loss_without_handler_is_fatal(tmp_path, ctx):
    plan = FaultPlan([FaultEvent(step=2, kind="rank_loss", rank=1)])
    sup = _supervisor(tmp_path, _builder(ctx), fault_plan=plan)
    with pytest.raises(RankLost):
        sup.run(_w0(), _batches(8), 8)


# ---------------------------------------------------------------------------
# degradation policy
# ---------------------------------------------------------------------------
def test_degradation_quarantine_release_backoff():
    pol = DegradationPolicy(DegradeConfig(max_failures=2, cooldown=3,
                                          cooldown_backoff=2.0))
    key = ("matmul_allreduce", (2, 8, 16, 16))
    assert pol.effective_mode(*key, "fused") == "fused"
    assert pol.record_failure(key) == []          # strike 1
    assert pol.record_failure(key) == [key]       # strike 2 -> jailed
    assert pol.consume_dirty() and not pol.consume_dirty()
    assert pol.effective_mode(*key, "fused") == "bulk"
    assert pol.effective_mode(*key, "bulk") == "bulk"
    for _ in range(2):
        assert pol.record_healthy() == []
    assert pol.record_healthy() == [key]          # cooldown 3 expired
    assert pol.consume_dirty()
    assert pol.effective_mode(*key, "fused") == "fused"  # re-probe
    # a failed re-probe re-jails with the cooldown doubled
    pol.record_failure(key)
    assert pol.record_failure(key) == [key]
    assert pol._quarantine[key] == 6
    assert pol.summary()["sentences"] == 2


def test_degrade_mode_demotes_at_trace_time(ctx, rng):
    x = (rng.standard_normal((B, S, K)) * 0.1).astype(np.float32)
    w = (rng.standard_normal((K, K)) * 0.1).astype(np.float32)
    bulk = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w, mode="bulk"))(x, w)
    pol = DegradationPolicy()
    prev = set_degradation_policy(pol)
    try:
        # register the key by tracing once, then strike it out
        fused = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w))(x, w)
        pol.record_failure()
        pol.record_failure()
        assert pol.quarantined("matmul_allreduce", (B, S, K, K))
        assert pol.consume_dirty()
        demoted = jax.jit(
            lambda x, w: matmul_allreduce(ctx, x, w))(x, w)
        assert pol.demotions >= 1
    finally:
        set_degradation_policy(prev)
    # the demoted trace runs the bulk reference path
    np.testing.assert_allclose(np.asarray(demoted), np.asarray(bulk),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(bulk),
                               rtol=1e-5, atol=1e-5)
    # no policy installed -> the hook is inert
    assert degrade_mode("matmul_allreduce", (B, S, K, K), "fused") == "fused"


def test_supervisor_degrades_after_repeated_faults(tmp_path, ctx):
    """Two transient faults strike the active fused decisions; the policy
    quarantines them and the supervisor re-jits onto the bulk path."""
    pol = DegradationPolicy(DegradeConfig(max_failures=2, cooldown=100))
    prev = set_degradation_policy(pol)
    try:
        plan = FaultPlan([FaultEvent(step=2, kind="timeout"),
                          FaultEvent(step=4, kind="timeout")])
        sup = _supervisor(tmp_path, _builder(ctx), fault_plan=plan,
                          degradation=pol)
        state, step = sup.run(_w0(), _batches(10), 10)
        assert step == 10
        assert pol.quarantined("matmul_allreduce", (B, S, K, K))
        assert pol.demotions >= 1  # the post-quarantine re-jit went bulk
    finally:
        set_degradation_policy(prev)


# ---------------------------------------------------------------------------
# serving under chaos
# ---------------------------------------------------------------------------
def _decode_setup(ctx):
    from repro.configs.registry import get_arch
    from repro.models.common import split_params

    bundle = get_arch("chatglm3-6b").reduced()
    params, specs = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    decode = bundle.decode_fn(ctx)
    return bundle, params, specs, jax.jit(
        lambda t, c, p: decode(params, t, c, p))


def _requests(n, max_new=5):
    rng = np.random.default_rng(0)
    from repro.serve.engine import Request

    return [Request(uid=i, prompt=rng.integers(0, 64, 3).tolist(),
                    max_new=max_new) for i in range(n)]


def test_serve_reshard_inflight_requests_survive(ctx):
    from repro.serve.engine import DecodeEngine

    bundle, params, specs, decode_jit = _decode_setup(ctx)
    base = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    for r in _requests(4):
        base.submit(r)
    want = {r.uid: r.tokens for r in base.run_until_drained(max_steps=60)}

    engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    reqs = _requests(4)
    for r in reqs:
        engine.submit(r)
    for _ in range(4):          # mid-generation: prompts consumed,
        engine.step()           # some tokens already emitted
    assert any(r.tokens for r in engine.slots if r is not None)
    n = engine.reshard(decode_jit, bundle.init_cache)
    assert n == 4               # every in-flight slot re-queued
    fin = engine.run_until_drained(max_steps=80)
    assert len(fin) == 4
    # replaying prompt + generated prefix through the fresh cache resumes
    # the same greedy continuation the uninterrupted run produced
    assert {r.uid: r.tokens for r in fin} == want


def test_serve_with_chaos_rank_loss_resharded(ctx):
    from repro.serve.engine import DecodeEngine, serve_with_chaos

    bundle, params, specs, decode_jit = _decode_setup(ctx)
    base = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    for r in _requests(4):
        base.submit(r)
    want = {r.uid: r.tokens for r in base.run_until_drained(max_steps=60)}

    engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
    for r in _requests(4):
        engine.submit(r)
    shrunk = {}

    def reshard_fn(eng):
        # live-load elastic path: shrink the mesh, re-jit, replay slots
        new_ctx = shrink_context(ctx)
        new_params, _ = reshard_tree(params, specs, new_ctx)
        dec = bundle.decode_fn(new_ctx)
        new_jit = jax.jit(lambda t, c, p: dec(new_params, t, c, p))
        eng.reshard(new_jit, bundle.init_cache)
        shrunk["world"] = new_ctx.world

    plan = FaultPlan([FaultEvent(step=1, kind="timeout"),
                      FaultEvent(step=3, kind="rank_loss", rank=7),
                      FaultEvent(step=5, kind="slow_link", delay_s=0.0)])
    fin, stats = serve_with_chaos(engine, plan, reshard_fn=reshard_fn,
                                  sleep_fn=lambda s: None, max_steps=120)
    assert len(fin) == 4 and stats["reshards"] == 1 and stats["dropped"] == 1
    assert shrunk["world"] == ctx.world // 2
    # greedy decode is deterministic across the shrink (allclose logits
    # -> identical argmax for this model/seed)
    assert {r.uid: r.tokens for r in fin} == want


def test_serve_with_chaos_paged_engine_reshard(ctx):
    """The paged engine survives a rank loss: block tables are host state,
    but the pool lives on the lost mesh — reshard rebuilds the pool on the
    shrunk mesh and replays in-flight requests through chunked prefill."""
    from repro.configs.registry import get_arch
    from repro.models.common import split_params
    from repro.serve.engine import PagedDecodeEngine, serve_with_chaos

    bundle = get_arch("chatglm3-6b").reduced()
    params, specs = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    serve = bundle.serve_step_fn(ctx)
    sj = jax.jit(lambda t, pl, tb, p, n: serve(params, t, pl, tb, p, n))

    def make_engine():
        return PagedDecodeEngine(sj, bundle.init_paged_pool, batch_size=4,
                                 num_blocks=16, block_size=8,
                                 max_seq=bundle.config.max_seq, chunk=4,
                                 n_stripes=ctx.tp)

    base = make_engine()
    for r in _requests(4):
        base.submit(r)
    want = {r.uid: r.tokens for r in base.run_until_drained(max_steps=120)}

    engine = make_engine()
    for r in _requests(4):
        engine.submit(r)
    shrunk = {}

    def reshard_fn(eng):
        new_ctx = shrink_context(ctx)
        new_params, _ = reshard_tree(params, specs, new_ctx)
        sfn = bundle.serve_step_fn(new_ctx)
        new_jit = jax.jit(
            lambda t, pl, tb, p, n: sfn(new_params, t, pl, tb, p, n))
        n = eng.reshard(new_jit, bundle.init_paged_pool,
                        n_stripes=new_ctx.tp)
        shrunk["world"], shrunk["requeued"] = new_ctx.world, n

    plan = FaultPlan([FaultEvent(step=2, kind="rank_loss", rank=7)])
    fin, stats = serve_with_chaos(engine, plan, reshard_fn=reshard_fn,
                                  sleep_fn=lambda s: None, max_steps=200)
    assert stats["reshards"] == 1 and stats["drained"]
    assert shrunk["world"] == ctx.world // 2 and shrunk["requeued"] == 4
    assert len(fin) == 4
    # chunked-prefill replay on the new pool resumes the same greedy
    # continuation the uninterrupted run produced
    assert {r.uid: r.tokens for r in fin} == want
    assert engine.kv.used_blocks == 0
