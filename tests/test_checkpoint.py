"""Checkpointing: roundtrip, atomicity, GC, async, cross-mesh (elastic)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint
from repro.checkpoint.manager import CheckpointManager
from repro.runtime.elastic import rescale_batch, reshard_tree
from repro.compat import make_mesh


def _tree(rng):
    return {"params": {"w": rng.standard_normal((8, 16)).astype(np.float32),
                       "b": rng.standard_normal((16,)).astype(np.float32)},
            "opt": {"step": np.int32(7)}}


def test_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    path = save_checkpoint(str(tmp_path), 7, tree)
    restored, step = restore_checkpoint(path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path, rng):
    save_checkpoint(str(tmp_path), 1, _tree(rng))
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_manager_keep_k_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree(rng)
    for s in [10, 20, 30, 40]:
        mgr.save(s, tree)
    assert mgr.all_steps() == [30, 40]
    restored = mgr.restore_latest(tree)
    assert restored is not None and restored[1] == 40


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = _tree(rng)
    mgr.save(5, tree)
    mgr.wait()
    restored, step = mgr.restore_latest(tree)
    assert step == 5


def test_shape_mismatch_rejected(tmp_path, rng):
    tree = _tree(rng)
    path = save_checkpoint(str(tmp_path), 1, tree)
    bad = {"params": {"w": np.zeros((4, 4), np.float32),
                      "b": tree["params"]["b"]},
           "opt": {"step": np.int32(0)}}
    with pytest.raises(ValueError):
        restore_checkpoint(path, bad)


def test_elastic_cross_mesh_restore(tmp_path, rng):
    """Checkpoint on an 8-device mesh, restore re-sharded onto 4 devices."""
    from repro.parallel.sharding import ParallelContext

    mesh8 = make_mesh((2, 4), ("data", "model"))
    ctx8 = ParallelContext.from_mesh(mesh8)
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh4 = jax.sharding.Mesh(devs, ("data", "model"))
    ctx4 = ParallelContext.from_mesh(mesh4)

    tree = {"w": rng.standard_normal((8, 16)).astype(np.float32)}
    specs = {"w": ("fsdp", "tp")}
    placed8, _ = reshard_tree(tree, specs, ctx8)
    path = save_checkpoint(str(tmp_path), 3, placed8)
    restored, step = restore_checkpoint(path, tree)
    placed4, sh4 = reshard_tree(restored, specs, ctx4)
    np.testing.assert_array_equal(np.asarray(placed4["w"]), tree["w"])
    assert placed4["w"].sharding.mesh.shape["model"] == 2
    assert rescale_batch(256, old_dp=16, new_dp=8) == 128


def test_restore_latest_falls_back_past_corruption(tmp_path, rng):
    """A garbled newest checkpoint must not brick the run: restore walks
    back to the previous keep entry."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    tree = _tree(rng)
    good = _tree(rng)
    mgr.save(10, good)
    mgr.save(20, tree)
    # truncate the newest manifest mid-write (torn disk state)
    manifest = tmp_path / "step_00000020" / "manifest.json"
    manifest.write_text(manifest.read_text()[: 15])
    restored, step = mgr.restore_latest(tree)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  good["params"]["w"])
    # a missing array file is the same story
    mgr.save(30, tree)
    arrs = [p for p in os.listdir(tmp_path / "step_00000030")
            if p.endswith(".npy")]
    os.remove(tmp_path / "step_00000030" / arrs[0])
    assert mgr.restore_latest(tree)[1] == 10
    # nothing readable at all -> None, not an exception
    manifest10 = tmp_path / "step_00000010" / "manifest.json"
    manifest10.write_text("{")
    assert mgr.restore_latest(tree) is None


def test_rescale_batch_round_trip_and_warning():
    # clean shrink/grow round trip: per-device batch is preserved
    assert rescale_batch(256, old_dp=16, new_dp=8) == 128
    assert rescale_batch(128, old_dp=8, new_dp=16) == 256
    assert rescale_batch(rescale_batch(256, 16, 8), 8, 16) == 256
    # global batch smaller than dp: the per-device clamp silently changes
    # the effective global batch — that must warn, loudly
    with pytest.warns(RuntimeWarning, match="does not divide"):
        assert rescale_batch(4, old_dp=8, new_dp=4) == 4
    with pytest.warns(RuntimeWarning):
        rescale_batch(100, old_dp=16, new_dp=8)  # non-divisible too


def test_shrink_context_halves_dp_axis():
    from repro.parallel.sharding import ParallelContext
    from repro.runtime.elastic import shrink_context

    mesh = make_mesh((2, 4), ("data", "model"))
    ctx = ParallelContext.from_mesh(mesh)
    small = shrink_context(ctx)
    assert dict(small.mesh.shape) == {"data": 1, "model": 4}
    assert small.tp == 4 and small.world == 4
    # survivors are the prefix of the old flattened world
    assert [d.id for d in np.asarray(small.mesh.devices).reshape(-1)] == \
        [d.id for d in np.asarray(mesh.devices).reshape(-1)[:4]]
    # dp exhausted -> falls back to shrinking tp
    tiny = shrink_context(small)
    assert dict(tiny.mesh.shape) == {"data": 1, "model": 2}
    with pytest.raises(ValueError):
        shrink_context(ctx, factor=3)
    with pytest.raises(ValueError):
        shrink_context(ctx, axis="data", factor=4)


def test_straggler_monitor():
    from repro.runtime.straggler import StragglerMonitor

    mon = StragglerMonitor(window=20, threshold=1.5)
    for _ in range(15):
        mon.record(0.1)
    assert not mon.record(0.1)
    assert mon.record(1.0)  # 10x median -> flagged
    assert mon.skew > 1.0
    assert mon.summary()["flags"] >= 1
