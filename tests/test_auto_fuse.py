"""Automatic fusion pass: the rewritten step must be *bit-identical*
(f32 wire) to both the hand-fused step and the unfused bulk baseline.

Hand-fused reference uses ``fuse_kv_ag=False``: the analyzer never
rewrites attention (ring KV reassociates the online softmax), so the
apples-to-apples hand configuration leaves it bulk too.  Everything the
analyzer does rewrite routes through the same wrapper code paths as the
hand-fused config — identity holds by construction, and these tests pin
it empirically across a dense transformer, an MoE and DLRM.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import auto_fuse, build_comm_graph, plan_rewrites
from repro.configs.registry import get_arch
from repro.data.synthetic import DLRMBatches, LMBatches
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig

ARCHS = ("chatglm3-6b", "dbrx-132b", "dlrm")


def _setup(arch, batch=8, seq=16):
    bundle = get_arch(arch).reduced()
    cfg = bundle.config
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    if bundle.family == "dlrm":
        b = next(iter(DLRMBatches(cfg.n_tables, cfg.table_vocab, cfg.pooling,
                                  cfg.n_dense, 16, 0)))
    else:
        b = next(iter(LMBatches(cfg.vocab, batch, seq, 0)))
    return bundle, params, jax.tree.map(jnp.asarray, b)


def _ctx(mode, **kw):
    return make_host_mesh(fusion=FusionConfig(mode=mode, **kw))


@pytest.mark.parametrize("arch", ARCHS)
def test_auto_fused_step_is_bit_identical(arch):
    bundle, params, batch = _setup(arch)
    ctx_auto = _ctx("auto")
    ctx_hand = _ctx("fused", fuse_kv_ag=False)
    ctx_bulk = _ctx("bulk")

    reports = []
    l_auto = jax.jit(auto_fuse(ctx_auto, bundle.loss_fn(ctx_auto),
                               reports=reports))(params, batch)
    l_hand = jax.jit(bundle.loss_fn(ctx_hand))(params, batch)
    l_bulk = jax.jit(bundle.loss_fn(ctx_bulk))(params, batch)

    # something was actually rewritten, and the result is exact
    assert sum(1 for r in reports[0] if r.rewritten) >= 1
    np.testing.assert_array_equal(np.asarray(l_auto), np.asarray(l_hand))
    np.testing.assert_array_equal(np.asarray(l_auto), np.asarray(l_bulk))
    assert np.isfinite(float(l_auto))


def test_grad_through_rewritten_moe_and_custom_vjp():
    """Differentiating the interpreted/rewritten step — through the scan
    rebuild, the checkpoint rebuild, the rebuilt MoE shard_map and the
    custom_vjp CE loss — matches the hand-fused gradients."""
    bundle, params, batch = _setup("dbrx-132b")
    ctx_auto = _ctx("auto")
    ctx_hand = _ctx("fused", fuse_kv_ag=False)

    # the traced step really crosses a custom_vjp boundary (the fused CE)
    closed = jax.make_jaxpr(bundle.loss_fn(ctx_auto))(params, batch)
    assert "custom_vjp_call" in str(closed)

    g_auto = jax.jit(jax.grad(auto_fuse(ctx_auto, bundle.loss_fn(ctx_auto))
                              ))(params, batch)
    g_hand = jax.jit(jax.grad(bundle.loss_fn(ctx_hand)))(params, batch)
    flat_a, tree_a = jax.tree.flatten(g_auto)
    flat_h, tree_h = jax.tree.flatten(g_hand)
    assert tree_a == tree_h
    for a, h in zip(flat_a, flat_h):
        # AD through the interpreter reassociates nothing structural but
        # ULP-level noise appears in long accumulations; pin it tightly
        np.testing.assert_allclose(np.asarray(a), np.asarray(h),
                                   rtol=2e-4, atol=5e-6)


def test_auto_fuse_caches_plan_per_signature():
    bundle, params, batch = _setup("dlrm")
    ctx = _ctx("auto")
    fn = auto_fuse(ctx, bundle.loss_fn(ctx))
    jfn = jax.jit(fn)
    l1 = jfn(params, batch)
    l2 = jfn(params, batch)
    assert len(fn.cache) == 1      # one signature, one trace/plan
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_rewrite_honors_disabled_family_at_execution():
    """With the family flag off the auto step still runs — it just keeps
    the bulk collectives (and matches the bulk baseline exactly)."""
    bundle, params, batch = _setup("dlrm")
    ctx_off = make_host_mesh(fusion=FusionConfig(mode="auto",
                                                 fuse_embed_a2a=False))
    reports = []
    l_off = jax.jit(auto_fuse(ctx_off, bundle.loss_fn(ctx_off),
                              reports=reports))(params, batch)
    assert sum(1 for r in reports[0] if r.rewritten) == 0
    l_bulk = jax.jit(bundle.loss_fn(_ctx("bulk")))(params, batch)
    np.testing.assert_array_equal(np.asarray(l_off), np.asarray(l_bulk))


def test_decode_matmul_allreduce_is_bit_identical():
    """The serve decode path exercises the fifth family — the decode
    FFN-down GEMV + psum rewrites to ``matmul_allreduce`` and the decode
    outputs stay exact."""
    from repro.analysis import commgraph as cg

    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    tok = jnp.zeros((4, 1), jnp.int32)
    ctx_auto = _ctx("auto")

    reports = []
    dec_auto = auto_fuse(ctx_auto, bundle.decode_fn(ctx_auto),
                         reports=reports)
    out_auto = jax.jit(dec_auto)(params, tok, bundle.init_cache(4), 0)
    out_hand = jax.jit(bundle.decode_fn(_ctx("fused", fuse_kv_ag=False)))(
        params, tok, bundle.init_cache(4), 0)
    assert any(r.family == cg.MATMUL_ALLREDUCE and r.rewritten
               for r in reports[0])
    for a, h in zip(jax.tree.leaves(out_auto), jax.tree.leaves(out_hand)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(h))


def test_moe_rewrite_sinks_expert_ffn():
    """The dbrx rewrite must engage the per-destination producer sink
    (the paper's GEMM+A2A chain), not the fallback full-compute slice."""
    from repro.analysis import commgraph as cg
    from repro.analysis.rewrite import _MoeRewrite

    bundle, params, batch = _setup("dbrx-132b")
    ctx = _ctx("auto")
    closed = jax.make_jaxpr(bundle.loss_fn(ctx))(params, batch)
    plan = plan_rewrites(build_comm_graph(closed, ctx), ctx)
    moe_actions = [a for a in plan.actions.values()
                   if isinstance(a, _MoeRewrite)]
    assert len(moe_actions) == 1
    assert moe_actions[0].sink.ok, moe_actions[0].sink.why
    assert len(moe_actions[0].sink.chain) >= 3   # FFN GEMMs + activation
