"""Per-architecture reduced-config smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED config of the same
family and runs one forward/train step + one decode step on CPU,
asserting output shapes and finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.models.common import split_params

LM_ARCHS = [a for a in ARCHS if a != "dlrm"]


def _batch(bundle, B, S, rng):
    cfg = bundle.config
    batch = {"tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)}
    batch["labels"] = batch["tokens"]
    fe = getattr(cfg, "frontend", None)
    if fe == "audio":
        batch["frame_embeds"] = rng.standard_normal(
            (B, S, cfg.d_model)).astype(np.float32) * 0.02
    if fe == "vision":
        batch["vision_embeds"] = rng.standard_normal(
            (B, S, cfg.d_model)).astype(np.float32) * 0.02
        batch["vision_mask"] = np.arange(S) < 8
        batch["positions_thw"] = np.tile(
            np.arange(S, dtype=np.int32)[None, None], (3, B, 1))
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(ctx, rng, arch):
    bundle = get_arch(arch).reduced()
    B, S = 4, 32
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    batch = _batch(bundle, B, S, rng)
    loss = jax.jit(bundle.loss_fn(ctx))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one decode step
    cache = bundle.init_cache(B)
    logits, cache2 = jax.jit(bundle.decode_fn(ctx))(
        params, batch["tokens"][:, :1], cache, jnp.int32(0))
    assert logits.shape == (B, 1, bundle.config.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["chatglm3-6b", "rwkv6-7b", "zamba2-7b"])
def test_prefill_smoke(ctx, rng, arch):
    bundle = get_arch(arch).reduced()
    B, S = 4, 32
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    batch = _batch(bundle, B, S, rng)
    logits, cache = jax.jit(bundle.prefill_fn(ctx))(params, batch)
    assert logits.shape == (B, 1, bundle.config.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


def test_dlrm_smoke(ctx, rng):
    bundle = get_arch("dlrm").reduced()
    cfg = bundle.config
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    B = 16
    batch = {
        "dense": rng.standard_normal((B, cfg.n_dense)).astype(np.float32),
        "indices": rng.integers(0, cfg.table_vocab,
                                (B, cfg.n_tables, cfg.pooling)).astype(np.int32),
        "labels": rng.integers(0, 2, (B,)).astype(np.float32),
    }
    loss = jax.jit(bundle.loss_fn(ctx))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.jit(jax.grad(bundle.loss_fn(ctx)))(params, batch)
    assert all(np.isfinite(np.asarray(g, np.float32)).all()
               for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ["gemma2-27b", "deepseek-v3-671b"])
def test_grad_step_smoke(ctx, rng, arch):
    """Full value_and_grad through the trickiest variants."""
    bundle = get_arch(arch).reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    batch = _batch(bundle, 4, 32, rng)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn(ctx)))(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    assert any(np.abs(np.asarray(g, np.float32)).max() > 0 for g in flat)
