"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis.lint import schedule_violations
from repro.core.scheduling import (expected_send_cover,
                                   reduce_ring_chunk_order, ring_offsets,
                                   sub_chunk_send_events,
                                   sub_chunk_service_order)
from repro.train.grad_compression import _dequantize_int8, _quantize_int8

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(2, 64))
@settings(**SETTINGS)
def test_ring_offsets_cover_all_peers(world):
    for schedule in ["comm_aware", "oblivious"]:
        offs = ring_offsets(world, schedule)
        assert sorted(offs) == list(range(world))
    # comm-aware: local chunk strictly last
    assert ring_offsets(world, "comm_aware")[-1] == 0


@given(st.integers(2, 32), st.sampled_from([1, 2, 4]), st.integers(0, 1000))
@settings(**SETTINGS)
def test_sub_chunk_schedule_is_permutation(world, q, skew):
    """Sub-chunk ring scheduling is a permutation: for arbitrary
    (n_dev, chunks_per_rank, skew), every (rank, fine chunk) payload is
    sent exactly once and lands at the owning destination.

    The exact-cover checks go through the same
    ``schedule_violations`` / ``expected_send_cover`` pair the static
    lint lane runs (``scripts/lint_comm.py``) — one implementation, so
    the property suite and the lint verifier can never drift apart."""
    for schedule in ["comm_aware", "oblivious"]:
        assert schedule_violations(world, q, schedule, skew) == []
        events = sub_chunk_send_events(world, q, schedule, skew)
        want = expected_send_cover(world, q)
        for r, sends in enumerate(events):
            # the exact-cover invariant, stated directly against the
            # shared ground-truth definition
            assert set(sends) == want and len(sends) == len(want)
            # sub-chunks of one destination payload are issued in order,
            # back to back (each forwarded as soon as the previous one is
            # consumed — never interleaved across destinations)
            dests = [dest for dest, _ in sends]
            for j in range(0, len(sends), q):
                assert len(set(dests[j:j + q])) == 1
                assert [f % q for _, f in sends[j:j + q]] == list(range(q))
    # comm-aware keeps the local payload last under any skew
    aware = sub_chunk_send_events(world, q, "comm_aware", skew)
    for r, sends in enumerate(aware):
        assert all(dest == r for dest, _ in sends[-q:])


@given(st.sampled_from([1, 2, 4]), st.integers(0, 1000))
@settings(**SETTINGS)
def test_sub_chunk_service_order_is_permutation(q, skew):
    """The ring-carry sub-ring service order is a permutation of the
    sub-rings under any skew rotation (the other half of what the static
    schedule verifier proves)."""
    order = sub_chunk_service_order(q, skew)
    assert sorted(order) == list(range(q))
    # rotation only: relative cyclic order of the sub-rings is preserved
    r = skew % q
    assert order == list(range(r, q)) + list(range(r))


@given(st.integers(2, 32), st.integers(1, 31))
@settings(**SETTINGS)
def test_ring_offsets_skew_rotates_remotes(world, skew):
    """Skew rotates which remote peer goes first (Fig. 14 straggler
    feed-in) without disturbing coverage or the local chunk's slot."""
    base = ring_offsets(world, "comm_aware")
    skewed = ring_offsets(world, "comm_aware", skew)
    assert sorted(skewed) == list(range(world))
    assert skewed[-1] == 0
    remote = base[:-1]
    r = skew % len(remote)
    assert skewed[:-1] == remote[r:] + remote[:r]


@given(st.sampled_from([1, 2, 4]), st.integers(0, 20),
       st.sampled_from(["comm_aware", "oblivious"]))
@settings(max_examples=8, deadline=None)
def test_executed_a2a_order_matches_model(q, skew, schedule):
    """The *executed* sub-chunked A2A issues sends in exactly the order
    ``sub_chunk_send_events`` models: a payload that encodes the
    trace-time issue counter lands, on the real 8-device mesh, in the
    slot the modeled event list predicts for that counter value."""
    import itertools

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.collectives import direct_all_to_all_compute
    from repro.parallel.sharding import ParallelContext
    from repro.compat import make_mesh

    n = 8
    ctx = ParallelContext.from_mesh(make_mesh((n,), ("model",)))
    counter = itertools.count()

    def local_fn(xl):
        def produce(f):
            j = next(counter)  # static issue position (shared SPMD trace)
            rows = 1 if q > 1 else q
            return jnp.full((rows,), j, jnp.int32)

        return direct_all_to_all_compute(
            produce, jax.ShapeDtypeStruct((q,), jnp.int32), "model",
            schedule=schedule, chunks_per_rank=q, sub_axis=0, skew=skew)

    out = jax.jit(shard_map(
        local_fn, mesh=ctx.mesh, in_specs=(P("model"),),
        out_specs=P("model", None), check_vma=False,
    ))(jnp.zeros((n,), jnp.float32))
    got = np.asarray(out).reshape(n, n, q)  # [receiver, src, sub]

    from repro.core.scheduling import sub_chunk_send_events
    events = sub_chunk_send_events(n, q, schedule, skew)
    for d in range(n):
        for src in range(n):
            for s in range(q):
                k = events[src].index((d, d * q + s))
                assert got[d, src, s] == k, (d, src, s)


@given(st.integers(2, 64))
@settings(**SETTINGS)
def test_reduce_ring_order_is_permutation(world):
    for schedule in ["comm_aware", "oblivious"]:
        order = reduce_ring_chunk_order(world, schedule)
        assert sorted(o % world for o in order) == list(range(world))


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=256))
@settings(**SETTINGS)
def test_int8_quantization_error_bound(values):
    g = jnp.asarray(np.array(values, np.float32))
    q, scale = _quantize_int8(g)
    deq = _dequantize_int8(q, scale)
    # error bounded by half a quantization step
    assert float(jnp.abs(deq - g).max()) <= float(scale) * 0.5 + 1e-6


@given(st.integers(1, 8), st.integers(1, 4), st.integers(0, 1000))
@settings(**SETTINGS)
def test_moe_routing_conserves_tokens(e_pow, k, seed):
    """Every non-dropped (token, expert) slot holds exactly one token."""
    E = 2 ** e_pow
    K = min(k, E)
    T = 32
    rng = np.random.default_rng(seed)
    gate_i = np.stack([rng.choice(E, size=K, replace=False) for _ in range(T)])
    flat_e = gate_i.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    C = int(np.ceil(T * K / E))
    valid = np.asarray(pos) < C
    # no two assignments share an (expert, slot)
    slots = list(zip(flat_e[valid].tolist(), np.asarray(pos)[valid].tolist()))
    assert len(slots) == len(set(slots))
    # per-expert counts within capacity
    counts = np.bincount(flat_e[valid], minlength=E)
    assert (counts <= C).all()


@given(st.integers(0, 10_000), st.integers(1, 64))
@settings(**SETTINGS)
def test_rope_preserves_norm(seed, pos):
    from repro.models.rope import apply_rope

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, 1, 2, 16)).astype(np.float32)
    y = apply_rope(jnp.asarray(x), jnp.array([[pos]]))
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(x), rtol=1e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip_random_trees(tmp_path_factory, seed):
    from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint

    rng = np.random.default_rng(seed)
    tree = {"a": rng.standard_normal((rng.integers(1, 8),)).astype(np.float32),
            "b": [rng.integers(0, 100, (2, 3)).astype(np.int32)],
            "c": {"d": np.float32(rng.random())}}
    d = tmp_path_factory.mktemp("ckpt")
    path = save_checkpoint(str(d), seed, tree)
    restored, step = restore_checkpoint(path, tree)
    assert step == seed
    for x, y in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
