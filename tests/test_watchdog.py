"""Unit tests for the liveness layer (no subprocesses: clock and pid
prober are injected, so the whole classification matrix and both raise
paths run in-process).  The genuine cross-process drills — SIGKILL and
SIGSTOP against real jax.distributed workers — live in
``tests/multiprocess``."""
import threading
import time

import pytest

from repro.runtime.chaos import CollectiveTimeout, RankLost
from repro.runtime.watchdog import (ALIVE, DEAD, STALLED, STARTING,
                                    Heartbeat, HeartbeatWriter,
                                    LivenessMonitor, Watchdog,
                                    heartbeat_path, read_heartbeat,
                                    write_heartbeat)


def test_heartbeat_roundtrip(tmp_path):
    hb = Heartbeat(rank=3, pid=4242, time=123.5, step=7, generation=2,
                   status="up")
    write_heartbeat(str(tmp_path), hb)
    back = read_heartbeat(str(tmp_path), 3)
    assert back == hb


def test_read_missing_and_garbled(tmp_path):
    assert read_heartbeat(str(tmp_path), 0) is None
    with open(heartbeat_path(str(tmp_path), 0), "w") as f:
        f.write("{not json")
    assert read_heartbeat(str(tmp_path), 0) is None
    with open(heartbeat_path(str(tmp_path), 0), "w") as f:
        f.write('{"unexpected": 1}')
    assert read_heartbeat(str(tmp_path), 0) is None


def test_atomic_write_leaves_no_tmp(tmp_path):
    write_heartbeat(str(tmp_path), Heartbeat(rank=0, pid=1, time=0.0))
    names = {p.name for p in tmp_path.iterdir()}
    assert names == {"hb_0.json"}


def test_writer_beats_in_background(tmp_path):
    w = HeartbeatWriter(str(tmp_path), 0, interval_s=0.02)
    with w:
        time.sleep(0.1)
        hb1 = read_heartbeat(str(tmp_path), 0)
        time.sleep(0.1)
        hb2 = read_heartbeat(str(tmp_path), 0)
    assert hb1 is not None and hb2 is not None
    assert hb2.time > hb1.time
    # final beat on stop carries the departure status
    assert read_heartbeat(str(tmp_path), 0).status == "leaving"


def _monitor(tmp_path, *, now, world=2, pid_alive=lambda pid: True,
             **kw):
    clock = lambda: now[0]
    return LivenessMonitor(str(tmp_path), 0, world, pid_alive=pid_alive,
                           clock=clock, **kw)


def test_classification_matrix(tmp_path):
    now = [1000.0]
    alive_pids = {1: True}
    mon = _monitor(tmp_path, now=now, stall_after_s=2.0, start_grace_s=30.0,
                   pid_alive=lambda pid: alive_pids.get(pid, False))

    # no heartbeat yet, inside the grace window -> STARTING
    assert mon.observe()[1].state == STARTING

    # fresh heartbeat -> ALIVE
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=1, time=now[0]))
    assert mon.observe()[1].state == ALIVE

    # stale heartbeat, pid alive -> STALLED (SIGSTOP / wedged runtime)
    now[0] += 5.0
    assert mon.observe()[1].state == STALLED

    # stale heartbeat, pid gone -> DEAD
    alive_pids[1] = False
    assert mon.observe()[1].state == DEAD

    # explicit departure status -> DEAD even when fresh
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=1, time=now[0],
                                             status="leaving"))
    assert mon.observe()[1].state == DEAD


def test_no_heartbeat_past_grace_is_dead(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, start_grace_s=10.0)
    assert mon.observe()[1].state == STARTING
    now[0] = 11.0
    assert mon.observe()[1].state == DEAD


def test_stale_generation_reads_as_not_started(tmp_path):
    # A gen-0 heartbeat left behind by the previous incarnation must not
    # read as a live gen-1 peer.
    now = [0.0]
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=1, time=now[0],
                                             generation=0))
    mon = _monitor(tmp_path, now=now, generation=1, start_grace_s=10.0)
    assert mon.observe()[1].state == STARTING
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=1, time=now[0],
                                             generation=1))
    assert mon.observe()[1].state == ALIVE


def test_check_raises_rank_lost_for_dead_peer(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, pid_alive=lambda pid: False)
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=99, time=0.0))
    now[0] = 10.0
    with pytest.raises(RankLost) as ei:
        mon.check()
    assert "liveness" in str(ei.value)


def test_check_raises_collective_timeout_for_stalled_peer(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, pid_alive=lambda pid: True)
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=99, time=0.0))
    now[0] = 10.0
    with pytest.raises(CollectiveTimeout) as ei:
        mon.check()
    assert "stalled" in str(ei.value)


def test_dead_wins_over_stalled(tmp_path):
    # rank 1 stalled, rank 2 dead: the dead rank is the stronger
    # diagnosis and must be the one raised.
    now = [0.0]
    mon = _monitor(tmp_path, now=now, world=3,
                   pid_alive=lambda pid: pid == 1)
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=1, time=0.0))
    write_heartbeat(str(tmp_path), Heartbeat(rank=2, pid=2, time=0.0))
    now[0] = 10.0
    with pytest.raises(RankLost) as ei:
        mon.check()
    assert ei.value.rank == 2


def test_disarmed_monitor_never_raises(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, pid_alive=lambda pid: False)
    mon.enabled = False
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=99, time=0.0))
    now[0] = 100.0
    mon.check()   # no raise while disarmed (first-compile window)
    mon.enabled = True
    with pytest.raises(RankLost):
        mon.check()


def test_guarded_passes_through_result_and_exception(tmp_path):
    mon = LivenessMonitor(str(tmp_path), 0, 1)   # no peers: check no-ops
    assert mon.guarded(lambda a, b: a + b, 2, 3) == 5

    class Boom(RuntimeError):
        pass

    def boom():
        raise Boom("inner")

    with pytest.raises(Boom):
        mon.guarded(boom)


def test_guarded_raises_when_peer_dies_mid_step(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, pid_alive=lambda pid: False)
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=99, time=0.0))
    release = threading.Event()

    def hang():
        now[0] = 10.0          # peer goes stale while we are "in" the step
        release.wait(5.0)

    with pytest.raises(RankLost):
        mon.guarded(hang, poll_s=0.01)
    release.set()


def test_guarded_step_deadline(tmp_path):
    # all peers healthy (world of 1) but the step wedges: the deadline
    # backstop converts it into CollectiveTimeout.
    mon = LivenessMonitor(str(tmp_path), 0, 1)
    release = threading.Event()
    with pytest.raises(CollectiveTimeout) as ei:
        mon.guarded(lambda: release.wait(5.0), deadline_s=0.05, poll_s=0.01)
    assert "deadline" in str(ei.value)
    release.set()


def test_watchdog_parks_and_reraises(tmp_path):
    now = [0.0]
    mon = _monitor(tmp_path, now=now, pid_alive=lambda pid: False)
    write_heartbeat(str(tmp_path), Heartbeat(rank=1, pid=99, time=0.0))
    wd = Watchdog(mon, poll_s=0.01)
    with wd:
        wd.maybe_raise()       # healthy so far
        now[0] = 10.0
        deadline = time.time() + 2.0
        while wd.failure is None and time.time() < deadline:
            time.sleep(0.01)
        with pytest.raises(RankLost):
            wd.maybe_raise()
