"""Unit tests for the pure parts of :mod:`repro.runtime.multiprocess`:
the worker-env wire format, the respawn-protocol decision function and
the measured alpha-beta hardware-model fit.  Everything that needs a
real coordinator-wired world lives in ``tests/multiprocess``."""
import numpy as np
import pytest

from repro.core.perfmodel import DCN
from repro.runtime.multiprocess import (EXIT_OK, EXIT_RESHARD,
                                        EXIT_RESTART, WorkerEnv,
                                        fit_alpha_beta,
                                        measured_hardware_model,
                                        next_generation_world,
                                        pick_free_port)


def test_worker_env_roundtrip():
    cfg = WorkerEnv(rank=2, world=4, coordinator="127.0.0.1:12345",
                    generation=1, heartbeat_dir="/tmp/hb", local_devices=2,
                    extra={"steps": 8, "ckpt_dir": "/tmp/ck"})
    env = cfg.to_env()
    assert all(k.startswith("REPRO_MP_") for k in env)
    back = WorkerEnv.from_env({**env, "UNRELATED": "x"})
    assert back == cfg


def test_worker_env_defaults():
    cfg = WorkerEnv(rank=0, world=1, coordinator="h:1", generation=0,
                    heartbeat_dir="/tmp/hb")
    back = WorkerEnv.from_env(cfg.to_env())
    assert back.local_devices == cfg.local_devices
    assert back.extra == {}


def test_pick_free_port_is_bindable():
    import socket

    port = pick_free_port()
    with socket.socket() as s:
        s.bind(("127.0.0.1", port))


class TestNextGenerationWorld:
    def test_reshard_shrinks_to_survivors(self):
        # rank 1 SIGKILLed (-9), the other two exited with the reshard
        # protocol code: next world = number of survivors.
        codes = {0: EXIT_RESHARD, 1: -9, 2: EXIT_RESHARD}
        assert next_generation_world(codes) == 2

    def test_restart_keeps_world_size(self):
        codes = {0: EXIT_RESTART, 1: EXIT_RESTART}
        assert next_generation_world(codes) == 2

    def test_reshard_wins_over_restart(self):
        # mixed signals: the permanent diagnosis (reshard) subsumes the
        # transient one.
        codes = {0: EXIT_RESHARD, 1: EXIT_RESTART, 2: -9}
        assert next_generation_world(codes) == 2

    def test_all_ok_is_terminal(self):
        # run_elastic checks for completion before consulting this
        # function; an all-OK generation carries no respawn request.
        assert next_generation_world({0: EXIT_OK, 1: EXIT_OK}) is None

    def test_all_crashed_is_unrecoverable(self):
        assert next_generation_world({0: -9, 1: 1}) is None

    def test_clean_exits_count_as_survivors(self):
        # a rank that drained and exited 0 while its peers voted reshard
        # still exists for the next generation.
        codes = {0: EXIT_RESHARD, 1: EXIT_OK, 2: -9}
        assert next_generation_world(codes) == 2


def test_fit_alpha_beta_recovers_synthetic_line():
    alpha, beta = 40e-6, 1.0 / 2e9          # 40us latency, 2 GB/s
    sizes = [1 << 20, 4 << 20, 16 << 20]
    times = [alpha + beta * s for s in sizes]
    a, b = fit_alpha_beta(sizes, times)
    assert a == pytest.approx(alpha, rel=1e-6)
    assert b == pytest.approx(beta, rel=1e-6)


def test_fit_alpha_beta_clamps_negative_intercept():
    # noisy small-transfer data can produce a negative intercept; the
    # model clamps to physical values.
    sizes = [1e6, 2e6]
    times = [1e-4, 3e-4]                    # implies alpha < 0
    a, b = fit_alpha_beta(sizes, times)
    assert a >= 0.0
    assert b > 0.0


def test_measured_hardware_model_replaces_link_constants():
    sizes = [1 << 20, 8 << 20]
    beta = 1.0 / 1.5e9
    times = [1e-4 + beta * s for s in sizes]
    hw = measured_hardware_model(sizes, times)
    assert hw.ici_bw == pytest.approx(1.5e9, rel=1e-6)
    assert hw.ici_lat == pytest.approx(1e-4, rel=1e-6)
    # non-link constants are inherited from the base (DCN) model
    assert hw.hbm_bw == DCN.hbm_bw


def test_measured_model_feeds_perf_predictions():
    # the measured model must slot into the same prediction path the
    # --calibrate sweep uses: slower measured links -> larger predicted
    # collective time.
    sizes = [1 << 20, 8 << 20]
    fast = measured_hardware_model(sizes, [s / 10e9 + 1e-5 for s in sizes])
    slow = measured_hardware_model(sizes, [s / 1e9 + 1e-3 for s in sizes])
    nbytes = 4 << 20
    t_fast = nbytes / fast.ici_bw + fast.ici_lat
    t_slow = nbytes / slow.ici_bw + slow.ici_lat
    assert t_slow > t_fast
    assert np.isfinite(t_slow)
