"""End-to-end behaviour: registry coverage + launcher drivers."""
import subprocess
import sys

import pytest

from repro.configs.registry import ARCHS, SHAPES, get_arch


def test_registry_covers_assignment():
    assigned = {"chatglm3-6b", "phi3-medium-14b", "gemma2-27b", "deepseek-67b",
                "musicgen-medium", "rwkv6-7b", "zamba2-7b", "deepseek-v3-671b",
                "dbrx-132b", "qwen2-vl-2b"}
    assert assigned.issubset(set(ARCHS))
    assert "dlrm" in ARCHS  # the paper's own architecture


def test_shape_cells_complete():
    """40 assigned cells: 10 archs x 4 shapes, with long_500k honoured only
    by sub-quadratic archs (skips are explicit, not silent)."""
    lm_archs = [a for a in ARCHS if a != "dlrm"]
    assert len(lm_archs) == 10 and len(SHAPES) == 4
    cells = {(a, s) for a in lm_archs for s in SHAPES}
    assert len(cells) == 40
    runnable = {(a, s) for a in lm_archs for s in get_arch(a).shapes()}
    skipped = cells - runnable
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "chatglm3-6b", "phi3-medium-14b", "gemma2-27b", "deepseek-67b",
        "musicgen-medium", "deepseek-v3-671b", "dbrx-132b", "qwen2-vl-2b"}
    assert ("rwkv6-7b", "long_500k") in runnable
    assert ("zamba2-7b", "long_500k") in runnable


def test_production_mesh_constructors():
    """make_production_mesh is a function and importing the module never
    touches jax device state (as required by the dry-run contract)."""
    import inspect

    from repro.launch import mesh as mesh_mod

    src = inspect.getsource(mesh_mod)
    assert "def make_production_mesh" in src
    assert "make_mesh(" not in src.split("def ")[0]


def test_train_driver_end_to_end(tmp_path):
    """launch driver: 30 steps of a reduced model completes + checkpoints."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "chatglm3-6b",
           "--reduced", "--steps", "30", "--batch", "8", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done at step 30" in out.stdout
