"""Paged KV host side: block allocator, tables, striping, rollback."""
import numpy as np
import pytest

from repro.serve.kv_cache import FREE_BLOCK, OutOfBlocks, PagedKVCache


def test_alloc_stripes_round_robin():
    kv = PagedKVCache(num_blocks=8, block_size=4,
                      max_blocks_per_request=4, n_stripes=4)
    kv.ensure(0, 16)   # 4 blocks
    stripes = sorted(b // 2 for b in kv.blocks_for(0))
    # one block from each rank stripe: balanced HBM/attention load
    assert stripes == [0, 1, 2, 3]


def test_ensure_is_incremental_and_idempotent():
    kv = PagedKVCache(16, 4, max_blocks_per_request=8)
    kv.ensure(1, 3)
    assert len(kv.blocks_for(1)) == 1 and kv.capacity(1) == 4
    kv.ensure(1, 4)    # still fits the first block
    assert len(kv.blocks_for(1)) == 1
    kv.ensure(1, 5)
    assert len(kv.blocks_for(1)) == 2
    assert kv.used_blocks == 2


def test_release_returns_blocks_and_reuse():
    kv = PagedKVCache(4, 4, max_blocks_per_request=4)
    kv.ensure(1, 16)
    with pytest.raises(OutOfBlocks):
        kv.ensure(2, 4)
    kv.release(1)
    assert kv.free_blocks == 4
    kv.ensure(2, 16)   # the freed blocks are immediately reusable
    assert kv.used_blocks == 4
    assert kv.peak_blocks == 4


def test_failed_ensure_rolls_back_partial_growth():
    kv = PagedKVCache(4, 4, max_blocks_per_request=4)
    kv.ensure(1, 12)   # 3 of 4 blocks
    with pytest.raises(OutOfBlocks):
        kv.ensure(2, 8)  # needs 2, only 1 free
    # the one block grabbed before exhaustion went back to the pool
    assert kv.blocks_for(2) == []
    assert kv.free_blocks == 1
    kv.ensure(2, 4)      # single block still fits
    assert len(kv.blocks_for(2)) == 1


def test_table_bound_raises_value_error():
    kv = PagedKVCache(16, 4, max_blocks_per_request=2)
    with pytest.raises(ValueError):
        kv.ensure(0, 9)  # 3 blocks > MB=2


def test_tables_for_pads_with_sentinel():
    kv = PagedKVCache(8, 4, max_blocks_per_request=3)
    kv.ensure(7, 5)
    t = kv.tables_for([7, None])
    assert t.shape == (2, 3)
    assert (t[1] == FREE_BLOCK).all()          # empty slot: all sentinel
    assert (t[0][2:] == FREE_BLOCK).all()      # unused tail: sentinel
    assert sorted(t[0][:2]) == sorted(kv.blocks_for(7))


def test_num_blocks_must_divide_stripes():
    with pytest.raises(ValueError):
        PagedKVCache(6, 4, max_blocks_per_request=2, n_stripes=4)
