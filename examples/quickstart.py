"""Quickstart: the paper's fused operators in five minutes.

Runs on any CPU (8 simulated devices): builds a mesh, compares the
bulk-synchronous baseline against the fused compute-collective operators
(numerically identical, different collective schedule), and trains a tiny
transformer with every fused op engaged.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fused import (FusionConfig, matmul_allreduce,
                              sharded_cross_entropy)
from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_params
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state
from repro.data.synthetic import LMBatches


def main():
    ctx = make_host_mesh()
    print(f"mesh: {dict(ctx.mesh.shape)}  (dp={ctx.dp}, tp={ctx.tp})")

    # --- 1. one fused operator, bulk vs fused --------------------------------
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 16, 64)).astype(np.float32)
    w = rng.standard_normal((64, 128)).astype(np.float32)
    y_bulk = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w, mode="bulk"))(x, w)
    y_fused = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w, mode="fused"))(x, w)
    print("GEMM+AllReduce bulk == fused:",
          bool(jnp.allclose(y_bulk, y_fused, rtol=1e-4, atol=1e-4)))

    # the fused schedule shows up as collective-permutes in the HLO
    hlo = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w, mode="fused")
                  ).lower(x, w).compile().as_text()
    print("fused HLO collective-permutes:", hlo.count("collective-permute("))
    print("bulk would use a single all-reduce instead")

    # --- 2. tiny end-to-end training with all fused ops ---------------------
    bundle = get_arch("chatglm3-6b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    tc = TrainConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                               total_steps=60))
    state = init_train_state(tc, params)
    step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc),
                   donate_argnums=(0,))
    losses = []
    for i, batch in zip(range(60), LMBatches(bundle.config.vocab, 8, 32)):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    print(f"trained 60 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(fused embedding+RS, ring attention, SP FFN, fused vocab CE)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
