"""Serve a small model with batched requests (continuous batching).

The decode FFN runs the paper's flagship fused GEMV+AllReduce; the KV
cache is sequence-sharded with partial-softmax merge.

  PYTHONPATH=src python examples/serve_decode_fused.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig
from repro.serve.engine import DecodeEngine, Request


def main():
    for mode in ["bulk", "fused"]:
        ctx = make_host_mesh(fusion=FusionConfig(mode=mode))
        bundle = get_arch("chatglm3-6b").reduced()
        params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
        decode = bundle.decode_fn(ctx)
        decode_jit = jax.jit(lambda t, c, p: decode(params, t, c, p))
        engine = DecodeEngine(decode_jit, bundle.init_cache, batch_size=4)
        rng = np.random.default_rng(0)
        for i in range(8):
            engine.submit(Request(uid=i, prompt=rng.integers(0, 64, 4).tolist(),
                                  max_new=10))
        t0 = time.time()
        finished = engine.run_until_drained(max_steps=60)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in finished)
        print(f"{mode:6s}: {len(finished)} requests, {toks} tokens, "
              f"{toks/dt:.1f} tok/s (CPU proxy)")
        assert len(finished) == 8
    print("same greedy tokens either mode:",
          finished[0].tokens[:8])


if __name__ == "__main__":
    main()
