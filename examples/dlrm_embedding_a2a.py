"""DLRM with the fused embedding + All-to-All operator (the paper's own
architecture, Fig. 6) + fault-tolerant training.

Trains a reduced DLRM, kills a "node" mid-run (injected failure), and
shows the supervisor restoring from the async checkpoint.

  PYTHONPATH=src python examples/dlrm_embedding_a2a.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.data.synthetic import DLRMBatches
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_params
from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state


def main():
    ctx = make_host_mesh()
    bundle = get_arch("dlrm").reduced()
    cfg = bundle.config
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-2, warmup_steps=2,
                                               total_steps=80))
    state = init_train_state(tc, params)
    base_step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc),
                        donate_argnums=(0,))

    # inject a failure at step 25 (first attempt only)
    fail = {"armed": True}

    def step_fn(state, batch):
        s, m = base_step(state, batch)
        if fail["armed"] and int(m["step"]) == 25:
            fail["armed"] = False
            raise RuntimeError("injected node failure")
        return s, m

    losses = []
    with tempfile.TemporaryDirectory() as d:
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=d, checkpoint_every=10,
                             max_restarts=2, async_save=True),
            step_fn)
        batches = DLRMBatches(cfg.n_tables, cfg.table_vocab, cfg.pooling,
                              cfg.n_dense, batch=16)
        state, step = sup.run(state, batches, num_steps=60,
                              on_metrics=lambda s, m: losses.append(
                                  float(m["loss"])))
    print(f"finished at step {step} with {sup.restarts} restart(s); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert sup.restarts == 1 and step == 60 and losses[-1] < losses[0]


if __name__ == "__main__":
    main()
