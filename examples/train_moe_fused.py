"""Train a reduced MoE (deepseek-v3 family) with fused GEMM+All-to-All.

Shows the paper's MoE operator end-to-end: expert-parallel dispatch,
expert FFN fused with the combine All-to-All (per-destination sends,
comm-aware order), shared expert, MLA attention — and compares one step's
lowered collective schedule between bulk and fused modes.

  PYTHONPATH=src python examples/train_moe_fused.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import re

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.data.synthetic import LMBatches
from repro.launch.mesh import make_host_mesh
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state


def collective_counts(ctx, bundle, batch, params):
    out = {}
    for mode in ["bulk", "fused"]:
        c = make_host_mesh(fusion=FusionConfig(mode=mode))
        loss = bundle.loss_fn(c)
        txt = jax.jit(loss).lower(params, batch).compile().as_text()
        out[mode] = {k: len(re.findall(k + r"\(", txt))
                     for k in ["all-to-all", "collective-permute", "all-reduce",
                               "all-gather"]}
    return out


def main():
    ctx = make_host_mesh()
    bundle = get_arch("deepseek-v3-671b").reduced()
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    batch = next(LMBatches(bundle.config.vocab, 8, 32))

    counts = collective_counts(ctx, bundle, batch, params)
    print("collective schedule (one fwd):")
    for mode, c in counts.items():
        print(f"  {mode:6s}: {c}")
    print("fused mode decomposes the A2As into per-destination permutes the "
          "scheduler overlaps with expert GEMMs (paper Fig. 10)")

    tc = TrainConfig(optimizer=OptimizerConfig(name="adafactor", lr=1e-2,
                                               warmup_steps=3, total_steps=40))
    state = init_train_state(tc, params)
    step = jax.jit(build_train_step(bundle.loss_fn(ctx), tc),
                   donate_argnums=(0,))
    losses = []
    for i, b in zip(range(40), LMBatches(bundle.config.vocab, 8, 32)):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
        if i % 10 == 0:
            print(f"step {i:3d} loss {losses[-1]:.4f}")
    print(f"MoE loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
