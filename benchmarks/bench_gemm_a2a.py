"""Paper Fig. 10: MoE expert GEMM + All-to-All combine, fused vs bulk.

The paper reports 12% avg (20% max) lower execution time with a generic
Triton GEMM (compute-dominated, which bounds the win).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import model_bulk, model_fused, pct_reduction, timeit


def run(report):
    import jax

    from repro.core.moe_all_to_all import fused_expert_ffn_combine
    from repro.launch.mesh import make_host_mesh

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    reductions = []
    for C, D, F in [(16, 64, 128), (32, 128, 256)]:
        n_ep, E = 4, 8
        xd = rng.standard_normal((8, n_ep, E, C, D)).astype(np.float32)
        wu = rng.standard_normal((E, D, F)).astype(np.float32)
        wg = rng.standard_normal((E, D, F)).astype(np.float32)
        wd = rng.standard_normal((E, F, D)).astype(np.float32)
        fns = {m: jax.jit(lambda x, m=m: fused_expert_ffn_combine(
            ctx, x, wu, wg, wd, act=jax.nn.silu, mode=m))
            for m in ["bulk", "fused"]}
        t = {m: timeit(fns[m], xd) for m in fns}
        red = pct_reduction(t["bulk"], t["fused"])
        report(f"gemm_a2a_cpu_proxy_C{C}xD{D}", t["fused"] * 1e6,
               f"bulk_us={t['bulk']*1e6:.1f};reduction_pct={red:.1f}")
        reductions.append(red)

    # projection: expert shards (dbrx-like / deepseek-v3-like), tp=16
    for tok, D, F in [(4096, 6144, 10752), (4096, 7168, 2048)]:
        flops = 2 * 3 * tok * D * F / 16
        hbm = 3 * D * F * 2            # expert weights read once (bf16)
        wire = tok * D * 2 / 16 * 2    # dispatch + combine token bytes
        b = model_bulk(flops, hbm, wire)
        f = model_fused(flops, hbm, wire, chunks=16)
        report(f"gemm_a2a_v5e_model_D{D}xF{F}", f * 1e6,
               f"bulk_us={b*1e6:.1f};reduction_pct={pct_reduction(b, f):.1f}")
    return reductions
