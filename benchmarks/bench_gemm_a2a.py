"""Paper Fig. 10: MoE expert GEMM + All-to-All combine, fused vs bulk.

The paper reports 12% avg (20% max) lower execution time with a generic
Triton GEMM (compute-dominated, which bounds the win).

End-to-end sweep: the full MoE hot path dispatch A2A -> expert FFN ->
combine A2A, measured for three executions of the same math —

  bulk    - bulk dispatch collective, full FFN, bulk combine collective
  fused   - XLA-level decomposition: both A2As chunked and overlapped by
            the latency-hiding scheduler (the paper's technique)
  chained - device-initiated Pallas chain: the dispatch-side PUT-ring
            kernel feeding the FFN+combine kernel (``fused_moe_kernel``)

Each variant is wall-clock timed on the host mesh and projected under a
slow-link (DCN) alpha-beta model where wire exposure dominates — the
regime device-initiated fusion targets.  Machine-readable output:
``BENCH_moe_e2e.json``; the schema validation pins the acceptance
invariant ``chained <= bulk`` on the modeled slow-link times on every
write (the CPU interpreter's measured times are software-emulation
artifacts and are recorded but not pinned).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import model_bulk, model_fused, pct_reduction, timeit
from repro.core.perfmodel import DCN

JSON_PATH = "BENCH_moe_e2e.json"

SCHEMA_KEYS = {"measured", "modeled", "invariant_chained_le_bulk",
               "workload"}

# modeled e2e workload: deepseek-v3-like expert shard on a 16-way EP ring
# over a slow DCN link — tokens x d_model x d_ff, three GEMMs, both A2As
TOK, DM, DF, NDEV = 4096, 7168, 2048, 16


def _modeled_e2e(q: int):
    flops = 2.0 * 3.0 * TOK * DM * DF / NDEV
    hbm = 3.0 * DM * DF * 2.0            # expert weights read once (bf16)
    wire = 2.0 * TOK * DM * 2.0 / NDEV   # dispatch + combine token bytes
    bulk = model_bulk(flops, hbm, wire, hw=DCN)
    chained = model_fused(flops, hbm, wire, chunks=NDEV * q, hw=DCN)
    return bulk, chained


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"{JSON_PATH} schema rot: missing {missing}"
    for section in ("measured", "modeled"):
        assert out[section], f"empty {section} section"
    mod = out["modeled"]
    assert mod["chained"] <= mod["bulk"], (
        f"device-initiated chain regressed vs bulk under the slow-link "
        f"model: {mod}")
    assert out["invariant_chained_le_bulk"]


def run(report, smoke=False):
    import jax

    from repro.core.moe_all_to_all import (fused_expert_ffn_combine,
                                           moe_dispatch_all_to_all)
    from repro.kernels.fused_gemm_a2a import fused_moe_kernel
    from repro.launch.mesh import make_host_mesh

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    tkw = dict(iters=2, warmup=1) if smoke else {}
    reductions = []
    shapes = [(16, 64, 128)] if smoke else [(16, 64, 128), (32, 128, 256)]
    for C, D, F in shapes:
        n_ep, E = 4, 8
        xd = rng.standard_normal((8, n_ep, E, C, D)).astype(np.float32)
        wu = rng.standard_normal((E, D, F)).astype(np.float32)
        wg = rng.standard_normal((E, D, F)).astype(np.float32)
        wd = rng.standard_normal((E, F, D)).astype(np.float32)
        fns = {m: jax.jit(lambda x, m=m: fused_expert_ffn_combine(
            ctx, x, wu, wg, wd, act=jax.nn.silu, mode=m))
            for m in ["bulk", "fused"]}
        t = {m: timeit(fns[m], xd, **tkw) for m in fns}
        red = pct_reduction(t["bulk"], t["fused"])
        report(f"gemm_a2a_cpu_proxy_C{C}xD{D}", t["fused"] * 1e6,
               f"bulk_us={t['bulk']*1e6:.1f};reduction_pct={red:.1f}")
        reductions.append(red)

    # projection: expert shards (dbrx-like / deepseek-v3-like), tp=16
    for tok, D, F in [(4096, 6144, 10752), (4096, 7168, 2048)]:
        flops = 2 * 3 * tok * D * F / 16
        hbm = 3 * D * F * 2            # expert weights read once (bf16)
        wire = tok * D * 2 / 16 * 2    # dispatch + combine token bytes
        b = model_bulk(flops, hbm, wire)
        f = model_fused(flops, hbm, wire, chunks=16)
        report(f"gemm_a2a_v5e_model_D{D}xF{F}", f * 1e6,
               f"bulk_us={b*1e6:.1f};reduction_pct={pct_reduction(b, f):.1f}")

    # ---- e2e dispatch -> FFN -> combine sweep ---------------------------
    out = {"measured": {}, "modeled": {}}
    C, D, F = (8, 16, 24) if smoke else (16, 32, 48)
    n_ep, E = ctx.tp, 2 * ctx.tp
    xd = rng.standard_normal((8, n_ep, E, C, D)).astype(np.float32)
    wu = rng.standard_normal((E, D, F)).astype(np.float32)
    wg = rng.standard_normal((E, D, F)).astype(np.float32)
    wd = rng.standard_normal((E, F, D)).astype(np.float32)

    def e2e(mode):
        def fn(x):
            disp = moe_dispatch_all_to_all(ctx, x, mode=mode)
            return fused_expert_ffn_combine(ctx, disp, wu, wg, wd,
                                            act=jax.nn.silu, mode=mode)
        return jax.jit(fn)

    variants = {
        "bulk": e2e("bulk"),
        "fused": e2e("fused"),
        "chained": jax.jit(lambda x: fused_moe_kernel(
            ctx, x, wu, wg, wd, act=jax.nn.silu)),
    }
    for name, fn in variants.items():
        t = timeit(fn, xd, **tkw)
        out["measured"][name] = t
        report(f"moe_e2e_measured_{name}", t * 1e6, f"C{C}xD{D}xF{F}")

    qs = [1, 2] if smoke else [1, 2, 4]
    per_q = {q: _modeled_e2e(q) for q in qs}
    bulk_t = per_q[qs[0]][0]
    chained_t = min(c for _, c in per_q.values())
    out["modeled"] = {"bulk": bulk_t, "chained": chained_t,
                      "per_q": {f"q{q}": c for q, (_, c) in per_q.items()}}
    report("moe_e2e_model_dcn_bulk", bulk_t * 1e6, "hw=dcn")
    report("moe_e2e_model_dcn_chained", chained_t * 1e6,
           f"reduction_pct={pct_reduction(bulk_t, chained_t):.1f}")

    out["invariant_chained_le_bulk"] = chained_t <= bulk_t
    out["workload"] = {
        "modeled": {"tok": TOK, "d_model": DM, "d_ff": DF, "n_dev": NDEV,
                    "dcn_bw": DCN.ici_bw},
        "measured": {"C": C, "D": D, "F": F, "n_ep": n_ep, "E": E,
                     "mesh": list(ctx.mesh.shape.values())},
    }
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("moe_e2e_json", 0.0, JSON_PATH)
    return reductions
