"""Benchmark utilities: host-mesh timing + v5e alpha-beta projection.

Two complementary measurements per paper figure:
  measured  - wall-clock on the local CPU device mesh (relative bulk-vs-
              fused ratios; the CPU backend executes the same collective
              schedule the HLO encodes).
  projected - alpha-beta roofline model with TPU v5e constants, fed by the
              exact per-chunk byte/flop counts of the op (the ASTRA-Sim
              analogue used for the scale-out figure).
"""
from __future__ import annotations

import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

PEAK_FLOPS = 197e12     # v5e bf16
HBM_BW = 819e9
ICI_BW = 50e9
ICI_LAT = 1e-6          # collective setup/launch latency (bulk boundary)
BOUNDARY = 2e-6         # kernel-boundary sync the fused form removes
CHUNK_OVERHEAD = 2e-7   # per-chunk issue cost (device-initiated comm is cheap
                        # -- the paper's point; ROC_SHMEM API ~ns-scale)


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _compute_time(flops, hbm_bytes):
    """Roofline compute time: MXU- or HBM-bound, whichever binds."""
    return max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW)


def model_bulk(flops, hbm_bytes, wire_bytes, *, bw=ICI_BW):
    """Bulk-synchronous: full compute kernel, boundary sync, collective."""
    return _compute_time(flops, hbm_bytes) + BOUNDARY + ICI_LAT + wire_bytes / bw


def model_fused(flops, hbm_bytes, wire_bytes, chunks, *, bw=ICI_BW,
                zero_copy_saving=0.0):
    """Fused: chunk i's wire time hides behind chunks i+1..n's compute.

    total = first chunk compute + max(rest compute, rest wire) +
            last chunk wire + per-chunk issue overhead - zero-copy saving."""
    c = _compute_time(flops, hbm_bytes)
    w = wire_bytes / bw + ICI_LAT
    per_c, per_w = c / chunks, w / chunks
    overlapped = per_c + max(c - per_c, w - per_w) + per_w
    return max(overlapped + chunks * CHUNK_OVERHEAD - zero_copy_saving, 0.0)


def pct_reduction(bulk, fused):
    return 100.0 * (bulk - fused) / bulk


def csv_row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
