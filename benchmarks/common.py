"""Benchmark utilities: host-mesh timing + v5e alpha-beta projection.

Two complementary measurements per paper figure:
  measured  - wall-clock on the local CPU device mesh (relative bulk-vs-
              fused ratios; the CPU backend executes the same collective
              schedule the HLO encodes).
  projected - alpha-beta roofline model with TPU v5e constants, fed by the
              exact per-chunk byte/flop counts of the op (the ASTRA-Sim
              analogue used for the scale-out figure).

The model itself lives in :mod:`repro.core.perfmodel` (promoted there so
the serve/train overlap autotuner shares the constants); this module
re-exports it for the benchmark scripts plus wall-clock helpers.
"""
from __future__ import annotations

import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.core.perfmodel import (  # noqa: F401  (re-exported)
    V5E,
    HardwareModel,
    model_bulk,
    model_fused,
    pct_reduction,
)

PEAK_FLOPS = V5E.peak_flops
HBM_BW = V5E.hbm_bw
ICI_BW = V5E.ici_bw
ICI_LAT = V5E.ici_lat
BOUNDARY = V5E.boundary
CHUNK_OVERHEAD = V5E.chunk_overhead


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _compute_time(flops, hbm_bytes):
    """Roofline compute time: MXU- or HBM-bound, whichever binds."""
    return V5E.compute_time(flops, hbm_bytes)


def csv_row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
