"""Chaos recovery: supervised training under seeded fault injection.

Runs the fused GEMM+AllReduce training workload on the 8-device host
mesh under :class:`~repro.runtime.chaos.FaultPlan` Bernoulli schedules at
increasing fault rates (0 / 5% / 15% per step — transient timeouts, slow
links, and NaN wire payloads), driven by the
:class:`~repro.runtime.fault_tolerance.TrainSupervisor` checkpoint/
restart/replay loop.  Records effective throughput, restart counts, and
whether the recovered run's final weights are *bit-identical* to the
fault-free run (same batches replayed through the same traces — the
recovery-correctness headline).

A second section forces the :class:`~repro.core.degrade.DegradationPolicy`
to quarantine the fused path and measures throughput of the demoted bulk
collective — the graceful-degradation invariant is that a chaos-stricken
op family keeps making progress (> 0 steps/s) on the bulk path.

Machine-readable output: ``BENCH_chaos.json`` (schema-validated on every
write).
"""
from __future__ import annotations

import itertools
import json
import shutil
import tempfile
import time

import numpy as np

JSON_PATH = "BENCH_chaos.json"

SCHEMA_KEYS = {"throughput", "restarts", "recovery", "degraded",
               "invariant_degraded_throughput_positive", "workload"}

RATES = (0.0, 0.05, 0.15)
CHAOS_KINDS = ("timeout", "slow_link", "nan_wire")


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"BENCH_chaos.json schema rot: missing {missing}"
    for rate in RATES:
        key = f"rate_{rate}"
        assert out["throughput"][key] > 0.0, \
            f"no forward progress at fault rate {rate}: {out['throughput']}"
        rec = out["recovery"][key]
        assert rec["completed_steps"] > 0, f"no steps completed at {rate}"
    # under 5% chaos the run must still finish every step
    assert out["recovery"]["rate_0.05"]["completed_steps"] == \
        out["workload"]["num_steps"], "5% chaos run did not complete"
    assert out["degraded"]["throughput"] > 0.0
    assert out["invariant_degraded_throughput_positive"]


def run(report, smoke=False):
    import jax
    import jax.numpy as jnp

    from repro.core.degrade import (DegradationPolicy,
                                    set_degradation_policy)
    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.chaos import FaultPlan
    from repro.runtime.fault_tolerance import (SupervisorConfig,
                                               TrainSupervisor)

    ctx = make_host_mesh()
    B, S, K = (4, 8, 16) if smoke else (4, 16, 32)
    N = K
    num_steps = 25 if smoke else 60
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((B, S, K)) * 0.1).astype(np.float32)
    w0 = (rng.standard_normal((K, N)) * 0.1).astype(np.float32)

    def make_step():
        # a fresh closure every call: rebuild_step must re-trace so the
        # NaN-wire hook lands (a cached jaxpr would replay clean)
        def raw(state, batch):
            y = matmul_allreduce(ctx, batch, state["w"])
            g = jnp.einsum("bsk,bsn->kn", batch, jnp.tanh(y))
            return ({"w": state["w"] - 1e-3 * g},
                    {"loss": jnp.mean(y * y)})

        return jax.jit(raw)

    out = {"throughput": {}, "restarts": {}, "recovery": {}}
    w_clean = None
    for rate in RATES:
        ckpt = tempfile.mkdtemp(prefix=f"bench_chaos_r{int(rate * 100)}_")
        plan = (None if rate == 0.0 else FaultPlan.from_rate(
            int(rate * 100), rate, num_steps, kinds=CHAOS_KINDS,
            delay_s=1e-3))
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_dir=ckpt, checkpoint_every=10,
                             keep=2, max_restarts=max(8, num_steps),
                             async_save=False, backoff_base_s=1e-4,
                             backoff_max_s=1e-3),
            make_step(), fault_plan=plan, rebuild_step=make_step,
            sleep_fn=lambda s: None)  # recorded, not slept: bench clock
        t0 = time.perf_counter()
        state, step = sup.run({"w": w0}, itertools.repeat(x), num_steps)
        dt = time.perf_counter() - t0
        wf = np.asarray(state["w"])
        if rate == 0.0:
            w_clean = wf
        key = f"rate_{rate}"
        out["throughput"][key] = step / dt
        out["restarts"][key] = sup.restarts
        out["recovery"][key] = {
            "completed_steps": step,
            "faults_injected": sup.faults_injected,
            "backoffs": len(sup.backoffs),
            # bit-identical recovery: restore + batch replay reruns the
            # identical trace on identical state
            "final_w_equal_clean": bool(np.array_equal(w_clean, wf)),
        }
        report(f"chaos_rate{rate}", dt / max(step, 1) * 1e6,
               f"steps={step};faults={sup.faults_injected};"
               f"restarts={sup.restarts};"
               f"bit_identical={out['recovery'][key]['final_w_equal_clean']}")
        shutil.rmtree(ckpt, ignore_errors=True)

    # ---- graceful degradation: quarantined fused path -> bulk -----------
    policy = DegradationPolicy()
    prev = set_degradation_policy(policy)
    try:
        fn = make_step()
        state = {"w": w0}
        state, m = fn(state, x)          # trace registers the active key
        float(m["loss"])
        policy.record_failure()
        policy.record_failure()          # 2 strikes -> quarantine
        assert policy.consume_dirty()
        fn = make_step()                 # re-trace: degrade_mode -> bulk
        iters = 5 if smoke else 20
        t0 = time.perf_counter()
        for _ in range(iters):
            state, m = fn(state, x)
        float(m["loss"])                 # block on the last step
        dt = time.perf_counter() - t0
        degraded_thr = iters / dt
        out["degraded"] = {"throughput": degraded_thr,
                           "policy": policy.summary()}
        report("chaos_degraded_bulk", dt / iters * 1e6,
               f"steps_per_s={degraded_thr:.1f};"
               f"quarantined={policy.summary()['quarantined']}")
    finally:
        set_degradation_policy(prev)

    out["invariant_degraded_throughput_positive"] = \
        out["degraded"]["throughput"] > 0.0
    out["workload"] = {"B": B, "S": S, "K": K, "N": N,
                       "num_steps": num_steps, "rates": list(RATES),
                       "kinds": list(CHAOS_KINDS),
                       "mesh": list(ctx.mesh.shape.values())}
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("chaos_json", 0.0, JSON_PATH)
    return out["throughput"]
