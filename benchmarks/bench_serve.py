"""SLO serving benchmark: Poisson request stream through the paged engine.

Drives :class:`~repro.serve.engine.PagedDecodeEngine` (chunked prefill +
paged KV + per-slot positions, the continuous-batching substrate for the
paper's fused GEMV+AllReduce decode) with open-loop Poisson arrivals at
increasing request rates and reports the SLO-facing latency tails:

* **TTFT** (time to first token: submission -> first sampled token,
  includes queueing + chunked prefill) p50/p99 per rate;
* **per-token latency** (TPOT: inter-token time after the first) p50/p99;
* throughput (generated tokens / wall second) per rate.

A mixed-length (ragged) workload also compares the paged pool's HBM
footprint against the dense ``B x S_max`` cache the engine replaced —
the paged invariant is strictly smaller allocation at equal capacity to
serve the workload.

Machine-readable output: ``BENCH_serve.json`` (schema-validated on every
write; CI runs ``--smoke`` and re-validates).
"""
from __future__ import annotations

import json
import time

import numpy as np

JSON_PATH = "BENCH_serve.json"

SCHEMA_KEYS = {"rates", "hbm", "workload",
               "invariant_paged_hbm_lt_dense"}
RATE_KEYS = {"ttft_ms", "tpot_ms", "throughput_tok_s", "completed",
             "drained", "offered_rate_req_s"}


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"BENCH_serve.json schema rot: missing {missing}"
    assert len(out["rates"]) >= 3, \
        f"need >= 3 Poisson rates, got {list(out['rates'])}"
    for key, r in out["rates"].items():
        rmissing = RATE_KEYS - set(r)
        assert not rmissing, f"{key} missing {rmissing}"
        assert r["completed"] > 0, f"no requests completed at {key}"
        assert r["drained"], f"{key} did not drain"
        for lat in ("ttft_ms", "tpot_ms"):
            assert r[lat]["p50"] > 0.0 and r[lat]["p99"] >= r[lat]["p50"], \
                f"{key} {lat} percentiles inconsistent: {r[lat]}"
    assert out["hbm"]["paged_bytes"] < out["hbm"]["dense_bytes"], \
        f"paged pool not smaller than dense cache: {out['hbm']}"
    assert out["invariant_paged_hbm_lt_dense"]


def _percentiles(xs):
    return {"p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99))}


def _drive_poisson(engine, requests, arrivals, *, max_steps):
    """Open-loop driver: submit each request at its arrival time, step
    the engine whenever it has work, sleep to the next arrival when idle."""
    t0 = time.monotonic()
    pending = list(zip(arrivals, requests))
    finished = []
    steps = 0
    while (pending or engine._pending()) and steps < max_steps:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            engine.submit(pending.pop(0)[1])
        if engine._pending():
            _, fin = engine.step()
            finished.extend(fin)
            steps += 1
        elif pending:
            time.sleep(min(0.05, max(0.0, pending[0][0] - now)))
    wall = time.monotonic() - t0
    return finished, wall, not pending and not engine._pending()


def run(report, smoke=False):
    import jax

    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.models.common import split_params
    from repro.serve.engine import PagedDecodeEngine, Request
    from repro.serve.kv_cache import dense_cache_hbm_bytes, pool_hbm_bytes

    ctx = make_host_mesh()
    bundle = get_arch("chatglm3-6b").reduced()
    cfg = bundle.config
    params, _ = split_params(bundle.init_params(jax.random.PRNGKey(0)))
    serve_fn = bundle.serve_step_fn(ctx)
    serve_jit = jax.jit(
        lambda t, pl, tb, pos, nn: serve_fn(params, t, pl, tb, pos, nn))

    batch = 4
    block_size = 8
    # half the dense B x S_max token budget, tp-aligned — the ragged
    # workload fits because retired requests return their blocks
    num_blocks = (batch * cfg.max_seq // 2) // block_size // ctx.tp * ctx.tp
    chunk = 8
    n_req = 6 if smoke else 24
    max_new = 4 if smoke else 8
    rates = (4.0, 16.0, 64.0)

    def make_engine():
        return PagedDecodeEngine(
            serve_jit, bundle.init_paged_pool, batch,
            num_blocks=num_blocks, block_size=block_size,
            max_seq=cfg.max_seq, chunk=chunk, n_stripes=ctx.tp)

    # warm both traced graphs (C=chunk prefill, C=1 decode) out of band so
    # the first measured request does not pay compile time in its TTFT
    warm = make_engine()
    warm.submit(Request(uid=-1, prompt=list(range(2 * chunk)), max_new=2))
    warm.run_until_drained(max_steps=100)

    rng = np.random.default_rng(0)
    out = {"rates": {}}
    for rate in rates:
        engine = make_engine()
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab,
                                            rng.integers(1, 25)).tolist(),
                        max_new=int(rng.integers(2, max_new + 1)))
                for i in range(n_req)]
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_req))
        finished, wall, drained = _drive_poisson(
            engine, reqs, arrivals.tolist(), max_steps=50_000)
        ttft = [(r.t_first - r.t_submit) * 1e3 for r in finished
                if r.t_first is not None]
        tpot = [(r.t_done - r.t_first) / max(1, len(r.tokens) - 1) * 1e3
                for r in finished if r.t_first is not None]
        toks = sum(len(r.tokens) for r in finished)
        key = f"rate_{rate}"
        out["rates"][key] = {
            "offered_rate_req_s": rate,
            "completed": len(finished),
            "drained": bool(drained),
            "throughput_tok_s": toks / max(wall, 1e-9),
            "ttft_ms": _percentiles(ttft),
            "tpot_ms": _percentiles(tpot),
        }
        report(f"serve_rate{rate:g}", wall / max(toks, 1) * 1e6,
               f"p50_ttft_ms={out['rates'][key]['ttft_ms']['p50']:.1f};"
               f"p99_ttft_ms={out['rates'][key]['ttft_ms']['p99']:.1f};"
               f"tok_s={out['rates'][key]['throughput_tok_s']:.1f}")

    # ---- paged vs dense HBM for the ragged workload ---------------------
    paged_bytes = pool_hbm_bytes(make_engine().pool)
    dense_bytes = dense_cache_hbm_bytes(bundle.init_cache(batch))
    out["hbm"] = {
        "paged_bytes": paged_bytes,
        "dense_bytes": dense_bytes,
        "num_blocks": num_blocks,
        "block_size": block_size,
        "ratio": paged_bytes / dense_bytes,
    }
    out["invariant_paged_hbm_lt_dense"] = paged_bytes < dense_bytes
    report("serve_hbm", 0.0,
           f"paged={paged_bytes};dense={dense_bytes};"
           f"ratio={paged_bytes / dense_bytes:.2f}")

    out["workload"] = {
        "arch": "chatglm3-6b(reduced)", "batch": batch,
        "num_requests": n_req, "max_new": max_new,
        "prompt_len_range": [1, 24], "chunk": chunk,
        "max_seq": cfg.max_seq, "rates": list(rates),
        "mesh": list(ctx.mesh.shape.values()),
    }
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("serve_json", 0.0, JSON_PATH)
    return out["rates"]
