"""Paper Fig. 8 & 12: embedding pooling + All-to-All, fused vs bulk,
swept over {global batch | tables per device} like the paper's labels.

Paper: 20% avg intra-node (up to 32%), 31% avg inter-node (up to 58%).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import model_bulk, model_fused, pct_reduction, timeit


def run(report):
    import jax

    from repro.core.embedding_all_to_all import embedding_all_to_all
    from repro.launch.mesh import make_host_mesh

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    reductions = []
    V, D, L = 512, 32, 8
    for B, T in [(64, 8), (128, 8), (128, 16)]:
        idx = rng.integers(0, V, (B, T, L)).astype(np.int32)
        tabs = rng.standard_normal((T, V, D)).astype(np.float32)
        fns = {m: jax.jit(lambda i, t, m=m: embedding_all_to_all(ctx, i, t, mode=m))
               for m in ["bulk", "fused"]}
        t = {m: timeit(fns[m], idx, tabs) for m in fns}
        red = pct_reduction(t["bulk"], t["fused"])
        report(f"embed_a2a_cpu_proxy_b{B}_t{T}", t["fused"] * 1e6,
               f"bulk_us={t['bulk']*1e6:.1f};reduction_pct={red:.1f}")
        reductions.append(red)

    # projection at paper scale: dim 256, pooling 70, world 16.
    # Pooling is gather-bound (HBM); A2A wire is comparable -> overlap wins.
    # "ici" = v5e scale-up links; "ib20" = the paper's 20 GB/s inter-node.
    for B, T_per in [(512, 256), (1024, 256), (2048, 256), (4096, 256)]:
        world = 16
        flops = B * T_per * 70 * 256 * 2
        hbm = B * T_per * 70 * 256 * 4          # gathered rows (fp32)
        wire = B * T_per * 256 * 4 * (world - 1) / world
        for label, bw in [("ici", 50e9), ("ib20", 20e9)]:
            b = model_bulk(flops, hbm, wire, bw=bw)
            f = model_fused(flops, hbm, wire, chunks=world, bw=bw)
            report(f"embed_a2a_v5e_model_{label}_b{B}", f * 1e6,
                   f"bulk_us={b*1e6:.1f};reduction_pct={pct_reduction(b, f):.1f}")
    return reductions
