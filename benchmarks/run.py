"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only substr] [--smoke]

``--smoke`` asks benches that support it for a seconds-scale run (minimal
shapes/iters) — CI uses it to keep the machine-readable output schemas
honest without paying for a real sweep.
"""
import argparse
import inspect
import os
import sys
import traceback

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

BENCHES = [
    ("bench_gemv_allreduce", "Fig. 9  GEMV+AllReduce"),
    ("bench_gemm_a2a", "Fig. 10 GEMM+All-to-All (MoE)"),
    ("bench_embedding_a2a", "Fig. 8/12 embedding+All-to-All"),
    ("bench_scheduling", "Fig. 14 comm-aware scheduling skew"),
    ("bench_skew", "Fig. 14 measured-skew feedback loop"),
    ("bench_granularity", "Fig. 13 overlap granularity"),
    ("bench_wire", "compressed-wire rings (bf16/fp8 payloads)"),
    ("bench_chaos", "chaos recovery + degraded-mode throughput"),
    ("bench_serve", "SLO serving: Poisson TTFT/TPOT + paged-KV HBM"),
    ("bench_scaleout_sim", "Fig. 15 128-node DLRM scale-out sim"),
    ("bench_kernels", "device-initiated kernel comparison"),
    ("bench_elastic", "multi-process elastic recovery: MTTR + ring fit"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for mod_name, title in BENCHES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# {title}", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            report = lambda name, us, derived="": print(
                f"{name},{us:.1f},{derived}", flush=True)
            if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
                mod.run(report, smoke=True)
            else:
                mod.run(report)
        except Exception:
            failures += 1
            print(f"# FAILED {mod_name}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
