"""Compressed-wire sweep: wire dtype x granularity under a slow-DCN axis.

The wire knob pays where wire time is exposed: this bench sweeps
``(wire, chunks_per_rank)`` for the row-parallel GEMM+AllReduce workload
under the hierarchical :class:`~repro.core.perfmodel.MeshHardwareModel`
(a fast ICI axis and a slow DCN pod axis), *measures* the real XLA-fused
op on the host mesh at every point (capturing the actual cast overhead),
and records everything in machine-readable ``BENCH_wire.json``.

The combined metric adds the slow-axis *modeled wire exposure* (the part
of the fused time the alpha-beta model attributes to the wire, which the
CPU host mesh cannot reproduce) to the *measured* host time (which the
model cannot know) — so the acceptance invariant ``bf16 <= f32 on the
slow axis`` is checked against both worlds at once, and the schema
validation pins it on every write.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.perfmodel import (DCN, V5E, MeshHardwareModel, model_fused,
                                  resolve_hw)
from benchmarks.common import timeit

JSON_PATH = "BENCH_wire.json"

# model workload: v5e, row-parallel GEMM 16384 tokens x (32768/8 -> 16384),
# f32 activations — wire-heavy on the DCN axis (the regime the knob
# targets), and big enough that the modeled slow-axis exposure delta
# dwarfs host-mesh measurement noise (the CPU backend software-emulates
# bf16, so its cast overhead is an artifact the model workload must not
# be sensitive to)
ROWS, K_LOC, NOUT, NDEV, DTYPE_BYTES = 16384, 4096, 16384, 8, 4
FLOPS = 2.0 * ROWS * K_LOC * NOUT
HBM = float(K_LOC * NOUT * DTYPE_BYTES)
WIRE_BYTES = float(ROWS * NOUT * DTYPE_BYTES) * 2.0   # RS carry + AG
WIRES = ["f32", "bf16", "fp8"]
WIRE_FACTOR = {"f32": 1.0, "bf16": 0.5, "fp8": 0.25}

MESH_HW = MeshHardwareModel.for_mesh_axes(("pod", "data", "model"),
                                          ici=V5E, dcn=DCN)

SCHEMA_KEYS = {"modeled", "measured", "combined", "auto_choice",
               "invariant_bf16_le_f32_slow_axis", "workload"}


def _modeled(axis: str, wire: str, chunks: int) -> float:
    hw = resolve_hw(MESH_HW, axis)
    return model_fused(FLOPS, HBM, WIRE_BYTES * WIRE_FACTOR[wire], chunks,
                       hw=hw)


def _wire_exposure(axis: str, wire: str, chunks: int) -> float:
    """The slice of the modeled fused time the wire is responsible for:
    the same schedule with zero wire bytes subtracted out."""
    return _modeled(axis, wire, chunks) - model_fused(FLOPS, HBM, 0.0,
                                                      chunks,
                                                      hw=resolve_hw(
                                                          MESH_HW, axis))


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"BENCH_wire.json schema rot: missing {missing}"
    for section in ("modeled", "measured", "combined"):
        assert out[section], f"empty {section} section"
    # the acceptance invariant: on the slow (DCN) axis, shipping bf16
    # must not model+measure slower than shipping f32
    comb = out["combined"]
    best = {w: min(comb[w].values()) for w in comb}
    assert best["bf16"] <= best["f32"], (
        f"bf16 wire regressed on the slow axis: {best}")
    assert out["invariant_bf16_le_f32_slow_axis"]


def run(report, smoke=False):
    import jax

    from repro.core.autotune import clear_cache, tune_matmul_allreduce
    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.launch.mesh import make_host_mesh

    out = {"modeled": {}, "measured": {}, "combined": {}}
    chunk_ladder = [1, 2, 4, 8] if smoke else [1, 2, 4, 8, 16, 32]

    # ---- modeled: both axes, every wire ---------------------------------
    for axis, tag in (("model", "ici"), ("pod", "dcn")):
        for w in WIRES:
            for c in chunk_ladder:
                t = _modeled(axis, w, c * NDEV)
                out["modeled"][f"{tag}_{w}_q{c}"] = t
            report(f"wire_model_{tag}_{w}",
                   min(_modeled(axis, w, c * NDEV)
                       for c in chunk_ladder) * 1e6,
                   f"axis={tag}")

    # ---- measured: host mesh, real cast overhead ------------------------
    ctx = make_host_mesh()
    n = ctx.tp
    rng = np.random.default_rng(0)
    B, S, K, N = (4, 16, 32, 32) if smoke else (4, 64, 256, 256)
    tkw = dict(iters=2, warmup=1) if smoke else {}
    x = rng.standard_normal((B, S, K)).astype(np.float32)
    wmat = rng.standard_normal((K, N)).astype(np.float32)
    rows_local = B * S // ctx.dp
    qs = [q for q in ([1, 2] if smoke else [1, 2, 4])
          if rows_local % (n * q) == 0] or [1]
    for w in WIRES:
        out["measured"][w] = {}
        out["combined"][w] = {}
        for q in qs:
            fn = jax.jit(lambda x, wm, q=q, w=w: matmul_allreduce(
                ctx, x, wm, mode="fused", chunks_per_rank=q, wire=w))
            t = timeit(fn, x, wmat, **tkw)
            out["measured"][w][f"q{q}"] = t
            # combined = measured host time + the slow axis's modeled wire
            # exposure (what the CPU mesh cannot show)
            out["combined"][w][f"q{q}"] = t + _wire_exposure(
                "pod", w, q * NDEV)
            report(f"wire_measured_{w}_q{q}", t * 1e6,
                   f"combined_us={out['combined'][w][f'q{q}'] * 1e6:.1f}")

    # ---- autotuned joint choice on the slow axis ------------------------
    clear_cache()
    dec = tune_matmul_allreduce(ROWS, K_LOC, NOUT, dtype_bytes=DTYPE_BYTES,
                                n_dev=NDEV, chunk_dim=ROWS, hw=MESH_HW,
                                axis="pod", wire="auto")
    out["auto_choice"] = {"q": dec.q, "wire": dec.wire}
    report("wire_auto_choice_slow_axis", 0.0, f"q={dec.q};wire={dec.wire}")
    clear_cache()

    best = {w: min(out["combined"][w].values()) for w in WIRES}
    out["invariant_bf16_le_f32_slow_axis"] = best["bf16"] <= best["f32"]
    out["workload"] = {"rows": ROWS, "k_local": K_LOC, "n_out": NOUT,
                       "n_dev": NDEV, "dtype_bytes": DTYPE_BYTES,
                       "measured": {"B": B, "S": S, "K": K, "N": N,
                                    "mesh": list(ctx.mesh.shape.values())},
                       "hw": {"ici_bw": V5E.ici_bw, "dcn_bw": DCN.ici_bw}}
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("wire_json", 0.0, JSON_PATH)
    return out["auto_choice"]
