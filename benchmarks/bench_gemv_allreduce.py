"""Paper Fig. 9: GEMV + AllReduce, fused vs bulk-synchronous.

Measured on the host mesh at reduced sizes; projected at the paper's
matrix sizes (M = 8k..64k) with the v5e alpha-beta model.  The paper
reports 13% avg (22% max) lower execution time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import HBM_BW, model_bulk, model_fused, pct_reduction, timeit


def run(report):
    import jax

    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.launch.mesh import make_host_mesh

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    reductions = []
    for K, N in [(512, 512), (1024, 1024), (2048, 2048)]:
        x = rng.standard_normal((1, 1, K)).astype(np.float32)
        w = rng.standard_normal((K, N)).astype(np.float32)
        fns = {m: jax.jit(lambda x, w, m=m: matmul_allreduce(ctx, x, w, mode=m))
               for m in ["bulk", "fused"]}
        t = {m: timeit(fns[m], x, w) for m in fns}
        red = pct_reduction(t["bulk"], t["fused"])
        report(f"gemv_ar_cpu_proxy_{K}x{N}", t["fused"] * 1e6,
               f"bulk_us={t['bulk']*1e6:.1f};reduction_pct={red:.1f}")
        reductions.append(red)

    # projection at paper scale (per-device shard of M x M GEMV, tp=16).
    # GEMV is HBM-bound: compute time = weight bytes / HBM bw.
    for M in [8192, 16384, 32768, 65536]:
        flops = 2 * M * M / 16
        hbm = M * M * 2 / 16          # bf16 weight shard read once
        wire = M * 2 * 2              # reduce-scatter + broadcast, bf16
        b = model_bulk(flops, hbm, wire)
        f = model_fused(flops, hbm, wire, chunks=16,
                        zero_copy_saving=M * 2 / HBM_BW)
        report(f"gemv_ar_v5e_model_M{M}", f * 1e6,
               f"bulk_us={b*1e6:.2f};reduction_pct={pct_reduction(b, f):.1f}")
    return reductions
