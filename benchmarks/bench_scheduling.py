"""Paper Fig. 14: communication-aware vs -oblivious scheduling skew.

The paper measures ~7% inter-node execution skew with oblivious
scheduling vs ~1% with comm-aware.  We measure wall-clock of the fused
embedding+A2A under both schedules and compute the modelled exposed-wire
difference (the skew mechanism: remote slices computed last leave their
wire time exposed to the consumer).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ICI_BW, PEAK_FLOPS, pct_reduction, timeit


def run(report):
    import jax

    from repro.core.embedding_all_to_all import embedding_all_to_all
    from repro.launch.mesh import make_host_mesh

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    V, D, L, B, T = 512, 32, 8, 128, 8
    idx = rng.integers(0, V, (B, T, L)).astype(np.int32)
    tabs = rng.standard_normal((T, V, D)).astype(np.float32)
    t = {}
    for sched in ["comm_aware", "oblivious"]:
        fn = jax.jit(lambda i, tb, s=sched: embedding_all_to_all(
            ctx, i, tb, mode="fused", schedule=s))
        t[sched] = timeit(fn, idx, tabs)
    report("sched_measured_comm_aware", t["comm_aware"] * 1e6,
           f"oblivious_us={t['oblivious']*1e6:.1f};"
           f"aware_faster_pct={pct_reduction(t['oblivious'], t['comm_aware']):.1f}")

    # modelled skew: oblivious exposes the last remote chunk's wire time
    world, chunk_bytes, chunk_flops = 16, 2048 * 256 * 2 / 16, 2048 * 256 * 70 * 2 / 16
    c = chunk_flops / PEAK_FLOPS
    w = chunk_bytes / ICI_BW
    total_aware = world * c + w              # wire hidden behind later chunks
    total_obliv = world * c + (world - 1) * 0 + w * min(world - 1, 3)
    skew_aware = w / total_aware * 100
    skew_obliv = w * 3 / total_obliv * 100
    report("sched_model_skew", skew_aware,
           f"oblivious_skew_pct={skew_obliv:.1f};aware_skew_pct={skew_aware:.1f}")
    return t
