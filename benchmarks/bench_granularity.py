"""Paper Fig. 13 analogue: overlap granularity sweep.

The paper sweeps GPU occupancy (slice size) and finds a sweet spot below
the maximum: finer slices overlap better until per-slice overhead and
contention win.  Our knob is ring-chunk count; we sweep it in the
alpha-beta model and measure two points on the host mesh.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import model_fused, model_bulk, timeit


def run(report):
    import jax

    from repro.launch.mesh import make_host_mesh
    from repro.core.matmul_allreduce import matmul_allreduce

    # model: v5e, row-parallel GEMM 4096 tokens x (14336/16 -> 4096)
    flops = 2 * 4096 * 14336 / 16 * 4096
    hbm = 14336 / 16 * 4096 * 2
    wire = 4096 * 4096 * 2 * 2 / 16
    best = None
    for chunks in [1, 2, 4, 8, 16, 32, 64, 128]:
        t = model_fused(flops, hbm, wire, chunks)
        report(f"granularity_model_chunks{chunks}", t * 1e6,
               f"bulk_us={model_bulk(flops, hbm, wire)*1e6:.1f}")
        if best is None or t < best[1]:
            best = (chunks, t)
    report("granularity_model_best", best[1] * 1e6, f"chunks={best[0]}")

    ctx = make_host_mesh()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 64, 256)).astype(np.float32)
    w = rng.standard_normal((256, 256)).astype(np.float32)
    for mode in ["bulk", "fused"]:
        fn = jax.jit(lambda x, w, m=mode: matmul_allreduce(ctx, x, w, mode=m))
        report(f"granularity_measured_{mode}", timeit(fn, x, w) * 1e6, "")
    return best
