"""Paper Fig. 13 analogue: overlap granularity sweep.

The paper sweeps GPU occupancy (slice size) and finds a sweet spot below
the maximum: finer slices overlap better until per-slice overhead and
contention win.  Our knob is ``chunks_per_rank``; we sweep it in the
alpha-beta model *and* measure the real XLA-fused op at every feasible
granularity on the 8-device host mesh, then record everything in
machine-readable ``BENCH_granularity.json`` (the autotuner's modeled
choice included, so regressions in the model/measurement agreement are
diffable across commits).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import model_fused, model_bulk, timeit

JSON_PATH = "BENCH_granularity.json"

# model workload: v5e, row-parallel GEMM 4096 tokens x (14336/16 -> 4096)
MODEL_FLOPS = 2 * 4096 * 14336 / 16 * 4096
MODEL_HBM = 14336 / 16 * 4096 * 2
MODEL_WIRE = 4096 * 4096 * 2 * 2 / 16

# the machine-readable contract: consumers (CI, cross-commit diffs) key on
# these — validated before every write so the schema cannot rot silently
SCHEMA_KEYS = {"model", "measured", "model_best_chunks", "model_bulk",
               "model_monotone_to_optimum", "autotuner_choice_q", "workload"}


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"BENCH_granularity.json schema rot: missing {missing}"
    assert out["model"] and out["measured"], "empty sweep sections"
    assert "bulk" in out["measured"]
    assert any(k.startswith("fused_q") for k in out["measured"])


def run(report, smoke=False):
    import jax

    from repro.core.autotune import clear_cache, tune_matmul_allreduce
    from repro.launch.mesh import make_host_mesh
    from repro.core.matmul_allreduce import matmul_allreduce

    out = {"model": {}, "measured": {}}

    best = None
    for chunks in [1, 2, 4, 8, 16, 32, 64, 128]:
        t = model_fused(MODEL_FLOPS, MODEL_HBM, MODEL_WIRE, chunks)
        out["model"][str(chunks)] = t
        report(f"granularity_model_chunks{chunks}", t * 1e6,
               f"bulk_us={model_bulk(MODEL_FLOPS, MODEL_HBM, MODEL_WIRE)*1e6:.1f}")
        if best is None or t < best[1]:
            best = (chunks, t)
    report("granularity_model_best", best[1] * 1e6, f"chunks={best[0]}")
    out["model_best_chunks"] = best[0]
    out["model_bulk"] = model_bulk(MODEL_FLOPS, MODEL_HBM, MODEL_WIRE)
    # acceptance: fused time monotonically improves from 1 chunk up to the
    # modeled optimum (then per-chunk overhead wins)
    ladder = [out["model"][str(c)] for c in [1, 2, 4, 8, 16, 32, 64, 128]
              if c <= best[0]]
    out["model_monotone_to_optimum"] = all(
        a >= b for a, b in zip(ladder, ladder[1:]))

    # ---- measured sweep on the host mesh -------------------------------
    # --smoke: minimal shapes/iters — exists so CI can exercise the whole
    # path (sweep -> schema validation -> JSON write) in seconds
    ctx = make_host_mesh()
    n = ctx.tp
    rng = np.random.default_rng(0)
    B, S, K, N = (4, 16, 32, 32) if smoke else (4, 64, 256, 256)
    tkw = dict(iters=2, warmup=1) if smoke else {}
    x = rng.standard_normal((B, S, K)).astype(np.float32)
    w = rng.standard_normal((K, N)).astype(np.float32)

    fn_bulk = jax.jit(lambda x, w: matmul_allreduce(ctx, x, w, mode="bulk"))
    t_bulk = timeit(fn_bulk, x, w, **tkw)
    out["measured"]["bulk"] = t_bulk
    report("granularity_measured_bulk", t_bulk * 1e6, "")

    rows_local = B * S // ctx.dp
    for q in [1, 2] if smoke else [1, 2, 4, 8]:
        if rows_local % (n * q):
            continue
        fn = jax.jit(lambda x, w, q=q: matmul_allreduce(
            ctx, x, w, mode="fused", chunks_per_rank=q))
        t = timeit(fn, x, w, **tkw)
        out["measured"][f"fused_q{q}"] = t
        report(f"granularity_measured_fused_q{q}", t * 1e6,
               f"bulk_us={t_bulk*1e6:.1f}")

    clear_cache()
    out["autotuner_choice_q"] = tune_matmul_allreduce(
        4096, 14336 // 16, 4096, dtype_bytes=2, n_dev=16, chunk_dim=4096).q
    out["workload"] = {"model": {"flops": MODEL_FLOPS, "hbm": MODEL_HBM,
                                 "wire": MODEL_WIRE},
                       "measured": {"B": B, "S": S, "K": K, "N": N,
                                    "mesh": list(ctx.mesh.shape.values())}}
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("granularity_json", 0.0, JSON_PATH)
    return best
