"""Kernel micro-benchmarks (interpret mode: correctness-level timing) and
the device-initiated fused kernel vs XLA-fused vs bulk comparison."""
from __future__ import annotations

import numpy as np

from benchmarks.common import pct_reduction, timeit
from repro.compat import make_mesh


def run(report):
    import jax

    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import ParallelContext

    m = make_mesh((8,), ("model",))
    ctx1d = ParallelContext.from_mesh(m)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 64)).astype(np.float32)
    w = rng.standard_normal((64, 128)).astype(np.float32)
    t_bulk = timeit(jax.jit(lambda x, w: matmul_allreduce(ctx1d, x, w, mode="bulk")), x, w, iters=5)
    t_fused = timeit(jax.jit(lambda x, w: matmul_allreduce(ctx1d, x, w, mode="fused")), x, w, iters=5)
    t_kernel = timeit(jax.jit(lambda x, w: fused_matmul_allreduce(ctx1d, x, w)), x, w, iters=2)
    report("kernel_gemv_ar_bulk", t_bulk * 1e6, "")
    report("kernel_gemv_ar_fused_xla", t_fused * 1e6, "")
    report("kernel_gemv_ar_fused_dma_interp", t_kernel * 1e6,
           "interpret-mode (correctness proxy, not perf)")

    from repro.kernels.fused_embedding_a2a.ops import fused_embedding_a2a

    idx = rng.integers(0, 32, (16, 16, 4)).astype(np.int32)
    tabs = rng.standard_normal((16, 32, 16)).astype(np.float32)
    t_edma = timeit(jax.jit(lambda i, t: fused_embedding_a2a(ctx1d, i, t)),
                    idx, tabs, iters=2)
    report("kernel_embed_a2a_fused_dma_interp", t_edma * 1e6,
           "interpret-mode (correctness proxy, not perf)")
    return t_kernel
