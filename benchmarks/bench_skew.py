"""Paper Fig. 14: comm-aware vs -oblivious scheduling skew, with the
measured straggler rotation closing the loop.

The paper measures ~7% inter-node execution skew with oblivious
scheduling vs ~1% with comm-aware.  This bench reproduces the comparison
under an *injected 1.5x per-rank delay* on the 8-device mesh:

  1. modeled: per-rank finish times of the fused direct-A2A schedule
     (``repro.core.scheduling.modeled_finish_times``) under the injected
     delay and a slow (DCN/pod-boundary-style) link, reduced to the
     rate-normalized max/median-1 execution-skew statistic for the
     oblivious baseline, the static comm-aware schedule, and comm-aware
     plus the rotation the ``SkewEstimator`` derives from the *measured*
     (injected) step times — the full telemetry -> bucket -> schedule
     loop.
  2. measured parity: the fused ops execute on the real 8-device host
     mesh under every tested skew bucket and must match the bulk
     reference — the A2A/reduce-scatter families bit-identically.
  3. wall-clock: comm-aware vs oblivious fused matmul+AllReduce on the
     host mesh (the Fig. 14 flavor measurement).

Everything lands in machine-readable ``BENCH_skew.json`` so the
acceptance invariant (comm-aware + measured skew < oblivious) is
diffable across commits.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import timeit

JSON_PATH = "BENCH_skew.json"

WORLD = 8
DELAYED_RANK = 5
DELAY = 1.5
# slow link between ranks 4 and 5 (the pod/DCN boundary of a 2-pod ring)
LINK_SCALE = [1.0, 1.0, 1.0, 1.0, 4.0, 1.0, 1.0, 1.0]

SCHEMA_KEYS = {"modeled", "estimator", "measured_parity", "measured",
               "workload"}


def _validate(out):
    missing = SCHEMA_KEYS - set(out)
    assert not missing, f"BENCH_skew.json schema rot: missing {missing}"
    m = out["modeled"]
    assert m["comm_aware_measured"] < m["oblivious"], \
        "comm-aware + measured skew must beat the oblivious baseline"
    assert m["comm_aware_measured"] <= m["comm_aware"] + 1e-12
    assert out["measured_parity"]["parity_ok"]
    assert out["measured_parity"]["bit_identical_ok"]


def run(report, smoke=False):
    import jax

    from repro.core.scheduling import modeled_execution_skew
    from repro.core.matmul_allreduce import matmul_allreduce
    from repro.core.moe_all_to_all import moe_dispatch_all_to_all
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.straggler import SkewEstimator

    out = {}

    # ---- 1. modeled skew under the injected delay ----------------------
    times = [1.0] * WORLD
    times[DELAYED_RANK] = DELAY

    est = SkewEstimator({"ring": WORLD}, link_scales={"ring": LINK_SCALE})
    n_obs = 0
    for _ in range(4):
        est.observe(times)
        n_obs += 1
    rot = est.rotation("ring")

    stats = {
        "oblivious": modeled_execution_skew(
            WORLD, "oblivious", 0, times, link_scale=LINK_SCALE),
        "comm_aware": modeled_execution_skew(
            WORLD, "comm_aware", 0, times, link_scale=LINK_SCALE),
        "comm_aware_measured": modeled_execution_skew(
            WORLD, "comm_aware", rot, times, link_scale=LINK_SCALE),
    }
    out["modeled"] = dict(stats, rotation=rot)
    out["estimator"] = {"rotation": rot, "observations": n_obs,
                        "axis_skew": est.axis_skew("ring"),
                        "delayed_rank": DELAYED_RANK, "delay": DELAY}
    for name, s in stats.items():
        report(f"skew_model_{name}", s * 100,
               f"pct_skew;rotation={rot if name.endswith('measured') else 0}")
    report("skew_model_reduction_vs_oblivious",
           (1 - stats["comm_aware_measured"] / stats["oblivious"]) * 100,
           "pct")

    # ---- 2. parity on the real mesh under every tested bucket ----------
    ctx = make_host_mesh()
    n = ctx.tp
    rng = np.random.default_rng(0)
    B, S, K, N = (2, 16, 16, 32) if smoke else (4, 16, 32, 64)
    x = rng.standard_normal((B, S, K)).astype(np.float32)
    w = rng.standard_normal((K, N)).astype(np.float32)
    ref = np.asarray(jax.jit(
        lambda: matmul_allreduce(ctx, x, w, mode="bulk"))())
    xd = rng.standard_normal((2, 4, 8, 4, 8)).astype(np.float32)
    a2a_ref = np.asarray(jax.jit(
        lambda: moe_dispatch_all_to_all(ctx, xd, mode="bulk"))())

    buckets = sorted({0, rot % max(n - 1, 1), n - 1})
    parity_ok, bit_ok = True, True
    base_mm = base_a2a = None
    for sk in buckets:
        y = np.asarray(jax.jit(lambda sk=sk: matmul_allreduce(
            ctx, x, w, mode="fused", chunks_per_rank=2, skew=sk))())
        parity_ok &= np.allclose(y, ref, rtol=3e-4, atol=3e-4)
        ya = np.asarray(jax.jit(lambda sk=sk: moe_dispatch_all_to_all(
            ctx, xd, mode="fused", chunks_per_rank=2, skew=sk))())
        parity_ok &= np.array_equal(ya, a2a_ref)
        base_mm = y if base_mm is None else base_mm
        base_a2a = ya if base_a2a is None else base_a2a
        bit_ok &= np.array_equal(y, base_mm) and np.array_equal(ya, base_a2a)
    out["measured_parity"] = {"buckets": buckets, "parity_ok": bool(parity_ok),
                              "bit_identical_ok": bool(bit_ok)}
    report("skew_parity_buckets", float(len(buckets)),
           f"ok={parity_ok};bit_identical={bit_ok}")

    # ---- 3. wall-clock comm-aware vs oblivious -------------------------
    tkw = dict(iters=2, warmup=1) if smoke else {}
    out["measured"] = {}
    for sched in ["comm_aware", "oblivious"]:
        fn = jax.jit(lambda s=sched: matmul_allreduce(
            ctx, x, w, mode="fused", schedule=s, chunks_per_rank=2))
        t = timeit(fn, **tkw)
        out["measured"][sched] = t
        report(f"skew_measured_{sched}", t * 1e6, "")

    out["workload"] = {"world": WORLD, "delayed_rank": DELAYED_RANK,
                       "delay": DELAY, "link_scale": LINK_SCALE,
                       "mesh": list(ctx.mesh.shape.values()),
                       "mm_shape": [B, S, K, N]}
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("skew_json", 0.0, JSON_PATH)
    return out
