"""Elastic recovery across a *real* process boundary: MTTR + throughput.

Spawns coordinator-wired jax.distributed CPU worker processes through
:class:`~repro.runtime.multiprocess.MultiprocessDriver` (the same
harness as ``pytest -m multiprocess``) and runs two sections:

* **ring** — measured cross-process all-reduce times on the data axis,
  fitted to the alpha-beta model and compared against the stock DCN
  constants of :class:`~repro.core.hardware.MeshHardwareModel` (the
  ``--calibrate`` path's cost model, now fed by measurement);
* **recovery** — a short supervised run where one worker is SIGKILLed
  mid-training; survivors detect the loss through the heartbeat
  watchdog (RankLost from *liveness*, no fault injection), respawn on
  the shrunk world, restore from checkpoint, and finish.  Reports MTTR
  (wall time from the kill to the first recovered step) and per-step
  times before/after the shrink.

Machine-readable output: ``BENCH_elastic.json`` (schema-validated on
every write).  Pinned invariants: the recovery drill completes, and the
survivor generation makes positive throughput.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import tempfile

import numpy as np

JSON_PATH = "BENCH_elastic.json"

SCHEMA_KEYS = {"workload", "worlds", "ring", "recovery",
               "invariant_recovery_completed",
               "invariant_survivor_throughput_positive"}

_WORKERS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "multiprocess", "workers")


def _validate(out):
    missing = SCHEMA_KEYS - out.keys()
    assert not missing, f"BENCH_elastic.json schema rot: missing {missing}"
    assert out["invariant_recovery_completed"], \
        "elastic recovery drill did not complete"
    assert out["invariant_survivor_throughput_positive"], \
        "survivor generation made no progress"


def _read(path):
    with open(path) as f:
        return json.load(f)


def _ring_section(world, workdir, timeout_s):
    from repro.runtime.multiprocess import EXIT_OK, MultiprocessDriver

    res_dir = os.path.join(workdir, "ring_res")
    d = MultiprocessDriver([os.path.join(_WORKERS, "ring_worker.py")],
                           world, devices_per_proc=max(1, 8 // world),
                           workdir=os.path.join(workdir, "ring"),
                           extra={"result_dir": res_dir}, hang_grace_s=10.0)
    d.launch_generation(0, world)
    result = d.wait_generation(timeout_s)
    assert all(c == EXIT_OK for c in result.codes.values()), result.codes
    out = _read(os.path.join(res_dir, "ring.json"))
    return {"world": world, "sizes_bytes": out["sizes"],
            "times_s": out["times_s"], "alpha_s": out["alpha_s"],
            "beta_s_per_byte": out["beta_s_per_byte"],
            "measured_bw_gbps": out["measured_bw"] / 1e9,
            "measured_pred_s": out["measured_pred_s"],
            "dcn_pred_s": out["dcn_pred_s"]}


def _recovery_section(world, steps, kill_step, workdir, timeout_s):
    from repro.runtime.multiprocess import (EXIT_OK, EXIT_RESHARD,
                                            MultiprocessDriver)

    res_dir = os.path.join(workdir, "res")
    extra = {"steps": steps, "batch": 8, "seq": 32, "ckpt_every": 3,
             "stall_after": 2.0, "ckpt_dir": os.path.join(workdir, "ckpt"),
             "result_dir": res_dir}
    d = MultiprocessDriver([os.path.join(_WORKERS, "train_worker.py")],
                           world, devices_per_proc=max(1, 8 // world),
                           workdir=os.path.join(workdir, "train"),
                           extra=extra, hang_grace_s=10.0)
    victim = world - 1          # never rank 0: it hosts the coordinator
    report = d.run_elastic(
        max_generations=3, gen_timeout_s=timeout_s,
        faults={0: lambda drv: drv.kill_at_step(victim, kill_step)})

    assert report.completed, [g.codes for g in report.generations]
    g0, g1 = report.generations[0], report.generations[1]
    assert g0.codes[victim] == -signal.SIGKILL
    assert g0.codes[0] == EXIT_RESHARD

    kill_t = report.events("kill")[-1][2]
    r0 = _read(os.path.join(res_dir, "result_g0_r0.json"))
    r1 = _read(os.path.join(res_dir, "result_g1_r0.json"))
    assert r1["completed"] and r1["start_step"] > 0

    def step_s(rec):
        ts = [s["t"] for s in rec["steps"]]
        return float(np.median(np.diff(ts))) if len(ts) > 1 else 0.0

    first_recovered_t = r1["steps"][0]["t"]
    mttr_s = first_recovered_t - kill_t
    g1_step = step_s(r1)
    return {"world": world, "survivor_world": g1.world,
            "kill_step": kill_step, "mttr_s": mttr_s,
            "gen0_step_s": step_s(r0), "gen1_step_s": g1_step,
            "survivor_throughput_steps_per_s":
                (1.0 / g1_step) if g1_step > 0 else 0.0,
            "restored_step": r1["start_step"],
            "completed": bool(r1["completed"]),
            "generations": len(report.generations),
            "final_codes": {str(k): v for k, v in g1.codes.items()}}


def run(report, smoke=False):
    worlds = [2] if smoke else [2, 4]
    steps = 10 if smoke else 20
    kill_step = 4 if smoke else 8
    timeout_s = 420.0

    out = {"workload": "train_worker chatglm3-6b(reduced) b8 s32 + "
                       "SIGKILL mid-run; ring_worker alpha-beta fit",
           "worlds": worlds, "ring": [], "recovery": []}

    root = tempfile.mkdtemp(prefix="bench_elastic_")
    try:
        for world in worlds:
            wdir = os.path.join(root, f"w{world}")
            os.makedirs(wdir, exist_ok=True)
            ring = _ring_section(world, wdir, timeout_s)
            out["ring"].append(ring)
            report(f"ring_allreduce_w{world}_max",
                   ring["times_s"][-1] * 1e6,
                   f"bw={ring['measured_bw_gbps']:.2f}GB/s")

            rec = _recovery_section(world, steps, kill_step, wdir, timeout_s)
            out["recovery"].append(rec)
            report(f"elastic_mttr_w{world}", rec["mttr_s"] * 1e6,
                   f"step={rec['gen1_step_s'] * 1e3:.0f}ms "
                   f"world->{rec['survivor_world']}")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    out["invariant_recovery_completed"] = all(
        r["completed"] for r in out["recovery"])
    out["invariant_survivor_throughput_positive"] = all(
        r["survivor_throughput_steps_per_s"] > 0 for r in out["recovery"])
    _validate(out)
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    report("elastic_json", 0.0, JSON_PATH)
