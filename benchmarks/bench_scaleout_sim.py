"""Paper Fig. 15: large scale-out simulation of DLRM training.

ASTRA-Sim analogue: an alpha-beta event model of one DLRM training pass
(fwd + bwd) over N nodes on a 2D-torus (200 Gb/s links, 700 ns latency —
the paper's Table II network parameters), comparing bulk-synchronous
embedding/All-to-All against the fused kernel.  The paper reports ~21%
end-to-end reduction at 128 nodes.

Per-kernel compute times follow the paper's measured structure: bottom
MLP (independent, overlappable), embedding pooling (memory-bound),
All-to-All (exposed in baseline), interaction + top MLP (dependent).

Network + roofline constants come from the shared hierarchical
:class:`~repro.core.perfmodel.MeshHardwareModel` — the embedding A2A is
a *world*-ring crossing the inter-node DCN, so its wire time is read off
the ``node`` axis while compute rooflines come from the intra-node
device model, keeping this projection consistent with the per-axis
constants the autotuner plans against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.perfmodel import HardwareModel, MeshHardwareModel, V5E

# paper Table II network parameters on the inter-node axis; the device
# roofline (peak flops / HBM) is the accelerator's own.
HW = MeshHardwareModel.from_mapping(
    {"node": dataclasses.replace(V5E, ici_bw=200e9 / 8, ici_lat=700e-9)},
    default=V5E)


def dlrm_pass(nodes: int, fused: bool, *, batch_per=2048, tables_per=256,
              dim=92, pooling=70, mlp=(682, 682, 682), chunks=32,
              hw: MeshHardwareModel = HW):
    """Returns seconds for one training pass (fwd+bwd) on one node."""
    B = batch_per
    dev = hw.axis("device")          # intra-node roofline (default class)
    dcn = hw.axis("node")            # inter-node link class
    # compute times
    t_embed = dev.compute_time(0.0, tables_per * B * pooling * dim * 4)
    t_bot = 2 * B * 13 * 512 / dev.peak_flops
    n_vec = tables_per + 1
    d_int = n_vec * (n_vec - 1) // 2 + dim
    t_top = (2 * B * sum(a * b for a, b in zip((d_int,) + mlp, mlp + (1,)))
             / dev.peak_flops)
    # All-to-All bytes (each node keeps 1/nodes of its pooled output):
    # the exchange crosses the inter-node axis -> DCN bandwidth/latency
    wire = B * tables_per * dim * 4 * (nodes - 1) / nodes
    hops = max(1, int(np.sqrt(nodes)) // 2)                   # 2D torus avg
    t_wire = wire / dcn.ici_bw + hops * dcn.ici_lat

    if not fused:
        fwd = t_bot + t_embed + t_wire + t_top
        # bwd mirrors: top-mlp grad, A2A of embedding grads, embed update
        bwd = t_top * 2 + t_wire + t_embed
        return fwd + bwd
    # fused: per-chunk pooled slices PUT while later slices pool;
    # exposed wire = max(0, wire_time - compute_after_first_chunk)
    per_chunk = t_embed / chunks
    exposed = max(0.0, t_wire - (t_embed - per_chunk)) + chunks * 2e-6
    fwd = t_bot + t_embed + exposed + t_top
    bwd = t_top * 2 + max(0.0, t_wire - t_top) + t_embed + chunks * 2e-6
    return fwd + bwd


def run(report):
    for nodes in [16, 32, 64, 128]:
        b = dlrm_pass(nodes, fused=False)
        f = dlrm_pass(nodes, fused=True)
        red = 100 * (b - f) / b
        report(f"scaleout_dlrm_n{nodes}", f * 1e6,
               f"bulk_us={b*1e6:.0f};reduction_pct={red:.1f}")
    return red
