"""Paper Fig. 15: large scale-out simulation of DLRM training.

ASTRA-Sim analogue: an alpha-beta event model of one DLRM training pass
(fwd + bwd) over N nodes on a 2D-torus (200 Gb/s links, 700 ns latency —
the paper's Table II network parameters), comparing bulk-synchronous
embedding/All-to-All against the fused kernel.  The paper reports ~21%
end-to-end reduction at 128 nodes.

Per-kernel compute times follow the paper's measured structure: bottom
MLP (independent, overlappable), embedding pooling (memory-bound),
All-to-All (exposed in baseline), interaction + top MLP (dependent).
"""
from __future__ import annotations

import numpy as np

LINK_BW = 200e9 / 8          # paper Table II: 200 Gb/s
LINK_LAT = 700e-9
PEAK = 197e12
HBM = 819e9


def dlrm_pass(nodes: int, fused: bool, *, batch_per=2048, tables_per=256,
              dim=92, pooling=70, mlp=(682, 682, 682), chunks=32):
    """Returns seconds for one training pass (fwd+bwd) on one node."""
    B = batch_per
    # compute times
    t_embed = tables_per * B * pooling * dim * 4 / HBM        # gather-bound
    t_bot = 2 * B * 13 * 512 / PEAK
    n_vec = tables_per + 1
    d_int = n_vec * (n_vec - 1) // 2 + dim
    t_top = 2 * B * sum(a * b for a, b in zip((d_int,) + mlp, mlp + (1,))) / PEAK
    # All-to-All bytes (each node keeps 1/nodes of its pooled output)
    wire = B * tables_per * dim * 4 * (nodes - 1) / nodes
    hops = max(1, int(np.sqrt(nodes)) // 2)                   # 2D torus avg
    t_wire = wire / LINK_BW + hops * LINK_LAT

    if not fused:
        fwd = t_bot + t_embed + t_wire + t_top
        # bwd mirrors: top-mlp grad, A2A of embedding grads, embed update
        bwd = t_top * 2 + t_wire + t_embed
        return fwd + bwd
    # fused: per-chunk pooled slices PUT while later slices pool;
    # exposed wire = max(0, wire_time - compute_after_first_chunk)
    per_chunk = t_embed / chunks
    exposed = max(0.0, t_wire - (t_embed - per_chunk)) + chunks * 2e-6
    fwd = t_bot + t_embed + exposed + t_top
    bwd = t_top * 2 + max(0.0, t_wire - t_top) + t_embed + chunks * 2e-6
    return fwd + bwd


def run(report):
    for nodes in [16, 32, 64, 128]:
        b = dlrm_pass(nodes, fused=False)
        f = dlrm_pass(nodes, fused=True)
        red = 100 * (b - f) / b
        report(f"scaleout_dlrm_n{nodes}", f * 1e6,
               f"bulk_us={b*1e6:.0f};reduction_pct={red:.1f}")
    return red
