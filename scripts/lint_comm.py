#!/usr/bin/env python
"""Collective lint lane: static schedule verification + comm-graph report.

Runs in two stages, both report-only (no arrays are allocated — models
are traced through ``jax.eval_shape`` / ``jax.make_jaxpr`` on
``ShapeDtypeStruct`` leaves):

  1. verify every static direct-A2A send schedule the launchers could
     configure — all (world, q, schedule, skew) points — is an exact
     (destination, fine-chunk) cover, via
     :func:`repro.analysis.lint.verify_schedules`; any violation fails
     the lane (exit 1),
  2. trace each requested registry architecture's loss step and print
     the ``--explain-comm`` report: every collective site, its fused-op
     family, the modeled bulk->fused savings, and a concrete reason
     whenever a site is not fusible.

  PYTHONPATH=src python scripts/lint_comm.py --smoke
  PYTHONPATH=src python scripts/lint_comm.py --arch chatglm3-6b,dbrx-132b,dlrm
"""
from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402


def _sds(tree):
    import jax.numpy as jnp
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), jnp.asarray(x).dtype
                                       if not hasattr(x, "dtype") else x.dtype),
        tree)


def report_arch(arch: str, reduced: bool, batch: int, seq: int) -> str:
    from repro.configs.registry import get_arch
    from repro.analysis import explain_comm
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_batches
    from repro.models.common import split_params
    from repro.parallel.sharding import FusionConfig

    ctx = make_host_mesh(fusion=FusionConfig(mode="auto"))
    bundle = get_arch(arch)
    if reduced:
        bundle = bundle.reduced()
    # shapes only: eval_shape the init, SDS-ify the first synthetic batch
    params = jax.eval_shape(
        lambda k: split_params(bundle.init_params(k))[0],
        jax.random.PRNGKey(0))
    batch0 = _sds(next(iter(make_batches(bundle, batch, seq))))
    return explain_comm(ctx, bundle.loss_fn(ctx), params, batch0)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b,dbrx-132b,dlrm",
                    help="comma-separated registry architectures to report")
    ap.add_argument("--full", action="store_true",
                    help="trace the full (non-reduced) configs")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: schedule sweep + one architecture")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    args = ap.parse_args(argv)

    from repro.analysis import verify_schedules

    violations = verify_schedules()
    if violations:
        print(f"schedule verification FAILED ({len(violations)} violations):")
        for v in violations[:20]:
            print(f"  {v}")
        return 1
    print("schedule verification: all (world, q, schedule, skew) send "
          "schedules are exact covers")

    archs = args.arch.split(",")
    if args.smoke:
        archs = archs[:1]
    for arch in archs:
        print()
        print(f"== {arch} ==")
        print(report_arch(arch.strip(), not args.full, args.batch, args.seq))
    return 0


if __name__ == "__main__":
    sys.exit(main())
