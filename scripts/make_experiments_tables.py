"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep JSONs.

  PYTHONPATH=src python scripts/make_experiments_tables.py > experiments/tables.md
"""
import glob
import json
import os
import sys

GB = 2 ** 30


def load(outdir="experiments/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | compile | HBM/dev | HLO GFLOP/dev | coll MB/dev | collective mix |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r.get("fusion") != "fused":
            continue
        if r.get("schedule", "comm_aware") != "comm_aware":
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped (quadratic attn @500k) | - | - | - | - | - |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | - | {r.get('error','')[:60]} |")
            continue
        mem = r["memory"]["peak_bytes_per_device"] / GB
        fl = r["cost"]["flops_per_device"] / 1e9
        cb = r["collectives"]["total_bytes_per_device"] / 2 ** 20
        counts = r["collectives"].get("counts", {})
        mix = " ".join(f"{k.split('-')[-1] if '-' in k else k}:{int(v)}"
                       for k, v in sorted(counts.items()))
        rows.append(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']}s | "
                    f"{mem:.2f} GiB | {fl:,.0f} | {cb:,.0f} | {mix} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | compute | memory (raw/adj) | collective (raw/adj) | dominant | useful ratio |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok" or r.get("fusion") != "fused":
            continue
        if r.get("schedule", "comm_aware") != "comm_aware":
            continue
        ro = r["roofline"]
        ra = r.get("roofline_tpu_adjusted", ro)
        ur = r["model_flops"]["useful_ratio"]
        rows.append(f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
                    f"{fmt_s(ro['memory_s'])} / {fmt_s(ra['memory_s'])} | "
                    f"{fmt_s(ro['collective_s'])} / {fmt_s(ra['collective_s'])} | "
                    f"**{ro['dominant']}** | {ur:.2f} |")
    return "\n".join(rows)


def summary(recs):
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = sum(1 for r in recs if r["status"] == "error")
    return f"cells ok={ok} skipped={sk} error={er}"


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print("## Dry-run summary\n")
    print(summary(recs), "\n")
    print("### Single-pod mesh (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n### Multi-pod mesh (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single-pod, per device, v5e: 197 TF bf16 / 819 GB/s HBM / 50 GB/s ICI)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod, 512 chips, per device)\n")
    print(roofline_table(recs, "multi"))
