from repro.parallel.sharding import (  # noqa: F401
    ParallelContext,
    logical_to_sharding,
    param_sharding_rules,
)
