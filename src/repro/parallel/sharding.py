"""Sharding infrastructure: logical axes -> mesh axes, parallel context.

The production mesh is (data, model) single-pod or (pod, data, model)
multi-pod.  ``pod`` always composes with ``data`` into the DP/FSDP
dimension so the same model code runs on both meshes.

Logical axes used throughout the model zoo:

  batch     -> (pod, data)         activation batch dim
  seq       -> model               sequence dim under sequence/context parallelism
  fsdp      -> (pod, data)         FSDP shard dim of parameters / optimizer state
  tp        -> model               tensor-parallel dim (d_ff columns, heads, vocab, experts)
  none      -> replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _default_mesh_hw():
    # deferred: repro.core.fused imports this module, so a module-level
    # import of repro.core.perfmodel would be circular
    from repro.core.perfmodel import MeshHardwareModel

    return MeshHardwareModel()


@dataclasses.dataclass(frozen=True)
class FusionConfig:
    """Controls how dependent compute+collective pairs execute.

    mode:
      "bulk"   - bulk-synchronous baseline: full compute kernel, then the
                 collective (what RCCL/NCCL-style libraries give you).
      "fused"  - the paper's technique, TPU-adapted: the op is decomposed
                 into chunks; each chunk's collective is issued as soon as
                 its compute finishes, so XLA's latency-hiding scheduler
                 overlaps wire time with the remaining chunks' compute.
      "kernel" - Pallas device-initiated kernels (remote DMA) where
                 available; falls back to "fused" elsewhere.
      "auto"   - trace-time graph mode: every call site emits the bulk
                 reference collectives, and the jaxpr comm-graph analyzer
                 (:mod:`repro.analysis`) rewrites the profitable matches
                 to the fused ops afterwards (``--auto-fuse`` on the
                 launchers).  Model code needs no fused-op calls at all.
    schedule:
      "comm_aware"  - remote-destined chunks are computed first, the
                      locally-consumed chunk last (paper Fig. 6b / 7b).
      "oblivious"   - chunks computed in natural order (paper's baseline
                      scheduling; exists to reproduce Fig. 14).
    granularity: sub-chunk factor ``chunks_per_rank`` — how many slices
      each ring step's payload is split into (paper Fig. 13 knob).  1 is
      the paper's slice-per-peer granularity (one chunk per ring rank);
      larger values put each sub-slice on the wire as soon as it is
      produced, hiding more wire time until per-slice overhead wins.
      "auto" defers to the shape-keyed alpha-beta autotuner
      (:mod:`repro.core.autotune`) per fused-op call site.  Values that
      do not divide the chunked dimension are clamped per-op to the
      largest feasible factor.
    skew: measured straggler rotation (paper Fig. 14).  An integer bucket
      produced by :class:`repro.runtime.straggler.SkewEstimator` from
      per-rank step-time telemetry; every fused op ringing over the *tp*
      axis rotates its static chunk schedule by it (the A2A family
      rotates the remote destination order, the ring-carry family the
      sub-chunk service order).  The schedule is baked into the lowered
      HLO, so changing the bucket requires a re-jit —
      :class:`repro.runtime.straggler.SkewScheduler` owns that loop.
      0 = no measured skew (the default schedules).
    skew_world: the same bucket for ops that ring over the flattened
      full-world axis (the DLRM embedding A2A).  A rotation is only
      meaningful for the ring it was estimated on, so the world-ring ops
      deliberately do not inherit the tp-ring ``skew``
      (``SkewEstimator`` reduces per axis; feed each ring its own
      bucket).
    wire: wire dtype of every ring/A2A payload.  ``"f32"`` keeps the
      compute dtype on the wire (exact — the pre-wire graphs,
      bit-identical); ``"bf16"``/``"fp8"`` compress payloads on the send
      side while all local accumulation stays f32 (fp8 ships a per-chunk
      max-abs scale alongside the payload); ``"auto"`` defers to the
      per-mesh-axis alpha-beta model (:class:`~repro.core.perfmodel.
      MeshHardwareModel` via ``ParallelContext.hw``) jointly with the
      granularity choice — a slow DCN axis picks a narrow wire, a fast
      ICI axis whose wire hides behind compute keeps f32.
    """

    mode: str = "fused"
    schedule: str = "comm_aware"
    granularity: int | str = 1
    skew: int = 0
    skew_world: int = 0
    wire: str = "f32"
    fuse_ag_matmul: bool = True
    fuse_matmul_rs: bool = True
    fuse_moe_a2a: bool = True
    fuse_embed_a2a: bool = True
    fuse_kv_ag: bool = True

    def resolve(self, which: str) -> str:
        """Effective mode for one of the fused-op families."""
        if self.mode in ("bulk", "auto") or not getattr(self, f"fuse_{which}"):
            # "auto": trace bulk; the comm-graph analyzer rewrites after
            return "bulk"
        return self.mode


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Mesh + axis-role assignment threaded through the model zoo.

    ``hw`` is the hierarchical per-mesh-axis hardware model every
    ``tune_*`` call resolves its link constants from: a multi-pod mesh
    assigns the ``pod`` axis the DCN link class, so rings over different
    axes autotune against the bandwidth/latency they actually see."""

    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    fusion: FusionConfig = dataclasses.field(default_factory=FusionConfig)
    hw: "MeshHardwareModel" = dataclasses.field(
        default_factory=_default_mesh_hw)

    @classmethod
    def from_mesh(cls, mesh: Mesh, fusion: FusionConfig | None = None,
                  hw: "MeshHardwareModel | None" = None) -> "ParallelContext":
        from repro.core.perfmodel import MeshHardwareModel

        names = mesh.axis_names
        dp = tuple(n for n in names if n in ("pod", "data", "replica"))
        tp = "model" if "model" in names else names[-1]
        if hw is None:
            hw = MeshHardwareModel.for_mesh_axes(names)
        return cls(mesh=mesh, dp_axes=dp, tp_axis=tp,
                   fusion=fusion or FusionConfig(), hw=hw)

    def hw_for(self, axis):
        """Resolved flat :class:`~repro.core.perfmodel.HardwareModel` for
        one ring axis (or the bottleneck composite for a tuple of axes —
        the flattened-world embedding A2A)."""
        return self.hw.for_axes(axis)

    # -- sizes -----------------------------------------------------------
    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        s = 1
        for a in self.dp_axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def world(self) -> int:
        return self.dp * self.tp

    # -- spec helpers ----------------------------------------------------
    @property
    def batch_axes(self):
        if not self.dp_axes:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def spec(self, *logical: str | None) -> P:
        return P(*[self._resolve(ax) for ax in logical])

    def _resolve(self, ax: str | None):
        if ax is None or ax == "none":
            return None
        if ax == "batch" or ax == "fsdp":
            return self.batch_axes
        if ax in ("seq", "tp", "model", "expert", "heads", "vocab"):
            return self.tp_axis
        if ax == "ep":  # decode expert-parallel world: (data, model)
            non_pod = tuple(a for a in self.mesh.axis_names if a != "pod")
            return non_pod
        if ax == "world":  # flattened full-world axis (DLRM embedding A2A)
            return tuple(self.dp_axes) + (self.tp_axis,)
        raise ValueError(f"unknown logical axis {ax!r}")

    def sharding(self, *logical: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def with_fusion(self, fusion: FusionConfig) -> "ParallelContext":
        return dataclasses.replace(self, fusion=fusion)


def logical_to_sharding(ctx: ParallelContext, logical: Sequence[str | None]) -> NamedSharding:
    return ctx.sharding(*logical)


def param_sharding_rules(ctx: ParallelContext, params: Any, logical_tree: Any) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda spec: ctx.sharding(*spec),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def shard_like(ctx: ParallelContext, x, *logical: str | None):
    """with_sharding_constraint shorthand used inside jit-traced model code."""
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*logical))
