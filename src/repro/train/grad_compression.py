"""Compressed gradient reduction with error feedback (distributed-opt trick).

At 1000+ node scale the DP gradient all-reduce crosses DCN; compressing
it matters.  Two schemes, both with error-feedback residuals so the
compression error is re-injected next step (provably convergent for
convex objectives, standard practice at scale):

  int8:  per-tensor symmetric quantization; the AllReduce runs on int8
         payloads (sum in f32 after dequant locally -> psum of int8 is
         invalid, so we psum the dequantized f32 but *ship* int8 — in XLA
         terms the collective operand is the int8 tensor and the scale).
  topk:  magnitude top-k sparsification; only (values, indices) are
         reduced (k entries per tensor), everything else accumulates in
         the residual.

These wrap the grads *before* the optimizer; the fused-op machinery is
orthogonal (this compresses the DP axis, the paper fuses the TP/EP axes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # none | int8 | topk
    topk_ratio: float = 0.01


def init_residuals(cfg: CompressionConfig, params):
    if cfg.scheme == "none":
        return {}
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(cfg: CompressionConfig, grads, residuals):
    """Apply compression locally (error feedback), returning the grads that
    will be fed to the (already reduced) optimizer step plus new residuals.

    The caller is responsible for the actual reduction; under pjit the DP
    reduction is implicit in the grad computation, so this models the
    compression loss + error feedback faithfully while keeping the wire
    payload int8/sparse when lowered with shard_map reductions.
    """
    if cfg.scheme == "none":
        return grads, residuals

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if cfg.scheme == "int8":
            q, s = _quantize_int8(g32)
            deq = _dequantize_int8(q, s)
            return deq.astype(g.dtype), g32 - deq
        if cfg.scheme == "topk":
            flat = g32.reshape(-1)
            k = max(1, int(flat.size * cfg.topk_ratio))
            vals, idx = lax.top_k(jnp.abs(flat), k)
            kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
            return kept.reshape(g.shape).astype(g.dtype), (flat - kept).reshape(g.shape)
        raise ValueError(cfg.scheme)

    out = jax.tree.map(one, grads, residuals)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_res
