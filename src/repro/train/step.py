"""train_step factory: loss -> grads -> clip -> (compress) -> optimizer.

Microbatch gradient accumulation (for memory) is a scan over microbatch
slices; remat policy lives in the model configs.  The returned step is a
pure function ready for jax.jit with in/out shardings from the spec
trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.grad_compression import CompressionConfig, compress_decompress, init_residuals
from repro.train.optimizer import OptimizerConfig, clip_by_global_norm, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)
    microbatches: int = 1


def init_train_state(tc: TrainConfig, params):
    opt_init, _ = make_optimizer(tc.optimizer)
    state = {"params": params, "opt": opt_init(tc.optimizer, params)}
    if tc.compression.scheme != "none":
        state["residuals"] = init_residuals(tc.compression, params)
    return state


def build_train_step(loss_fn: Callable, tc: TrainConfig):
    """loss_fn(params, batch) -> scalar loss."""
    _, opt_update = make_optimizer(tc.optimizer)

    def split_micro(batch, i):
        def sl(x):
            mb = x.shape[0] // tc.microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        return jax.tree.map(sl, batch)

    def train_step(state, batch):
        params = state["params"]
        if tc.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def acc_body(carry, i):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, split_micro(batch, i))
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (loss_acc + l, g_acc), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0),
                jnp.arange(tc.microbatches))
            loss = loss / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)

        grads, gnorm = clip_by_global_norm(grads, tc.optimizer.grad_clip)
        new_state = dict(state)
        if tc.compression.scheme != "none":
            grads, new_state["residuals"] = compress_decompress(
                tc.compression, grads, state["residuals"])
        new_params, new_opt, lr = opt_update(tc.optimizer, grads, state["opt"], params)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                   "step": new_opt["step"]}
        return new_state, metrics

    return train_step


def train_state_specs(tc: TrainConfig, param_specs):
    from repro.train.optimizer import optimizer_state_specs

    specs = {"params": param_specs,
             "opt": optimizer_state_specs(tc.optimizer, param_specs)}
    if tc.compression.scheme != "none":
        specs["residuals"] = param_specs
    return specs
