"""Optimizers: AdamW (dtype-configurable state) and Adafactor-lite.

State dtype matters at assigned-architecture scale: deepseek-v3-671b with
f32 Adam moments does not fit 512 v5e chips; bf16 moments (or Adafactor)
do.  Configs pick via ``optimizer_state_dtype``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"   # bf16 for the largest configs


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(cfg: OptimizerConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.betas
    dt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / (1 - b1 ** step.astype(jnp.float32))
        vh = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, lr


# ---------------------------------------------------------------------------
# Adafactor-lite (factored second moment; for the 100B+ configs)
# ---------------------------------------------------------------------------
def adafactor_init(cfg: OptimizerConfig, params):
    def make(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"v": jax.tree.map(make, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        if p.ndim >= 2:
            g2 = g * g + 1e-30
            vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]) / \
                jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], 1e-30)
            u = g / jnp.sqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": decay * v["v"] + (1 - decay) * g * g}
            u = g / jnp.sqrt(nv["v"] + 1e-30)
        # update clipping (Adafactor RMS rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        new_p = p.astype(jnp.float32) - lr * u
        if p.ndim >= 2:
            new_p = new_p - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), nv

    is_v = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    g_flat, treedef = jax.tree.flatten(grads)
    p_flat = jax.tree.leaves(params)
    v_flat = jax.tree.leaves(state["v"], is_leaf=is_v)
    out = [upd(g, v, p) for g, v, p in zip(g_flat, v_flat, p_flat)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in out])
    new_v = jax.tree.unflatten(treedef, [t[1] for t in out])
    return new_params, {"v": new_v, "step": step}, lr


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, adamw_update
    if cfg.name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(cfg.name)


def optimizer_state_specs(cfg: OptimizerConfig, param_specs):
    """Optimizer state inherits each parameter's sharding."""
    if cfg.name == "adamw":
        return {"mu": param_specs, "nu": param_specs, "step": ()}

    def make(spec):
        # factored state drops the last / second-to-last axis spec
        s = tuple(spec)
        if len(s) >= 2:
            return {"vr": s[:-1], "vc": s[:-2] + s[-1:]}
        return {"v": s}

    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return {"v": jax.tree.map(make, param_specs, is_leaf=is_spec),
            "step": ()}
