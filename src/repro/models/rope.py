"""Rotary position embeddings: standard, 2D (chatglm), and M-RoPE (qwen2-vl)."""
from __future__ import annotations

import jax.numpy as jnp


def _rot_half_interleaved(x):
    x1, x2 = x[..., ::2], x[..., 1::2]
    return jnp.stack([-x2, x1], axis=-1).reshape(x.shape)


def _angles(positions, dim, theta):
    """positions [...,] -> cos/sin [..., dim//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, *, theta: float = 10000.0, rotary_dim: int | None = None):
    """Standard RoPE.  x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    rd = rotary_dim or hd
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = _angles(positions, rd, theta)          # [B, S, rd//2]
    cos = jnp.repeat(cos, 2, axis=-1)[:, :, None, :]  # [B, S, 1, rd]
    sin = jnp.repeat(sin, 2, axis=-1)[:, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    out = xr * cos.astype(x.dtype) + _rot_half_interleaved(xr) * sin.astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rd < hd else out


def apply_rope_2d(x, positions, *, theta: float = 10000.0):
    """ChatGLM-style 2D RoPE: rotary applied to the first half of head_dim
    only (the second half stays un-rotated), matching GLM's
    ``rotary_percentage=0.5`` with interleaved layout."""
    return apply_rope(x, positions, theta=theta, rotary_dim=x.shape[-1] // 2)


def apply_mrope(x, positions_thw, *, theta: float = 1_000_000.0,
                sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: head_dim frequency bands are split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  positions_thw: [3, B, S].  For pure-text positions the three
    streams are identical, recovering standard RoPE."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions_thw[..., None].astype(jnp.float32) * inv  # [3, B, S, hd//2]
    splits = [sum(sections[: i + 1]) for i in range(len(sections) - 1)]
    parts = []
    for i, a in enumerate(jnp.split(ang, splits, axis=-1)):
        parts.append(a[i])  # pick stream i's angles for section i
    ang = jnp.concatenate(parts, axis=-1)                     # [B, S, hd//2]
    cos = jnp.repeat(jnp.cos(ang), 2, axis=-1)[:, :, None, :]
    sin = jnp.repeat(jnp.sin(ang), 2, axis=-1)[:, :, None, :]
    return x * cos.astype(x.dtype) + _rot_half_interleaved(x) * sin.astype(x.dtype)
