"""DLRM — the paper's own architecture (Fig. 2).

Embedding tables are model-parallel across the *whole* device world;
bottom/top MLPs are data-parallel.  The switch between the two
parallelisms is the All-to-All the paper fuses into embedding pooling
(repro.core.embedding_all_to_all).  The interaction op consumes the
fused output directly in its {local batch, tables x dim} layout — the
paper's "no explicit shuffle" property.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.embedding_all_to_all import embedding_all_to_all
from repro.models.common import Param, dense_init, embed_init, key_iter
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    n_tables: int = 64            # global embedding table count
    table_vocab: int = 100_000
    embed_dim: int = 92           # paper Table II
    n_dense: int = 13
    bottom_mlp: tuple = (512, 256, 92)
    top_mlp: tuple = (682, 682, 682, 1)   # paper Table II avg size 682
    pooling: int = 70             # avg pooling size (lookups per bag)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def dlrm_init(key, cfg: DLRMConfig):
    ks = key_iter(key)
    params = {
        "tables": embed_init(next(ks), (cfg.n_tables, cfg.table_vocab, cfg.embed_dim),
                             ("world", None, None), cfg.pdtype),
        "bottom": [], "top": [],
    }
    d = cfg.n_dense
    for h in cfg.bottom_mlp:
        params["bottom"].append({
            "w": dense_init(next(ks), (d, h), (None, None), cfg.pdtype),
            "b": Param(jnp.zeros((h,), cfg.pdtype), (None,)),
        })
        d = h
    assert d == cfg.embed_dim, "bottom MLP must end at embed_dim"
    n_vec = cfg.n_tables + 1
    d_int = n_vec * (n_vec - 1) // 2 + cfg.embed_dim
    d = d_int
    for h in cfg.top_mlp:
        params["top"].append({
            "w": dense_init(next(ks), (d, h), (None, None), cfg.pdtype),
            "b": Param(jnp.zeros((h,), cfg.pdtype), (None,)),
        })
        d = h
    return params


def _mlp(layers, x, final_act=None):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1:
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def _interaction(bottom, pooled):
    """Dot-product interaction.  bottom: [B, D]; pooled: [B, T, D]."""
    B, T, D = pooled.shape
    z = jnp.concatenate([bottom[:, None], pooled], axis=1)   # [B, T+1, D]
    zz = jnp.einsum("bid,bjd->bij", z, z)
    iu, ju = jnp.triu_indices(T + 1, k=1)
    flat = zz[:, iu, ju]                                      # [B, (T+1)T/2]
    return jnp.concatenate([bottom, flat], axis=-1)


def dlrm_forward(ctx: ParallelContext, params, cfg: DLRMConfig, batch, *,
                 mode: str | None = None):
    """batch: dense [B, n_dense] (B world-sharded), indices [B, T, L] int32
    (full global batch; table dim sharded over world).  Returns logits [B]."""
    dense, indices = batch["dense"], batch["indices"]
    bottom = _mlp(params["bottom"], dense)                    # [B_loc.., D] DP
    pooled = embedding_all_to_all(ctx, indices, params["tables"], mode=mode)
    x = _interaction(bottom, pooled)
    return _mlp(params["top"], x)[:, 0]


def dlrm_loss(ctx: ParallelContext, params, cfg: DLRMConfig, batch, *,
              mode: str | None = None):
    logits = dlrm_forward(ctx, params, cfg, batch, mode=mode)
    y = batch["labels"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # numerically stable BCE-with-logits
    loss = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return loss.mean()
