"""Shared NN layers: norms, gated MLPs, vocab-parallel embedding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.allgather_matmul import allgather_matmul, matmul_reducescatter
from repro.core.collectives import ring_reduce_scatter_compute
from repro.core.matmul_allreduce import matmul_allreduce
from repro.models.common import Param, dense_init, embed_init, ones_init, key_iter
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


# ---------------------------------------------------------------------------
# norms (always computed in f32)
# ---------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6, *, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:  # gemma-style (1 + w) parameterization
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def rms_norm_init(dim, dtype, *, zero: bool = False):
    init = jnp.zeros if zero else jnp.ones
    return Param(init((dim,), dtype), (None,))


def softcap(x, cap: float | None):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU) — the paper's GEMM/GEMV + AllReduce target
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype, *, act="silu"):
    ks = key_iter(key)
    return {
        "w_gate": dense_init(next(ks), (d_model, d_ff), ("fsdp", "tp"), dtype),
        "w_up": dense_init(next(ks), (d_model, d_ff), ("fsdp", "tp"), dtype),
        "w_down": dense_init(next(ks), (d_ff, d_model), ("tp", "fsdp"), dtype),
    }


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
         "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
         "relu": jax.nn.relu}


def mlp_apply(ctx: ParallelContext, params, x, *, act="silu", seq_sharded: bool):
    """Column-parallel up/gate, row-parallel down.

    seq_sharded (train/prefill): AG&matmul fused in, matmul&RS fused out —
    the SP split of the paper's GEMM+AllReduce.
    not seq_sharded (decode, S=1): local column matmuls + fused
    GEMV+AllReduce out — the paper's flagship operator.
    """
    fn = _ACTS[act]
    if seq_sharded:
        g = allgather_matmul(ctx, x, params["w_gate"])
        u = allgather_matmul(ctx, x, params["w_up"])
        h = fn(g) * u
        return matmul_reducescatter(ctx, h, params["w_down"])
    # decode: x replicated over tp; shard the column matmuls over tp
    g = _colshard_matmul(ctx, x, params["w_gate"])
    u = _colshard_matmul(ctx, x, params["w_up"])
    h = fn(g) * u
    return matmul_allreduce(ctx, h, params["w_down"])


def _colshard_matmul(ctx: ParallelContext, x, w):
    """x replicated over tp  @  w column-sharded -> out col-sharded."""
    b = x.shape[0]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None

    def f(xl, wl):
        return xl @ wl

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), P(None, ctx.tp_axis)),
        out_specs=P(dp, None, ctx.tp_axis),
        check_vma=False,
    )(x, w)


# ---------------------------------------------------------------------------
# vocab-parallel embedding (+ fused embed & reduce-scatter for SP output)
# ---------------------------------------------------------------------------
def embedding_init(key, vocab, d_model, dtype):
    return {"table": embed_init(key, (vocab, d_model), ("tp", "fsdp"), dtype)}


def embedding_lookup(ctx: ParallelContext, params, tokens, *, seq_shard: bool,
                     scale: float | None = None):
    """tokens [B, S] -> x [B, S, D].

    seq_shard=True returns x sequence-sharded over tp: each rank computes
    partial embeddings (its vocab slice) per sequence chunk and the chunks
    are combined with a compute-interleaved ring reduce-scatter — the same
    fused embedding+collective shape as the paper's DLRM operator, applied
    to the LM token embedding (beyond-paper use of the technique).
    """
    table = params["table"]
    V, D = table.shape
    B, S = tokens.shape
    axis, n = ctx.tp_axis, ctx.tp
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    do_seq = seq_shard and S % n == 0 and S >= n

    def local_fn(tok, tab):
        d = lax.axis_index(axis)
        v_loc = tab.shape[0]

        def embed_partial(ids):
            rel = ids - d * v_loc
            ok = (rel >= 0) & (rel < v_loc)
            e = jnp.take(tab, jnp.clip(rel, 0, v_loc - 1), axis=0)
            return jnp.where(ok[..., None], e, 0).astype(tab.dtype)

        if do_seq:
            s_loc = tok.shape[1] // n

            def partial(c):
                ids = lax.dynamic_slice_in_dim(tok, c * s_loc, s_loc, axis=1)
                return embed_partial(ids)

            x = ring_reduce_scatter_compute(partial, axis,
                                            schedule=ctx.fusion.schedule)
        else:
            x = lax.psum(embed_partial(tok), axis)
        if scale is not None:
            x = (x.astype(jnp.float32) * scale).astype(x.dtype)
        return x

    out_spec = P(dp, axis, None) if do_seq else P(dp, None, None)
    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None), P(axis, None)),
        out_specs=out_spec, check_vma=False,
    )(tokens, table)
