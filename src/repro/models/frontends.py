"""Stub modality frontends (per assignment: backbone-only for [audio]/[vlm]).

These produce the precomputed frame/patch embeddings that
``input_specs()`` advertises; they are deterministic, shape-correct, and
cheap — stand-ins for EnCodec (musicgen) and the dynamic-resolution ViT
(qwen2-vl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def audio_frame_embeddings(key, batch: int, seq: int, d_model: int,
                           dtype=jnp.float32):
    """Stub EnCodec conditioning frames: [B, S, D]."""
    return jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02


def vision_patch_embeddings(key, batch: int, seq: int, d_model: int,
                            n_patches: int, dtype=jnp.float32):
    """Stub ViT patch embeddings occupying the first n_patches positions.

    Returns (embeds [B, S, D], mask [S] bool)."""
    emb = jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02
    mask = jnp.arange(seq) < n_patches
    return emb, mask


def mrope_positions(batch: int, seq: int, n_patches: int, grid_h: int = 0):
    """Synthetic (t, h, w) position streams for M-RoPE.  Vision patches get
    a 2D grid; text tokens continue with equal t/h/w positions."""
    g = grid_h or max(1, int(np.sqrt(max(n_patches, 1))))
    t = np.zeros((seq,), np.int32)
    h = np.zeros((seq,), np.int32)
    w = np.zeros((seq,), np.int32)
    for i in range(min(n_patches, seq)):
        t[i] = 0
        h[i] = i // g
        w[i] = i % g
    base = (max(n_patches, 1) // g) + 1
    for i in range(n_patches, seq):
        t[i] = h[i] = w[i] = base + (i - n_patches)
    pos = np.stack([t, h, w])[:, None, :].repeat(batch, axis=1)
    return jnp.asarray(pos)
