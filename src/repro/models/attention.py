"""Context-parallel attention with fused KV all-gather (train/prefill) and
sequence-sharded KV caches with partial-softmax merge (decode).

Sharding scheme (see DESIGN.md §5): activations are sequence-sharded over
the tp axis; attention keeps *all* heads on every rank (uniform across the
zoo's awkward head counts) and shards the KV sequence instead.

Train/prefill: rank d owns query chunk d and ring-gathers KV chunks,
running a flash-attention update on each arriving chunk while the next is
on the wire — the fused AllGather x attention operator (the paper's
decomposition applied to the KV gather).  Sliding-window layers
statically bound the number of ring hops (window/chunk), which the bulk
AG baseline cannot do.

Decode: the KV cache stays sequence-sharded; every rank computes a flash
partial over its local slice and one tiny pmax/psum pair merges them
(replaces the paper's sliceRdy polling with the collective itself).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import resolve_overlap, tune_ring_attention
from repro.core.collectives import (attention_partial_merge, ring_permute,
                                    split_ring_payload, wire_cast,
                                    wire_uncast)
from repro.core.scheduling import sub_chunk_service_order
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map

NEG_INF = -1e30


def _flash_update(carry, q5, k, v, mask, scale, cap):
    """One flash-attention accumulation step (f32 carries).

    carry = (m, l, o): [b,hk,g,sq], [b,hk,g,sq], [b,hk,g,sq,d]
    q5: [b,sq,hk,g,d]; k,v: [b,sk,hk,d]; mask: [sq,sk] bool, or
    [b,sq,sk] when validity is per batch row (the paged-KV path, where
    each slot masks at its own length/block table).
    """
    m, l, o = carry
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k).astype(jnp.float32) * scale
    if cap is not None:
        s = jnp.tanh(s / cap) * cap
    # additive 2D mask: broadcasts inside the fusion; a select against the
    # full [b,h,g,q,k] score shape would get materialized + loop-hoisted
    bias = jnp.where(mask, 0.0, NEG_INF)
    s = s + (bias[:, None, None] if mask.ndim == 3
             else bias[None, None, None])
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(axis=-1)
    o = o * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m_new, l, o


def _span_flash(q5, k, v, qpos, kpos, carry, *, causal, window, scale, cap,
                q_block, kv_block):
    """Accumulate flash carries of q5 against one KV span, blocked so the
    score matrix never exceeds [b, hk, g, q_block, kv_block]."""
    b, sq, hk, g, d = q5.shape
    sk = k.shape[1]
    qb = min(q_block, sq)
    kb = min(kv_block, sk)

    def q_step(qi, mlo):
        m, l, o = mlo
        qs = lax.dynamic_slice_in_dim(q5, qi * qb, qb, axis=1)
        qp = lax.dynamic_slice_in_dim(qpos, qi * qb, qb, axis=0)
        cm = lax.dynamic_slice_in_dim(m, qi * qb, qb, axis=3)
        cl = lax.dynamic_slice_in_dim(l, qi * qb, qb, axis=3)
        co = lax.dynamic_slice_in_dim(o, qi * qb, qb, axis=3)

        def kv_step(ki, mlo_q):
            ks = lax.dynamic_slice_in_dim(k, ki * kb, kb, axis=1)
            vs = lax.dynamic_slice_in_dim(v, ki * kb, kb, axis=1)
            kp = lax.dynamic_slice_in_dim(kpos, ki * kb, kb, axis=0)
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            return _flash_update(mlo_q, qs, ks, vs, mask, scale, cap)

        cm, cl, co = lax.fori_loop(0, sk // kb, kv_step, (cm, cl, co))
        return (lax.dynamic_update_slice_in_dim(m, cm, qi * qb, axis=3),
                lax.dynamic_update_slice_in_dim(l, cl, qi * qb, axis=3),
                lax.dynamic_update_slice_in_dim(o, co, qi * qb, axis=3))

    return lax.fori_loop(0, sq // qb, q_step, carry)


def _init_carry(b, hk, g, sq, d):
    return (jnp.full((b, hk, g, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, hk, g, sq), jnp.float32),
            jnp.zeros((b, hk, g, sq, d), jnp.float32))


def _finalize(carry, b, sq, hq, d):
    m, l, o = carry
    o = o / jnp.maximum(l, 1e-30)[..., None]          # [b,hk,g,sq,d]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


# ---------------------------------------------------------------------------
# flash backward over one KV span (blocked; recompute-in-backward)
# ---------------------------------------------------------------------------
def _span_flash_bwd(q5, kc, vc, do5, delta, m, l, qpos, kpos, dq5, *,
                    causal, window, scale, cap, q_block, kv_block,
                    dk0=None, dv0=None):
    """Accumulate flash gradients of q5 against one KV span.

    q5/do5/dq5: [b,sq,hk,g,d]; kc,vc: [b,skc,hk,d]; delta,m,l: [b,hk,g,sq].
    dq5 and (dk0, dv0) are running accumulators (the latter travel the
    ring with their chunk).  Scores are recomputed per (q_block, kv_block)
    tile, never materialized whole.
    """
    b, sq, hk, g, dd = q5.shape
    skc = kc.shape[1]
    qb = min(q_block, sq)
    kb = min(kv_block, skc)
    dk = jnp.zeros((b, skc, hk, dd), jnp.float32) if dk0 is None else dk0
    dv = jnp.zeros((b, skc, hk, dd), jnp.float32) if dv0 is None else dv0

    def q_step(qi, carry):
        dq5_, dk_, dv_ = carry
        qs = lax.dynamic_slice_in_dim(q5, qi * qb, qb, axis=1)
        dos = lax.dynamic_slice_in_dim(do5, qi * qb, qb, axis=1)
        qp = lax.dynamic_slice_in_dim(qpos, qi * qb, qb, axis=0)
        ms = lax.dynamic_slice_in_dim(m, qi * qb, qb, axis=3)
        ls = lax.dynamic_slice_in_dim(l, qi * qb, qb, axis=3)
        dls = lax.dynamic_slice_in_dim(delta, qi * qb, qb, axis=3)
        dq_blk = jnp.zeros((b, qb, hk, g, dd), jnp.float32)

        def kv_step(ki, inner):
            dq_b, dk_b, dv_b = inner
            ks = lax.dynamic_slice_in_dim(kc, ki * kb, kb, axis=1)
            vs = lax.dynamic_slice_in_dim(vc, ki * kb, kb, axis=1)
            kp = lax.dynamic_slice_in_dim(kpos, ki * kb, kb, axis=0)
            raw = jnp.einsum("bqhgd,bkhd->bhgqk", qs, ks
                             ).astype(jnp.float32) * scale
            s = raw
            if cap is not None:
                s = jnp.tanh(raw / cap) * cap
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            s = s + jnp.where(mask, 0.0, NEG_INF)[None, None, None]
            p = jnp.exp(s - ms[..., None]) / jnp.maximum(ls, 1e-30)[..., None]
            dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p,
                              dos.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dos.astype(jnp.float32),
                            vs.astype(jnp.float32))
            ds = p * (dp - dls[..., None])
            if cap is not None:
                t = jnp.tanh(raw / cap)
                ds = ds * (1.0 - t * t)
            ds = ds * scale
            dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds, ks.astype(jnp.float32))
            dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qs.astype(jnp.float32))
            dk_b = lax.dynamic_update_slice_in_dim(
                dk_b, lax.dynamic_slice_in_dim(dk_b, ki * kb, kb, 1) + dk_c,
                ki * kb, axis=1)
            dv_b = lax.dynamic_update_slice_in_dim(
                dv_b, lax.dynamic_slice_in_dim(dv_b, ki * kb, kb, 1) + dv_c,
                ki * kb, axis=1)
            return dq_b + dq_c, dk_b, dv_b

        dq_blk, dk_, dv_ = lax.fori_loop(0, skc // kb, kv_step,
                                         (dq_blk, dk_, dv_))
        dq5_ = lax.dynamic_update_slice_in_dim(
            dq5_, lax.dynamic_slice_in_dim(dq5_, qi * qb, qb, 1) + dq_blk,
            qi * qb, axis=1)
        return dq5_, dk_, dv_

    return lax.fori_loop(0, sq // qb, q_step, (dq5, dk, dv))


def _make_ring_attention(axis, n, hops, causal, window, scale, cap,
                         q_block, kv_block, Hq, Hkv, hd, s_loc, n_world,
                         n_sub=1, skew=0, wire="f32"):
    """Ring attention with analytic backward (custom VJP).

    Forward: each arriving KV chunk is flash-consumed while the next hop's
    collective-permute is in flight (the fused AllGather x attention op).
    ``n_sub`` (= ``chunks_per_rank``, paper Fig. 13) splits the local KV
    chunk into sub-chunks that ring *independently*: each sub-chunk is
    forwarded the moment the previous sub-chunk's attention partial has
    been consumed, so sub-chunk ``j``'s wire time hides behind sub-chunk
    ``j-1``'s flash update; the online-softmax stats merge per sub-chunk
    through the shared (m, l, o) carry.
    Backward: KV sub-chunks ring again (recomputed masks/scores, flash-bwd
    per sub-chunk); each sub-chunk's (dk, dv) accumulator travels the ring
    *with* its sub-chunk and is delivered back to its owner in one final
    offset permute.  Peak memory: one score tile — autodiff through the
    unrolled ring would instead save every hop's probability tensors.

    ``skew`` (measured straggler rotation, Fig. 14) rotates the service
    order of the ``n_sub`` independent sub-chunk rings within each hop —
    the straggler-facing sub-ring is forwarded first.  The shared
    online-softmax carry then merges sub-chunks in rotated order, which
    is algebraically the same sum (equal within the usual fp tolerance).

    ``wire`` compresses the ring payloads: KV sub-chunks round once at
    their source (the compressed payload rings unchanged, so remote KV
    sees one rounding regardless of hop count) and the traveling (dk, dv)
    accumulators are cast on every send while the flash-backward
    accumulation stays f32.  ``wire="f32"`` keeps the pre-wire graphs
    bit-identical (the accumulators then travel at the operand dtype, as
    before).
    """
    g = Hq // Hkv
    sub = s_loc // n_sub
    order = sub_chunk_service_order(n_sub, skew)
    compress = wire not in (None, "f32")
    # Without causal/window masking the position arrays are dead code; an
    # unconsumed axis_index leaves a dangling partition-id instruction that
    # the SPMD partitioner refuses, so only trace it when a mask needs it.
    need_pos = causal or window is not None

    def _rank():
        return lax.axis_index(axis) if need_pos else jnp.int32(0)

    @jax.custom_vjp
    def ring_attn(ql, kl, vl):
        o, _, _ = _fwd(ql, kl, vl)
        return o

    def _fwd(ql, kl, vl):
        d = _rank()
        b = ql.shape[0]
        qpos = d * s_loc + jnp.arange(s_loc)
        q5 = ql.reshape(b, s_loc, Hkv, g, hd)
        carry = _init_carry(b, Hkv, g, s_loc, hd)
        # local chunk whole: it is resident at t=0, no wire to hide
        carry = _span_flash(q5, kl, vl, qpos, d * s_loc + jnp.arange(s_loc),
                            carry, causal=causal, window=window, scale=scale,
                            cap=cap, q_block=q_block, kv_block=kv_block)
        # the KV payloads round once at their source (compressed wire
        # rings unchanged; every consumer uncasts the same representation)
        kbufs = [wire_cast(s, wire) for s in split_ring_payload(kl, n_sub)]
        vbufs = [wire_cast(s, wire) for s in split_ring_payload(vl, n_sub)]
        for i in range(1, hops + 1):
            src = (d - i) % n
            for j in order:
                kbufs[j] = ring_permute(kbufs[j], axis, n)
                vbufs[j] = ring_permute(vbufs[j], axis, n)
                carry = _span_flash(
                    q5, wire_uncast(kbufs[j], kl.dtype),
                    wire_uncast(vbufs[j], vl.dtype), qpos,
                    src * s_loc + j * sub + jnp.arange(sub), carry,
                    causal=causal, window=window, scale=scale,
                    cap=cap, q_block=q_block, kv_block=kv_block)
        m, l, _ = carry
        o = _finalize(carry, b, s_loc, Hq, hd)
        return o.astype(ql.dtype), m, l

    def fwd_rule(ql, kl, vl):
        o, m, l = _fwd(ql, kl, vl)
        return o, (ql, kl, vl, o, m, l)

    def bwd_rule(res, do):
        ql, kl, vl, o, m, l = res
        d = _rank()
        b = ql.shape[0]
        qpos = d * s_loc + jnp.arange(s_loc)
        q5 = ql.reshape(b, s_loc, Hkv, g, hd)
        # output is fully sharded (not replicated), so the cotangent
        # arrives unsplit — no world scaling (cf. the CE replicated case)
        do5 = do.astype(jnp.float32).reshape(b, s_loc, Hkv, g, hd)
        o5 = o.reshape(b, s_loc, Hkv, g, hd).astype(jnp.float32)
        # delta = rowsum(do * o): [b,hk,g,sq]
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", do5, o5)
        dq5 = jnp.zeros((b, s_loc, Hkv, g, hd), jnp.float32)

        kpos0 = d * s_loc + jnp.arange(s_loc)
        dq5, dk, dv = _span_flash_bwd(
            q5, kl, vl, do5, delta, m, l, qpos, kpos0, dq5,
            causal=causal, window=window, scale=scale, cap=cap,
            q_block=q_block, kv_block=kv_block)
        # replayed KV rings round once at their source (as in forward)
        kbufs = [wire_cast(s, wire) for s in split_ring_payload(kl, n_sub)]
        vbufs = [wire_cast(s, wire) for s in split_ring_payload(vl, n_sub)]

        def dperm(buf, shift=1):
            """One traveling-accumulator hop: uncompressed wire rides the
            operand dtype (pre-wire behavior, bit-identical); compressed
            wire casts on the send and lands back in f32 for the next
            flash-backward accumulation."""
            if not compress:
                return ring_permute(buf, axis, n, shift=shift)
            return wire_uncast(
                ring_permute(wire_cast(buf, wire), axis, n, shift=shift),
                jnp.float32)

        # traveling (dk, dv) accumulators: local representation is f32
        # under a compressed wire, operand dtype otherwise
        def rest(s, ref):
            return s if compress else s.astype(ref.dtype)

        dkbufs = [rest(s, kl) for s in split_ring_payload(dk, n_sub)]
        dvbufs = [rest(s, vl) for s in split_ring_payload(dv, n_sub)]
        for i in range(1, hops + 1):
            src = (d - i) % n
            for j in order:
                kbufs[j] = ring_permute(kbufs[j], axis, n)
                vbufs[j] = ring_permute(vbufs[j], axis, n)
                dkbufs[j] = dperm(dkbufs[j])
                dvbufs[j] = dperm(dvbufs[j])
                dq5, dkf, dvf = _span_flash_bwd(
                    q5, wire_uncast(kbufs[j], kl.dtype),
                    wire_uncast(vbufs[j], vl.dtype), do5, delta, m, l, qpos,
                    src * s_loc + j * sub + jnp.arange(sub), dq5,
                    causal=causal, window=window, scale=scale, cap=cap,
                    q_block=q_block, kv_block=kv_block,
                    dk0=dkbufs[j].astype(jnp.float32),
                    dv0=dvbufs[j].astype(jnp.float32))
                dkbufs[j] = rest(dkf, kl)
                dvbufs[j] = rest(dvf, vl)
        # deliver accumulated (dk, dv) back to the owning rank: the chunk
        # rests hops ranks ahead of its owner -> one offset permute home
        if hops % n != 0:
            dkbufs = [dperm(s, shift=-hops) for s in dkbufs]
            dvbufs = [dperm(s, shift=-hops) for s in dvbufs]
        dkl = dkbufs[0] if n_sub == 1 else jnp.concatenate(dkbufs, axis=1)
        dvl = dvbufs[0] if n_sub == 1 else jnp.concatenate(dvbufs, axis=1)
        dql = dq5.reshape(b, s_loc, Hq, hd).astype(ql.dtype)
        return dql, dkl.astype(kl.dtype), dvl.astype(vl.dtype)

    ring_attn.defvjp(fwd_rule, bwd_rule)
    return ring_attn


# ---------------------------------------------------------------------------
# train/prefill: ring-gathered context attention
# ---------------------------------------------------------------------------
def context_attention(
    ctx: ParallelContext,
    q, k, v,                  # [B, S, Hq|Hkv, hd] global, S sharded over tp
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    softcap_val: float | None = None,
    mode: str | None = None,
    q_block: int = 256,
    kv_block: int = 1024,
    chunks_per_rank: int | str | None = None,
    skew: int | None = None,
    wire: str | None = None,
):
    """``chunks_per_rank`` sub-chunks the KV ring payload (paper Fig. 13);
    ``None`` defers to ``FusionConfig.granularity`` and ``"auto"`` to the
    shape-keyed alpha-beta tuner (:func:`tune_ring_attention`).  ``skew``
    rotates the sub-ring service order by the measured straggler bucket
    (Fig. 14; ``None`` uses ``ctx.fusion.skew``).  ``wire`` compresses
    the KV ring payloads and the traveling (dk, dv) accumulators (f32
    local accumulation; ``None`` uses ``ctx.fusion.wire``)."""
    mode = mode or ctx.fusion.resolve("kv_ag")
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis, n = ctx.tp_axis, ctx.tp
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    scale = scale if scale is not None else hd ** -0.5
    s_loc = S // n
    # sliding-window layers statically bound the ring (fused-mode win):
    # only ceil(window / chunk) previous chunks can contain unmasked keys.
    hops = n - 1
    if window is not None and mode != "bulk" and causal:
        hops = min(n - 1, -(-window // s_loc))

    if mode != "bulk":
        b_loc = B // ctx.dp if dp is not None else B
        # the ring payload is the local KV chunk: only q | s_loc matters
        dec = resolve_overlap(
            chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
            lambda fq, wr: tune_ring_attention(
                b_loc, s_loc, Hq, Hkv, hd, dtype_bytes=k.dtype.itemsize,
                n_dev=n, hops=hops, hw=ctx.hw, axis=axis, skew=skew,
                wire=wr, fixed_q=fq),
            dim=s_loc, ring=1)
        ring_attn = _make_ring_attention(
            axis, n, hops, causal, window, scale, softcap_val,
            q_block, kv_block, Hq, Hkv, hd, s_loc, ctx.mesh.size,
            n_sub=dec.q, skew=skew, wire=dec.wire)

    def local_fn(ql, kl, vl):
        d = lax.axis_index(axis)
        b = ql.shape[0]
        qpos = d * s_loc + jnp.arange(s_loc)

        if mode == "bulk":
            q5 = ql.reshape(b, s_loc, Hkv, g, hd)
            kg = lax.all_gather(kl, axis, axis=1, tiled=True)
            vg = lax.all_gather(vl, axis, axis=1, tiled=True)
            carry = _span_flash(q5, kg, vg, qpos, jnp.arange(S),
                                _init_carry(b, Hkv, g, s_loc, hd),
                                causal=causal, window=window, scale=scale,
                                cap=softcap_val, q_block=q_block,
                                kv_block=kv_block)
            return _finalize(carry, b, s_loc, Hq, hd).astype(ql.dtype)

        # fused: local chunk first (available at t=0), then each arriving
        # ring chunk while the next hop's collective-permute is in flight;
        # analytic backward (see _make_ring_attention).
        return ring_attn(ql, kl, vl)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, axis, None, None),) * 3,
        out_specs=P(dp, axis, None, None),
        check_vma=False,
    )(q, k, v).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: sequence-sharded KV cache + partial merge
# ---------------------------------------------------------------------------
def broadcast_pos(pos, B):
    """Normalize a decode position to a per-slot vector [B].

    Accepts the legacy scalar (one shared position — every slot at the
    same offset) or a per-slot ``[B]`` vector; always returns ``[B]``
    int32.  Continuous batching requires the vector form: a slot reused
    by a new request restarts at position 0 while its neighbors keep
    counting."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))


def decode_attention(
    ctx: ParallelContext,
    q,                  # [B, 1, Hq, hd] replicated over tp
    k_cache, v_cache,   # [B, S_max, Hkv, hd] S sharded over tp
    pos,                # [B] (or scalar) int32 per-slot position (kv written)
    *,
    window: int | None = None,
    scale: float | None = None,
    softcap_val: float | None = None,
):
    axis, n = ctx.tp_axis, ctx.tp
    B, S_max, Hkv, hd = k_cache.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    scale = scale if scale is not None else hd ** -0.5
    s_loc = S_max // n
    pos = broadcast_pos(pos, B)

    def local_fn(ql, kl, vl, p):
        d = lax.axis_index(axis)
        kpos = d * s_loc + jnp.arange(s_loc)
        b = ql.shape[0]
        q5 = ql.reshape(b, 1, Hkv, g, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kl).astype(jnp.float32) * scale
        if softcap_val is not None:
            s = jnp.tanh(s / softcap_val) * softcap_val
        valid = kpos[None, :] <= p[:, None]            # [b, s_loc] per slot
        if window is not None:
            valid &= p[:, None] - kpos[None, :] < window
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        pr = jnp.exp(s - m[..., None])
        l = pr.sum(axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", pr, vl.astype(jnp.float32))
        o = attention_partial_merge(o, m, l, axis)
        return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, Hq, hd)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, None, None), P(dp, axis, None, None),
                  P(dp, axis, None, None), P(dp)),
        out_specs=P(dp, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, pos).astype(q.dtype)


def cache_update(ctx: ParallelContext, cache, new, pos):
    """Write ``new`` [B, 1, *rest] into a sequence-sharded cache
    [B, S_max, *rest], row ``b`` at its own position ``pos[b]``; only the
    owning rank's slice is touched (zero-copy-style: no gather, no
    staging buffer).  A position at/past ``S_max`` is dropped — the
    engine retires a slot *before* it would reach its cache bound
    (:class:`repro.serve.engine.DecodeEngine`), so an in-graph write past
    the end must not silently rewrite the last row."""
    axis, n = ctx.tp_axis, ctx.tp
    B, S_max = cache.shape[:2]
    rest = (None,) * (cache.ndim - 2)
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    s_loc = S_max // n
    pos = broadcast_pos(pos, B)

    def local_fn(cl, nl, p):
        d = lax.axis_index(axis)
        local_pos = p - d * s_loc                      # [b]
        # rows outside this rank's slice (or past the cache bound) index
        # out of range and are dropped by the scatter
        rows = jnp.where((local_pos >= 0) & (local_pos < s_loc),
                         local_pos, s_loc)
        b = cl.shape[0]
        return cl.at[jnp.arange(b), rows].set(
            nl[:, 0].astype(cl.dtype), mode="drop")

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, axis, *rest), P(dp, None, *rest), P(dp)),
        out_specs=P(dp, axis, *rest),
        check_vma=False,
    )(cache, new, pos)


# ---------------------------------------------------------------------------
# paged KV: block pool + per-request block tables (continuous batching)
# ---------------------------------------------------------------------------
# The dense decode cache above is [B, S_max, ...] — every slot pays for
# the longest request it might ever serve.  The paged layout instead
# shares one pool of fixed-size blocks ([NB, block, ...], blocks sharded
# over tp) among all in-flight requests; a per-request *block table*
# [B, MB] maps the request's sequence-block m to the pool block that
# holds it (allocation/free lives host-side in
# :class:`repro.serve.kv_cache.PagedKVCache`).  Ragged sequences then
# cost HBM proportional to their actual lengths, not B x S_max.
#
# Sharding: pool blocks are sharded *contiguously* over the tp axis
# (rank d owns global blocks [d*NB/n, (d+1)*NB/n)); each rank writes and
# attends only the blocks it owns and the partials merge through the
# same pmax/psum pair as the dense decode path.  The allocator stripes
# handouts across ranks so load stays balanced.

def paged_cache_update(ctx: ParallelContext, pool, new, tables, pos, valid):
    """Scatter a token chunk into the block pool.

    pool: [NB, block, *rest] (blocks sharded over tp); new: [B, C, *rest];
    tables: [B, MB] global block ids; pos: [B, C] global positions;
    valid: [B, C] bool (False rows — padding past a slot's ``n_new``, or
    idle slots — are dropped).  Writes land only on the rank owning the
    target block; positions whose block index falls outside the table are
    dropped, never clamped."""
    axis, n = ctx.tp_axis, ctx.tp
    NB, block = pool.shape[:2]
    rest = (None,) * (pool.ndim - 2)
    B, C = pos.shape
    MB = tables.shape[1]
    nb_loc = NB // n

    def local_fn(pl, nl, tbl, p, ok):
        d = lax.axis_index(axis)
        blk = p // block                                   # [B, C] seq-block
        ok = ok & (blk < MB)
        g = jnp.take_along_axis(tbl, jnp.clip(blk, 0, MB - 1), axis=1)
        local = g - d * nb_loc
        rows = jnp.where(ok & (local >= 0) & (local < nb_loc), local, nb_loc)
        return pl.at[rows.reshape(-1), (p % block).reshape(-1)].set(
            nl.reshape((B * C,) + nl.shape[2:]).astype(pl.dtype), mode="drop")

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(axis, None, *rest), P(None, None, *rest), P(), P(), P()),
        out_specs=P(axis, None, *rest),
        check_vma=False,
    )(pool, new, tables, pos, valid)


def paged_attention(
    ctx: ParallelContext,
    q,                  # [B, C, Hq, hd] replicated over tp
    pool_k, pool_v,     # [NB, block, Hkv, hd] blocks sharded over tp
    tables,             # [B, MB] int32 global block ids
    pos,                # [B, C] global position of each query token
    *,
    window: int | None = None,
    scale: float | None = None,
    softcap_val: float | None = None,
    kv_block: int = 1024,
):
    """Flash attention of a token chunk against a paged KV pool.

    Each rank gathers the table blocks it owns, runs the shared
    flash-update machinery over them span by span (per-slot causal /
    window masks — the chunk's own KV is already in the pool, so one
    pass covers both the cache and intra-chunk causality), and the
    partials merge with the same pmax/psum pair as the dense decode
    path.  C=1 is the pure-decode fast path; C>1 is a prefill chunk
    (continuous batching mixes both in one call via the per-slot
    positions)."""
    axis, n = ctx.tp_axis, ctx.tp
    NB, block, Hkv, hd = pool_k.shape
    B, C, Hq, _ = q.shape
    g = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    MB = tables.shape[1]
    nb_loc = NB // n
    span = max(1, min(MB, kv_block // block))   # table blocks per flash span

    def local_fn(ql, pkl, pvl, tbl, p):
        d = lax.axis_index(axis)
        b = ql.shape[0]
        q5 = ql.reshape(b, C, Hkv, g, hd)
        local = tbl - d * nb_loc                           # [B, MB]
        own = (local >= 0) & (local < nb_loc)
        rows = jnp.where(own, local, 0)
        kg = pkl[rows]                                     # [B, MB, blk, ...]
        vg = pvl[rows]
        carry = _init_carry(b, Hkv, g, C, hd)
        for m0 in range(0, MB, span):
            me = min(MB, m0 + span)
            sk = (me - m0) * block
            ks = kg[:, m0:me].reshape(b, sk, Hkv, hd)
            vs = vg[:, m0:me].reshape(b, sk, Hkv, hd)
            kpos = m0 * block + jnp.arange(sk)             # [sk] global pos
            ownmask = jnp.repeat(own[:, m0:me], block, axis=1)  # [B, sk]
            mask = ownmask[:, None, :] & (kpos[None, None, :] <= p[:, :, None])
            if window is not None:
                mask &= p[:, :, None] - kpos[None, None, :] < window
            carry = _flash_update(carry, q5, ks, vs, mask, scale, softcap_val)
        m, l, o = carry
        o = attention_partial_merge(o, m, l, axis)         # [b,hk,g,C,d]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, C, Hq, hd)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(None, None, None, None), P(axis, None, None, None),
                  P(axis, None, None, None), P(), P()),
        out_specs=P(None, None, None, None),
        check_vma=False,
    )(q, pool_k, pool_v, tables, pos).astype(q.dtype)
