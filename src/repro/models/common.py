"""Functional parameter plumbing shared by the model zoo.

Parameters are plain pytrees of jnp arrays; every init function returns a
matching pytree of *logical sharding specs* alongside, so launchers can
derive NamedShardings without a module framework.  Logical axes are those
understood by ``ParallelContext.spec``:

  "fsdp"  - parameter shard dim for FSDP (maps to (pod, data))
  "tp"    - tensor/expert/vocab-parallel dim (maps to model)
  None    - replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Param:
    value: Any
    spec: tuple

    def __iter__(self):  # allow tuple-unpacking
        yield self.value
        yield self.spec


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), tuple(p.spec)),
    lambda spec, children: Param(children[0], spec),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """Split a pytree of Param into (values, logical specs)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_param)
    return values, specs


def dense_init(key, shape, spec, dtype, scale: float | None = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    v = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    return Param(v.astype(dtype), spec)


def embed_init(key, shape, spec, dtype, std: float = 0.02):
    v = jax.random.normal(key, shape, jnp.float32) * std
    return Param(v.astype(dtype), spec)


def zeros_init(shape, spec, dtype):
    return Param(jnp.zeros(shape, dtype), spec)


def ones_init(shape, spec, dtype):
    return Param(jnp.ones(shape, dtype), spec)


def key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))
