"""Multi-head Latent Attention (DeepSeek-V3) with context-parallel sharding.

MLA compresses KV into a per-token latent (c_kv + shared rope key).  Under
our context-parallel scheme this is a large communication win the paper's
fusion amplifies: the train-time ring gathers the *latent* stream
(kv_lora + rope dims per token instead of 2*H*hd), and each arriving
latent chunk is expanded to K/V and flash-consumed while the next chunk
is on the wire.  Decode uses the absorbed formulation: score and output
accumulation happen in latent space, so the partial-merge collective is
latent-sized too.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.collectives import attention_partial_merge, ring_permute
from repro.models.attention import (NEG_INF, _span_flash, _init_carry,
                                    _finalize, broadcast_pos)
from repro.models.common import dense_init, key_iter
from repro.models.layers import rms_norm
from repro.models.rope import apply_rope
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim


def mla_init(key, cfg: MLAConfig, dtype):
    ks = key_iter(key)
    D, H = cfg.d_model, cfg.n_heads
    return {
        "w_dq": dense_init(next(ks), (D, cfg.q_lora_rank), ("fsdp", None), dtype),
        "q_norm": dense_init(next(ks), (cfg.q_lora_rank,), (None,), jnp.float32, scale=0.0),
        "w_uq": dense_init(next(ks), (cfg.q_lora_rank, H * cfg.qk_dim), ("fsdp", None), dtype),
        "w_dkv": dense_init(next(ks), (D, cfg.kv_lora_rank), ("fsdp", None), dtype),
        "kv_norm": dense_init(next(ks), (cfg.kv_lora_rank,), (None,), jnp.float32, scale=0.0),
        "w_kr": dense_init(next(ks), (D, cfg.qk_rope_dim), ("fsdp", None), dtype),
        "w_uk": dense_init(next(ks), (cfg.kv_lora_rank, H, cfg.qk_nope_dim), ("fsdp", None, None), dtype),
        "w_uv": dense_init(next(ks), (cfg.kv_lora_rank, H, cfg.v_head_dim), ("fsdp", None, None), dtype),
        "w_o": dense_init(next(ks), (H * cfg.v_head_dim, D), (None, "fsdp"), dtype),
    }


def _mla_qkv_latent(params, cfg: MLAConfig, x, positions):
    """Shared projections: full q heads + per-token latent/rope-key."""
    B, S, D = x.shape
    H = cfg.n_heads
    q = rms_norm(x @ params["w_dq"], 1.0 + params["q_norm"])
    q = (q @ params["w_uq"]).reshape(B, S, H, cfg.qk_dim)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    c = rms_norm(x @ params["w_dkv"], 1.0 + params["kv_norm"])   # [B,S,ckv]
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        theta=cfg.rope_theta)[:, :, 0]            # [B,S,dr]
    return q_nope, q_rope, c, k_rope


def mla_context_attention(ctx: ParallelContext, params, cfg: MLAConfig, x,
                          *, mode: str | None = None):
    """Train/prefill MLA.  x: [B, S, D] sequence-sharded over tp.

    Ring-gathers the latent (c, k_rope) streams — ~(2*H*hd)/(ckv+dr) times
    fewer wire bytes than gathering expanded KV — expanding each chunk
    to K/V right before its flash update.
    Returns attention output [B, S, D] seq-sharded, plus (c, k_rope) as
    the prefill cache contribution.
    """
    mode = mode or ctx.fusion.resolve("kv_ag")
    axis, n = ctx.tp_axis, ctx.tp
    B, S, D = x.shape
    H = cfg.n_heads
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    s_loc = S // n
    scale = cfg.qk_dim ** -0.5

    def local_fn(xl, pl):
        w_uk, w_uv = pl["w_uk"], pl["w_uv"]
        d = lax.axis_index(axis)
        b = xl.shape[0]
        positions = (d * s_loc + jnp.arange(s_loc))[None, :]
        q_nope, q_rope, c, k_rope = _mla_qkv_latent(pl, cfg, xl, positions)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)     # [b,s,H,qk]
        q5 = q_full.reshape(b, s_loc, H, 1, cfg.qk_dim)
        qpos = d * s_loc + jnp.arange(s_loc)

        def expand(cc, kr):
            k_nope = jnp.einsum("bsc,chd->bshd", cc, w_uk)
            k = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                          kr.shape[:2] + (H, cfg.qk_rope_dim))],
                axis=-1)
            v = jnp.einsum("bsc,chd->bshd", cc, w_uv)
            return k, v

        def span(cc, kr, kpos, carry):
            k, v = expand(cc, kr)
            return _span_flash(q5, k, v, qpos, kpos, carry, causal=True,
                               window=None, scale=scale, cap=None,
                               q_block=256, kv_block=1024)

        carry = _init_carry(b, H, 1, s_loc, cfg.v_head_dim)
        if mode == "bulk":
            cg = lax.all_gather(c, axis, axis=1, tiled=True)
            kg = lax.all_gather(k_rope, axis, axis=1, tiled=True)
            carry = span(cg, kg, jnp.arange(S), carry)
        else:
            carry = span(c, k_rope, d * s_loc + jnp.arange(s_loc), carry)
            cbuf, kbuf = c, k_rope
            for i in range(1, n):
                cbuf = ring_permute(cbuf, axis, n)
                kbuf = ring_permute(kbuf, axis, n)
                src = (d - i) % n
                carry = span(cbuf, kbuf, src * s_loc + jnp.arange(s_loc), carry)
        o = _finalize(carry, b, s_loc, H, cfg.v_head_dim)
        out = o.reshape(b, s_loc, H * cfg.v_head_dim).astype(xl.dtype) @ pl["w_o"]
        return out, c, k_rope

    param_specs = jax.tree.map(lambda _: P(), params)
    out, c, k_rope = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, axis, None), param_specs),
        out_specs=(P(dp, axis, None), P(dp, axis, None), P(dp, axis, None)),
        check_vma=False,
    )(x, params)
    return out, (c, k_rope)


def mla_decode_attention(ctx: ParallelContext, params, cfg: MLAConfig, x,
                         c_cache, kr_cache, pos):
    """Absorbed-form MLA decode.

    x: [B, 1, D] replicated over tp; c_cache: [B, S_max, ckv] and
    kr_cache: [B, S_max, dr], both sequence-sharded (current position
    already written).  ``pos`` is the per-slot position vector [B] (a
    scalar broadcasts); each slot applies RoPE and masks at its own
    length.  Partials are merged in latent space.
    """
    axis, n = ctx.tp_axis, ctx.tp
    B, S_max, ckv = c_cache.shape
    H = cfg.n_heads
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    s_loc = S_max // n
    scale = cfg.qk_dim ** -0.5
    pos = broadcast_pos(pos, B)

    def local_fn(xl, cl, krl, p, pl):
        w_uk, w_uv = pl["w_uk"], pl["w_uv"]
        d = lax.axis_index(axis)
        b = xl.shape[0]
        positions = p[:, None]                                 # [b, 1]
        q_nope, q_rope, _c_new, _kr_new = _mla_qkv_latent(pl, cfg, xl, positions)
        # absorb W_uk into q: score_h(t) = q_eff_h . c_t + q_rope_h . kr_t
        q_eff = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)     # [b,1,H,ckv]
        kpos = d * s_loc + jnp.arange(s_loc)
        s_lat = jnp.einsum("bqhc,bkc->bhqk", q_eff, cl)
        s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope, krl)
        s = (s_lat + s_rope).astype(jnp.float32) * scale       # [b,H,1,k]
        valid = kpos[None, :] <= p[:, None]                    # [b, s_loc]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m = s.max(axis=-1)
        pr = jnp.exp(s - m[..., None])
        l = pr.sum(axis=-1)
        o_lat = jnp.einsum("bhqk,bkc->bhqc", pr, cl.astype(jnp.float32))
        o_lat = attention_partial_merge(o_lat, m, l, axis)      # [b,H,1,ckv]
        o = jnp.einsum("bhqc,chv->bqhv", o_lat.astype(xl.dtype), w_uv)
        return o.reshape(b, 1, H * cfg.v_head_dim)

    param_specs = jax.tree.map(lambda _: P(), params)
    o = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), P(dp, axis, None), P(dp, axis, None),
                  P(dp), param_specs),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, c_cache, kr_cache, pos, params)
    # output projection applied in global code so serve-time placement of
    # w_o (EP-sharded contraction) lowers to partial-matmul + psum rather
    # than a per-layer weight gather at the shard_map boundary
    return o @ params["w_o"]


def mla_latents_for_cache(params, cfg: MLAConfig, x, positions):
    """Compute (c, k_rope) for a new token (cache write path)."""
    c = rms_norm(x @ params["w_dkv"], 1.0 + params["kv_norm"])
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        theta=cfg.rope_theta)[:, :, 0]
    return c, k_rope
