"""Unified decoder-only LM covering the dense/MoE/audio/VLM architectures.

One config describes layer structure (GQA or MLA attention, dense or MoE
FFN, local/global window alternation, RoPE flavor, softcaps); layers are
scanned in homogeneous *groups* (a group = one period of the layer
pattern) so the lowered HLO stays compact for the 40-95 layer configs.

All activations run sequence-sharded over tp (train/prefill) with the
fused operators from repro.core at every collective site; decode runs
with replicated single-token activations, sequence-sharded KV caches and
the fused GEMV+AllReduce FFN (the paper's flagship op).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.loss import sharded_cross_entropy
from repro.models import moe as moe_mod
from repro.models import mla as mla_mod
from repro.models.attention import (broadcast_pos, cache_update,
                                    context_attention, decode_attention,
                                    paged_attention, paged_cache_update)
from repro.models.common import Param, dense_init, is_param, key_iter
from repro.models.layers import embedding_init, embedding_lookup, mlp_apply, mlp_init, rms_norm, rms_norm_init
from repro.models.rope import apply_mrope, apply_rope, apply_rope_2d
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    act: str = "silu"
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    rope_style: str = "full"           # full | 2d | mrope
    mrope_sections: tuple = (16, 24, 24)
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    window: int | None = None          # sliding window for local layers
    local_global_period: int = 0       # gemma2: 2 -> [local, global] pattern
    query_scale: float | None = None
    embed_scale: bool = False          # gemma: x *= sqrt(d_model)
    post_norms: bool = False           # gemma2 post-attn/ffn norms
    norm_plus_one: bool = False        # gemma (1+w) RMSNorm
    attn_type: str = "gqa"             # gqa | mla
    mla: mla_mod.MLAConfig | None = None
    moe: moe_mod.MoEConfig | None = None
    dense_prefix: int = 0              # deepseek-v3: first k layers dense
    frontend: str | None = None        # None | audio | vision
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    max_seq: int = 4096                # KV-cache length for decode
    remat: bool = True
    sub_quadratic: bool = False        # True for SSM/hybrid (long_500k ok)

    @property
    def hd(self):
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_len(self):
        return self.local_global_period or 1

    def layer_window(self, idx_in_pattern: int):
        if not self.local_global_period:
            return self.window if self.window else None
        # gemma2 style: even layers local, odd layers global
        return self.window if idx_in_pattern % 2 == 0 else None

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _layer_init(key, cfg: TransformerConfig, window):
    ks = key_iter(key)
    D = cfg.d_model
    p: dict[str, Any] = {"ln1": rms_norm_init(D, jnp.float32, zero=cfg.norm_plus_one),
                         "ln2": rms_norm_init(D, jnp.float32, zero=cfg.norm_plus_one)}
    if cfg.post_norms:
        p["post_ln1"] = rms_norm_init(D, jnp.float32, zero=cfg.norm_plus_one)
        p["post_ln2"] = rms_norm_init(D, jnp.float32, zero=cfg.norm_plus_one)
    if cfg.attn_type == "mla":
        p["attn"] = mla_mod.mla_init(next(ks), cfg.mla, cfg.pdtype)
    else:
        qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
        p["attn"] = {
            "w_qkv": dense_init(next(ks), (D, qkv), ("fsdp", None), cfg.pdtype),
            "w_o": dense_init(next(ks), (cfg.n_heads * cfg.hd, D), (None, "fsdp"), cfg.pdtype),
        }
    return p


def _ffn_init(key, cfg: TransformerConfig, dense: bool):
    if cfg.moe is not None and not dense:
        return moe_mod.moe_init(key, cfg.moe, cfg.pdtype)
    return mlp_init(key, cfg.d_model, cfg.d_ff, cfg.pdtype, act=cfg.act)


def _group_init(key, cfg: TransformerConfig, dense: bool):
    """One scan group = pattern_len consecutive layers."""
    ks = key_iter(key)
    group = []
    for i in range(cfg.pattern_len):
        lp = _layer_init(next(ks), cfg, cfg.layer_window(i))
        lp["ffn"] = _ffn_init(next(ks), cfg, dense)
        group.append(lp)
    return {f"l{i}": g for i, g in enumerate(group)}


def stacked_init(key, n: int, init_fn):
    """vmap an init over n layer keys; Param specs gain a leading None."""
    keys = jax.random.split(key, n)
    proto = init_fn(keys[0])
    flat_proto, treedef = jax.tree.flatten(proto, is_leaf=is_param)

    def values_fn(k):
        t = init_fn(k)
        return [p.value for p in jax.tree.leaves(t, is_leaf=is_param)]

    vals = jax.vmap(values_fn)(keys)
    out = [Param(v, (None,) + tuple(p.spec)) for v, p in zip(vals, flat_proto)]
    return jax.tree.unflatten(treedef, out)


def transformer_init(key, cfg: TransformerConfig):
    ks = key_iter(key)
    n_scan = (cfg.n_layers - cfg.dense_prefix) // cfg.pattern_len
    assert (cfg.n_layers - cfg.dense_prefix) % cfg.pattern_len == 0, cfg.name
    params: dict[str, Any] = {
        "embed": embedding_init(next(ks), cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": rms_norm_init(cfg.d_model, jnp.float32, zero=cfg.norm_plus_one),
        "layers": stacked_init(next(ks), n_scan, lambda k: _group_init(k, cfg, dense=False)),
    }
    if cfg.dense_prefix:
        params["prefix"] = [
            {"l0": {**_layer_init(next(ks), cfg, cfg.layer_window(0)),
                    "ffn": _ffn_init(next(ks), cfg, dense=True)}}
            for _ in range(cfg.dense_prefix)
        ]
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _apply_rope_any(cfg, x, positions):
    if cfg.rope_style == "2d":
        return apply_rope_2d(x, positions, theta=cfg.rope_theta)
    if cfg.rope_style == "mrope":
        return apply_mrope(x, positions, theta=cfg.rope_theta,
                           sections=cfg.mrope_sections)
    return apply_rope(x, positions, theta=cfg.rope_theta)


def _attn_train(ctx, cfg: TransformerConfig, lp, x, positions, window,
                collect_kv=False):
    B, S, D = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    if cfg.attn_type == "mla":
        out, latents = mla_mod.mla_context_attention(ctx, lp["attn"], cfg.mla, h)
        kv = {"c": latents[0], "kr": latents[1]} if collect_kv else None
        return out, kv
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qkv = h @ lp["attn"]["w_qkv"]
    q, k, v = jnp.split(qkv, [Hq * hd, (Hq + Hkv) * hd], axis=-1)
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = _apply_rope_any(cfg, q, positions)
    k = _apply_rope_any(cfg, k, positions)
    o = context_attention(ctx, q, k, v, causal=True, window=window,
                          scale=cfg.query_scale, softcap_val=cfg.attn_softcap)
    kv = {"k": k, "v": v} if collect_kv else None
    return o.reshape(B, S, Hq * hd) @ lp["attn"]["w_o"], kv


def _layer_train(ctx, cfg: TransformerConfig, lp, x, positions, window,
                 collect_kv=False):
    a, kv = _attn_train(ctx, cfg, lp, x, positions, window, collect_kv)
    if cfg.post_norms:
        a = rms_norm(a, lp["post_ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    if cfg.moe is not None and "router" in lp["ffn"]:
        f = moe_mod.moe_apply(ctx, lp["ffn"], h, cfg.moe)
    else:
        f = mlp_apply(ctx, lp["ffn"], h, act=cfg.act, seq_sharded=True)
    if cfg.post_norms:
        f = rms_norm(f, lp["post_ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return x + f, kv


def _embed_inputs(ctx, params, cfg: TransformerConfig, batch, *, seq_shard):
    """tokens and/or stub-frontend embeddings -> x [B, S, D]."""
    tokens = batch["tokens"]
    scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
    x = embedding_lookup(ctx, params["embed"], tokens,
                         seq_shard=seq_shard, scale=scale)
    x = x.astype(cfg.cdtype)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        is_v = batch["vision_mask"]  # [S] bool
        x = jnp.where(is_v[None, :, None], batch["vision_embeds"].astype(cfg.cdtype), x)
    if cfg.frontend == "audio" and "frame_embeds" in batch:
        x = x + batch["frame_embeds"].astype(cfg.cdtype)
    return x


def _positions_for(cfg, batch, S):
    if cfg.rope_style == "mrope":
        return batch["positions_thw"]  # [3, B, S]
    return jnp.arange(S)[None, :]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def train_forward(ctx: ParallelContext, params, cfg: TransformerConfig, batch):
    """batch: {tokens [B,S], labels [B,S], (frontend extras)} -> scalar loss."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_inputs(ctx, params, cfg, batch, seq_shard=True)
    positions = _positions_for(cfg, batch, S)

    for lp in params.get("prefix", []):
        x, _ = _layer_train(ctx, cfg, lp["l0"], x, positions, cfg.layer_window(0))

    def group_body(carry, group_params):
        h = carry
        for i in range(cfg.pattern_len):
            h, _ = _layer_train(ctx, cfg, group_params[f"l{i}"], h, positions,
                                cfg.layer_window(i))
        return h, ()

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = lax.scan(body, x, params["layers"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return sharded_cross_entropy(ctx, x, params["embed"]["table"],
                                 batch["labels"], logit_softcap=cfg.logit_softcap)


def prefill_forward(ctx: ParallelContext, params, cfg: TransformerConfig, batch):
    """Inference prefill: forward over the prompt, returning last-position
    logits [B, 1, V] and the per-layer KV/latent cache (seq dim = S)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed_inputs(ctx, params, cfg, batch, seq_shard=True)
    positions = _positions_for(cfg, batch, S)

    prefix_kv = []
    for lp in params.get("prefix", []):
        x, kv = _layer_train(ctx, cfg, lp["l0"], x, positions,
                             cfg.layer_window(0), collect_kv=True)
        prefix_kv.append(kv)

    def group_body(carry, group_params):
        h = carry
        kvs = []
        for i in range(cfg.pattern_len):
            h, kv = _layer_train(ctx, cfg, group_params[f"l{i}"], h, positions,
                                 cfg.layer_window(i), collect_kv=True)
            kvs.append(kv)
        return h, jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)

    x, scan_kv = lax.scan(group_body, x, params["layers"])
    n_scan_layers = cfg.n_layers - cfg.dense_prefix
    cache = {"scan": jax.tree.map(
        lambda c: c.reshape((n_scan_layers,) + c.shape[2:]), scan_kv)}
    if prefix_kv:
        cache["prefix"] = jax.tree.map(lambda *xs: jnp.stack(xs), *prefix_kv)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    x_last = jax.lax.with_sharding_constraint(
        x[:, S - 1:], ctx.sharding("batch", None, None))
    logits = _lm_logits(ctx, params, cfg, x_last)
    return logits, cache


# --- decode --------------------------------------------------------------
def init_cache(cfg: TransformerConfig, batch_size: int):
    """Zeroed decode caches (values only; shardings via cache_specs)."""
    S = cfg.max_seq
    n_scan = (cfg.n_layers - cfg.dense_prefix) // cfg.pattern_len

    def one(n):
        if cfg.attn_type == "mla":
            return {"c": jnp.zeros((n, batch_size, S, cfg.mla.kv_lora_rank), cfg.cdtype),
                    "kr": jnp.zeros((n, batch_size, S, cfg.mla.qk_rope_dim), cfg.cdtype)}
        return {"k": jnp.zeros((n, batch_size, S, cfg.n_kv_heads, cfg.hd), cfg.cdtype),
                "v": jnp.zeros((n, batch_size, S, cfg.n_kv_heads, cfg.hd), cfg.cdtype)}

    cache = {"scan": one(n_scan * cfg.pattern_len)}
    if cfg.dense_prefix:
        cache["prefix"] = one(cfg.dense_prefix)
    return cache


def cache_logical_specs(cfg: TransformerConfig, cache):
    """Logical sharding specs for a cache pytree: [L, B, S(seq), ...]."""
    def spec(x):
        return (None, "batch", "seq") + (None,) * (x.ndim - 3)
    return jax.tree.map(spec, cache)


def _attn_decode(ctx, cfg: TransformerConfig, lp, x, layer_cache, pos, window):
    """One decode-attention step.  ``pos`` is the per-slot position vector
    [B] — each batch slot applies RoPE, writes its KV, and masks its
    attention at its *own* length (continuous batching admits requests
    into freed slots at position 0 while neighbors keep counting)."""
    B = x.shape[0]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    if cfg.attn_type == "mla":
        c_new, kr_new = mla_mod.mla_latents_for_cache(
            lp["attn"], cfg.mla, h, pos[:, None])
        cc = cache_update(ctx, layer_cache["c"], c_new, pos)
        kr = cache_update(ctx, layer_cache["kr"], kr_new, pos)
        out = mla_mod.mla_decode_attention(ctx, lp["attn"], cfg.mla, h, cc, kr, pos)
        return out, {"c": cc, "kr": kr}
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qkv = h @ lp["attn"]["w_qkv"]
    q, k, v = jnp.split(qkv, [Hq * hd, (Hq + Hkv) * hd], axis=-1)
    q = q.reshape(B, 1, Hq, hd)
    k = k.reshape(B, 1, Hkv, hd)
    v = v.reshape(B, 1, Hkv, hd)
    positions = pos[:, None]                         # [B, 1] per-slot
    if cfg.rope_style == "mrope":  # text-phase decode: three equal streams
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q = _apply_rope_any(cfg, q, positions)
    k = _apply_rope_any(cfg, k, positions)
    kc = cache_update(ctx, layer_cache["k"], k, pos)
    vc = cache_update(ctx, layer_cache["v"], v, pos)
    o = decode_attention(ctx, q, kc, vc, pos, window=window,
                         scale=cfg.query_scale, softcap_val=cfg.attn_softcap)
    out = o.reshape(B, 1, Hq * hd) @ lp["attn"]["w_o"]
    return out, {"k": kc, "v": vc}


def _layer_decode(ctx, cfg, lp, x, layer_cache, pos, window):
    a, new_cache = _attn_decode(ctx, cfg, lp, x, layer_cache, pos, window)
    if cfg.post_norms:
        a = rms_norm(a, lp["post_ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    if cfg.moe is not None and "router" in lp["ffn"]:
        f = moe_mod.moe_apply(ctx, lp["ffn"], h, cfg.moe)
    else:
        f = mlp_apply(ctx, lp["ffn"], h, act=cfg.act, seq_sharded=False)
    if cfg.post_norms:
        f = rms_norm(f, lp["post_ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return x + f, new_cache


def decode_step(ctx: ParallelContext, params, cfg: TransformerConfig,
                tokens, cache, pos):
    """One decode step.  tokens: [B, 1]; pos: [B] int32 (0-based position
    of each slot's new token; a scalar broadcasts — every slot at the
    same offset, the pre-continuous-batching behavior).  Returns
    (logits [B, 1, V], updated cache)."""
    B = tokens.shape[0]
    pos = broadcast_pos(pos, B)
    scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False,
                         scale=scale).astype(cfg.cdtype)

    new_prefix = []
    for i, lp in enumerate(params.get("prefix", [])):
        lc = jax.tree.map(lambda c: c[i], cache["prefix"])
        x, nc = _layer_decode(ctx, cfg, lp["l0"], x, lc, pos, cfg.layer_window(0))
        new_prefix.append(nc)

    # cache threads through the scan as a *carry* with in-place
    # dynamic-update-slice writes, so a donated cache buffer aliases all
    # the way through the loop (no xs/ys double-buffering).
    n_scan_layers = (cfg.n_layers - cfg.dense_prefix)

    def group_body(carry, group_params):
        h, scan_cache, li = carry
        for i in range(cfg.pattern_len):
            lc = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(c, li + i, 0, keepdims=False),
                scan_cache)
            h, nc = _layer_decode(ctx, cfg, group_params[f"l{i}"], h, lc, pos,
                                  cfg.layer_window(i))
            scan_cache = jax.tree.map(
                lambda c, n: lax.dynamic_update_slice_in_dim(c, n[None], li + i,
                                                             axis=0),
                scan_cache, nc)
        return (h, scan_cache, li + cfg.pattern_len), ()

    (x, new_scan, _), _ = lax.scan(group_body, (x, cache["scan"], jnp.int32(0)),
                                   params["layers"])
    new_cache = {"scan": new_scan}
    if new_prefix:
        new_cache["prefix"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_prefix)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    logits = _lm_logits(ctx, params, cfg, x)
    return logits, new_cache


def _lm_logits(ctx, params, cfg, x):
    """Decode-time logits [B, 1, V] vocab-sharded over tp."""
    table = params["embed"]["table"]
    logits = jnp.einsum("bsd,vd->bsv", x.astype(cfg.cdtype),
                        table.astype(cfg.cdtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# --- paged serving (continuous batching) ---------------------------------
def init_paged_pool(cfg: TransformerConfig, num_blocks: int, block_size: int):
    """Zeroed paged KV block pools shared by all in-flight requests.

    Layout: {"scan": {"k": [L, NB, block, Hkv, hd], "v": ...}} (+ "prefix"
    for dense-prefix layers); blocks are sharded over tp, mapped to
    requests via host-side block tables (repro.serve.kv_cache).  GQA only
    — MLA keeps the dense latent cache for now (registry gates on
    ``supports_paged``)."""
    if cfg.attn_type != "gqa":
        raise NotImplementedError(
            f"paged KV requires attn_type='gqa' ({cfg.name} is {cfg.attn_type})")

    def one(n):
        shape = (n, num_blocks, block_size, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.cdtype),
                "v": jnp.zeros(shape, cfg.cdtype)}

    pool = {"scan": one(cfg.n_layers - cfg.dense_prefix)}
    if cfg.dense_prefix:
        pool["prefix"] = one(cfg.dense_prefix)
    return pool


def pool_logical_specs(cfg: TransformerConfig, pool):
    """Logical sharding specs for a paged pool: [L, NB(blocks/tp), ...]."""
    def spec(x):
        return (None, "seq") + (None,) * (x.ndim - 2)
    return jax.tree.map(spec, pool)


def _attn_serve(ctx, cfg: TransformerConfig, lp, x, layer_pool, tables,
                positions, valid, window):
    """Chunked attention against the paged pool.  x: [B, C, D]; positions
    [B, C] are per-slot global offsets (decode: C=1 at pos; prefill: a
    C-token chunk starting at pos); ``valid`` masks padding/idle rows out
    of the cache write.  The chunk's own KV lands in the pool *before*
    attention, so one causal pass covers both the cache and intra-chunk
    dependencies."""
    B, C, D = x.shape
    h = rms_norm(x, lp["ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qkv = h @ lp["attn"]["w_qkv"]
    q, k, v = jnp.split(qkv, [Hq * hd, (Hq + Hkv) * hd], axis=-1)
    q = q.reshape(B, C, Hq, hd)
    k = k.reshape(B, C, Hkv, hd)
    v = v.reshape(B, C, Hkv, hd)
    rpos = positions
    if cfg.rope_style == "mrope":   # text-phase serving: three equal streams
        rpos = jnp.broadcast_to(positions[None], (3, B, C))
    q = _apply_rope_any(cfg, q, rpos)
    k = _apply_rope_any(cfg, k, rpos)
    kc = paged_cache_update(ctx, layer_pool["k"], k, tables, positions, valid)
    vc = paged_cache_update(ctx, layer_pool["v"], v, tables, positions, valid)
    o = paged_attention(ctx, q, kc, vc, tables, positions, window=window,
                        scale=cfg.query_scale, softcap_val=cfg.attn_softcap)
    out = o.reshape(B, C, Hq * hd) @ lp["attn"]["w_o"]
    return out, {"k": kc, "v": vc}


def _layer_serve(ctx, cfg, lp, x, layer_pool, tables, positions, valid, window):
    a, new_pool = _attn_serve(ctx, cfg, lp, x, layer_pool, tables, positions,
                              valid, window)
    if cfg.post_norms:
        a = rms_norm(a, lp["post_ln1"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    if cfg.moe is not None and "router" in lp["ffn"]:
        f = moe_mod.moe_apply(ctx, lp["ffn"], h, cfg.moe)
    else:
        f = mlp_apply(ctx, lp["ffn"], h, act=cfg.act, seq_sharded=False)
    if cfg.post_norms:
        f = rms_norm(f, lp["post_ln2"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return x + f, new_pool


def serve_step(ctx: ParallelContext, params, cfg: TransformerConfig,
               tokens, pool, tables, pos, n_new):
    """One continuous-batching step mixing prefill chunks and decode.

    tokens: [B, C] (slot i's next n_new[i] tokens, zero-padded); tables:
    [B, MB] global block ids; pos: [B] first new position per slot;
    n_new: [B] with 0 = idle slot, 1 = decode step, >1 = prefill chunk.
    C is static, so jit traces exactly two graphs per engine: the
    chunked-prefill graph (C = chunk) and the decode fast path (C = 1).
    Returns (last-valid logits [B, V] f32, updated pool)."""
    B, C = tokens.shape
    pos = broadcast_pos(pos, B)
    n_new = jnp.asarray(n_new, jnp.int32)
    positions = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    valid = jnp.arange(C)[None, :] < n_new[:, None]
    scale = cfg.d_model ** 0.5 if cfg.embed_scale else None
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False,
                         scale=scale).astype(cfg.cdtype)

    new_prefix = []
    for i, lp in enumerate(params.get("prefix", [])):
        lc = jax.tree.map(lambda c: c[i], pool["prefix"])
        x, nc = _layer_serve(ctx, cfg, lp["l0"], x, lc, tables, positions,
                             valid, cfg.layer_window(0))
        new_prefix.append(nc)

    def group_body(carry, group_params):
        h, scan_pool, li = carry
        for i in range(cfg.pattern_len):
            lc = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(c, li + i, 0, keepdims=False),
                scan_pool)
            h, nc = _layer_serve(ctx, cfg, group_params[f"l{i}"], h, lc,
                                 tables, positions, valid, cfg.layer_window(i))
            scan_pool = jax.tree.map(
                lambda c, n: lax.dynamic_update_slice_in_dim(c, n[None], li + i,
                                                             axis=0),
                scan_pool, nc)
        return (h, scan_pool, li + cfg.pattern_len), ()

    (x, new_scan, _), _ = lax.scan(group_body, (x, pool["scan"], jnp.int32(0)),
                                   params["layers"])
    new_pool = {"scan": new_scan}
    if new_prefix:
        new_pool["prefix"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_prefix)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=cfg.norm_plus_one)
    # each slot's logits come from its last *valid* token (prefill chunks
    # only need the final position; idle slots produce garbage, discarded)
    idx = jnp.clip(n_new - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # [B,1,D]
    logits = _lm_logits(ctx, params, cfg, x_last)
    return logits[:, 0], new_pool
