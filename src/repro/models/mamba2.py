"""Mamba-2 (SSD) block with chunked selective scan.

Scalar-per-head decay makes the chunked form a plain matmul structure:
pairwise decay ratios exp(la_t - la_s) for s<=t are bounded in (0,1], so
the algorithm is numerically safe at any chunk size.  Heads are sharded
over tp (head counts divide 16 for the assigned configs); the out
projection is row-parallel with the fused matmul+AllReduce.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.matmul_allreduce import matmul_allreduce
from repro.models.common import dense_init, key_iter, zeros_init, ones_init
from repro.models.layers import rms_norm, rms_norm_init
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64          # N
    head_dim: int = 64         # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim


def mamba2_init(key, cfg: Mamba2Config, dtype):
    ks = key_iter(key)
    D, Di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        # in_proj -> [z, x, B, C, dt]
        "w_in": dense_init(next(ks), (D, 2 * Di + 2 * N + H), ("fsdp", "tp"), dtype),
        "conv": dense_init(next(ks), (cfg.conv_width, Di + 2 * N), (None, "tp"), dtype, scale=0.3),
        "A_log": zeros_init((H,), (None,), jnp.float32),
        "D": ones_init((H,), (None,), jnp.float32),
        "dt_bias": zeros_init((H,), (None,), jnp.float32),
        "norm": rms_norm_init(Di, jnp.float32),
        "w_out": dense_init(next(ks), (Di, D), ("tp", "fsdp"), dtype),
    }


def ssd_chunked(x, dt, A_log, B, C, state, chunk: int):
    """Chunked SSD scan.

    x: [b, T, H, P]; dt: [b, T, H]; B, C: [b, T, N]; state: [b, H, N, P].
    h_t = a_t h_{t-1} + dt_t B_t (x_t)^T ;  y_t = C_t . h_t
    with a_t = exp(-dt_t * exp(A_log_h)) scalar per head.
    """
    b, T, H, P = x.shape
    N = B.shape[-1]
    c = min(chunk, T)
    n_chunks = T // c
    a = -jnp.exp(A_log)[None, None] * dt                   # log a_t  [b,T,H]
    xs = (x.reshape(b, n_chunks, c, H, P).transpose(1, 0, 2, 3, 4),
          dt.reshape(b, n_chunks, c, H).transpose(1, 0, 2, 3),
          a.reshape(b, n_chunks, c, H).transpose(1, 0, 2, 3),
          B.reshape(b, n_chunks, c, N).transpose(1, 0, 2, 3),
          C.reshape(b, n_chunks, c, N).transpose(1, 0, 2, 3))

    def chunk_step(S, inp):
        xx, dtt, aa, BB, CC = inp                 # [b,c,H,P],[b,c,H],[b,c,H],[b,c,N]
        la = jnp.cumsum(aa, axis=1)               # inclusive cumulative log-decay
        # intra-chunk: y_t = sum_{s<=t} exp(la_t - la_s) (C_t.B_s) dt_s x_s
        dec = jnp.exp(jnp.clip(la[:, :, None] - la[:, None, :], -60.0, 0.0))
        mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])
        scores = jnp.einsum("btn,bsn->bts", CC, BB)[:, :, :, None] * \
            (dec * mask[None, :, :, None])        # [b,t,s,H]
        y = jnp.einsum("btsh,bsh,bshp->bthp", scores, dtt, xx)
        # inter-chunk: y_t += exp(la_t) C_t . S
        y = y + jnp.einsum("btn,bth,bhnp->bthp", CC, jnp.exp(jnp.clip(la, -60.0, 0.0)), S)
        # state: S' = exp(la_end) S + sum_s exp(la_end - la_s) dt_s B_s x_s^T
        la_end = la[:, -1]                        # [b,H]
        sdec = jnp.exp(jnp.clip(la_end[:, None] - la, -60.0, 0.0)) * dtt  # [b,c,H]
        S = jnp.exp(jnp.clip(la_end, -60.0, 0.0))[..., None, None] * S + \
            jnp.einsum("bsh,bsn,bshp->bhnp", sdec, BB, xx)
        return S, y

    # checkpoint per chunk (cf. rwkv6): avoids stacking the pairwise-decay
    # and score tensors across chunks as backward residuals
    state, y = lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                        state, xs)
    y = y.transpose(1, 0, 2, 3, 4).reshape(b, T, H, P)
    return y, state


def ssd_step(x, dt, A_log, B, C, state):
    """Single-token SSD step.  x: [b,1,H,P]; returns (y [b,1,H,P], state')."""
    xx, dtt, BB, CC = x[:, 0], dt[:, 0], B[:, 0], C[:, 0]
    a = jnp.exp(-jnp.exp(A_log)[None] * dtt)               # [b,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, BB, xx)
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", CC, state)
    return y[:, None], state


def _causal_conv(x, kernel, conv_state=None):
    """Depthwise causal conv1d.  x: [b, T, C]; kernel: [W, C]."""
    W = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i][None, None] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_state


def mamba2_apply(ctx: ParallelContext, p, cfg: Mamba2Config, x, *,
                 state=None, conv_state=None):
    """x: [B, T, D] replicated over tp (heads shard inside projections).

    Returns (out [B,T,D], (ssm_state, conv_state)) — states are None-safe
    for training (zero-init, discarded)."""
    b, T, D = x.shape
    Di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    zxbcdt = x @ p["w_in"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [Di, Di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    xh = xin.reshape(b, T, H, P).astype(jnp.float32)
    if state is None:
        state0 = jnp.zeros((b, H, N, P), jnp.float32)
        y, new_state = ssd_chunked(xh, dt, p["A_log"], Bc.astype(jnp.float32),
                                   Cc.astype(jnp.float32), state0, cfg.chunk)
    else:
        y, new_state = ssd_step(xh, dt, p["A_log"], Bc.astype(jnp.float32),
                                Cc.astype(jnp.float32), state)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, T, Di).astype(x.dtype)
    y = rms_norm(y, p["norm"]) * jax.nn.silu(z)
    # row-parallel out projection: fused matmul+AllReduce (paper op)
    out = matmul_allreduce(ctx, y, p["w_out"])
    return out, (new_state, new_conv)
