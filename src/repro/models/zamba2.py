"""Zamba2: Mamba-2 backbone + shared transformer (attention+MLP) blocks.

Structure (arXiv:2411.15242, adapted): a stack of Mamba2 blocks; every
``attn_every`` blocks, a *shared* transformer block (single parameter
set reused at every invocation, with small per-invocation LoRA deltas on
the QKV projection) is applied to the concatenation [hidden, embedding]
(2*d_model wide) and projected back to d_model.

The shared attention runs context-parallel (fused ring KV gather), the
Mamba out-projections and the shared-MLP down-projection use the fused
matmul+AllReduce — the paper's operators at every collective site.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.loss import sharded_cross_entropy
from repro.core.matmul_allreduce import matmul_allreduce
from repro.models import mamba2 as m2
from repro.models.attention import (broadcast_pos, cache_update,
                                    context_attention, decode_attention)
from repro.models.common import dense_init, key_iter
from repro.models.layers import (embedding_init, embedding_lookup, mlp_apply,
                                 mlp_init, rms_norm, rms_norm_init)
from repro.models.rope import apply_rope
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass(frozen=True)
class Zamba2Config:
    name: str
    n_layers: int               # total mamba blocks
    d_model: int
    n_heads: int                # shared-attention heads (on 2*d_model)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_state: int = 64
    attn_every: int = 6
    lora_r: int = 16
    rope_theta: float = 10000.0
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    max_seq: int = 4096
    remat: bool = True
    sub_quadratic: bool = True

    @property
    def d_attn(self):
        return 2 * self.d_model

    @property
    def hd(self):
        return self.d_attn // self.n_heads

    @property
    def n_groups(self):
        return self.n_layers // self.attn_every

    @property
    def n_tail(self):
        return self.n_layers % self.attn_every

    @property
    def mamba(self):
        return m2.Mamba2Config(d_model=self.d_model, d_state=self.d_state)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _shared_block_init(key, cfg: Zamba2Config):
    ks = key_iter(key)
    Da = cfg.d_attn
    qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
    return {
        "ln1": rms_norm_init(Da, jnp.float32),
        "w_qkv": dense_init(next(ks), (Da, qkv), ("fsdp", None), cfg.pdtype),
        "w_o": dense_init(next(ks), (cfg.n_heads * cfg.hd, Da), (None, "fsdp"), cfg.pdtype),
        "ln2": rms_norm_init(Da, jnp.float32),
        "mlp": mlp_init(next(ks), Da, cfg.d_ff, cfg.pdtype),
        "w_down": dense_init(next(ks), (Da, cfg.d_model), ("fsdp", None), cfg.pdtype),
    }


def _group_init(key, cfg: Zamba2Config):
    ks = key_iter(key)
    qkv = (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
    return {
        "mamba": [  # attn_every mamba blocks (unrolled within the group)
            {"ln": rms_norm_init(cfg.d_model, jnp.float32),
             "m": m2.mamba2_init(next(ks), cfg.mamba, cfg.pdtype)}
            for _ in range(cfg.attn_every)
        ],
        # per-invocation LoRA on the shared QKV
        "lora_a": dense_init(next(ks), (cfg.d_attn, cfg.lora_r), ("fsdp", None), cfg.pdtype, scale=0.01),
        "lora_b": dense_init(next(ks), (cfg.lora_r, qkv), (None, None), cfg.pdtype, scale=0.01),
    }


def zamba2_init(key, cfg: Zamba2Config):
    from repro.models.transformer import stacked_init
    ks = key_iter(key)
    params: dict[str, Any] = {
        "embed": embedding_init(next(ks), cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": rms_norm_init(cfg.d_model, jnp.float32),
        "shared": _shared_block_init(next(ks), cfg),
        "groups": stacked_init(next(ks), cfg.n_groups, lambda k: _group_init(k, cfg)),
        "tail": [
            {"ln": rms_norm_init(cfg.d_model, jnp.float32),
             "m": m2.mamba2_init(next(ks), cfg.mamba, cfg.pdtype)}
            for _ in range(cfg.n_tail)
        ],
    }
    return params


def _shared_attn(ctx, cfg: Zamba2Config, sp, gp, xcat, *, cache=None, pos=None):
    """Shared transformer block on [B, T, 2D]."""
    B, T, Da = xcat.shape
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(xcat, sp["ln1"])
    qkv = h @ sp["w_qkv"] + (h @ gp["lora_a"]) @ gp["lora_b"]
    q, k, v = jnp.split(qkv, [Hq * hd, (Hq + Hkv) * hd], axis=-1)
    q = q.reshape(B, T, Hq, hd)
    k = k.reshape(B, T, Hkv, hd)
    v = v.reshape(B, T, Hkv, hd)
    if cache is None:
        positions = jnp.arange(T)[None]
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        o = context_attention(ctx, q, k, v, causal=True)
        new_cache = None
    else:
        positions = pos[:, None]                     # [B, 1] per-slot
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        kc = cache_update(ctx, cache["k"], k, pos)
        vc = cache_update(ctx, cache["v"], v, pos)
        o = decode_attention(ctx, q, kc, vc, pos)
        new_cache = {"k": kc, "v": vc}
    x = xcat + o.reshape(B, T, Hq * hd) @ sp["w_o"]
    h2 = rms_norm(x, sp["ln2"])
    x = x + mlp_apply(ctx, sp["mlp"], h2, seq_sharded=cache is None)
    return x @ sp["w_down"], new_cache


def train_forward(ctx: ParallelContext, params, cfg: Zamba2Config, batch):
    tokens = batch["tokens"]
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)
    x0 = x
    shared = params["shared"]

    def group_body(h, gp):
        for mb in gp["mamba"]:
            a, _ = m2.mamba2_apply(ctx, mb["m"], cfg.mamba, rms_norm(h, mb["ln"]))
            h = h + a
        # shared attention every attn_every blocks, on [h, x0]
        hs = jax.lax.with_sharding_constraint(
            jnp.concatenate([h, x0], axis=-1), ctx.sharding("batch", "seq", None))
        delta, _ = _shared_attn(ctx, cfg, shared, gp, hs)
        delta = jax.lax.with_sharding_constraint(delta, ctx.sharding("batch", None, None))
        return h + delta, ()

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = lax.scan(lambda h, gp: body(h, gp), x, params["groups"])
    for mb in params["tail"]:
        a, _ = m2.mamba2_apply(ctx, mb["m"], cfg.mamba, rms_norm(x, mb["ln"]))
        x = x + a
    x = rms_norm(x, params["final_norm"])
    x = jax.lax.with_sharding_constraint(x, ctx.sharding("batch", "seq", None))
    return sharded_cross_entropy(ctx, x, params["embed"]["table"], batch["labels"])


def prefill_forward(ctx: ParallelContext, params, cfg: Zamba2Config, batch):
    """Prefill: forward over the prompt collecting SSM/conv states and the
    shared-attention KV; returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)
    x0 = x
    shared = params["shared"]

    def group_body(h, gp):
        ssms, convs = [], []
        for mb in gp["mamba"]:
            a, (s2, c2) = m2.mamba2_apply(ctx, mb["m"], cfg.mamba,
                                          rms_norm(h, mb["ln"]))
            h = h + a
            ssms.append(s2)
            convs.append(c2)
        hs = jax.lax.with_sharding_constraint(
            jnp.concatenate([h, x0], axis=-1), ctx.sharding("batch", "seq", None))
        Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        hh = rms_norm(hs, shared["ln1"])
        qkv = hh @ shared["w_qkv"] + (hh @ gp["lora_a"]) @ gp["lora_b"]
        q, k, v = jnp.split(qkv, [Hq * hd, (Hq + Hkv) * hd], axis=-1)
        positions = jnp.arange(S)[None]
        q = apply_rope(q.reshape(B, S, Hq, hd), positions, theta=cfg.rope_theta)
        k = apply_rope(k.reshape(B, S, Hkv, hd), positions, theta=cfg.rope_theta)
        v = v.reshape(B, S, Hkv, hd)
        o = context_attention(ctx, q, k, v, causal=True)
        xa = hs + o.reshape(B, S, Hq * hd) @ shared["w_o"]
        h2 = rms_norm(xa, shared["ln2"])
        xa = xa + mlp_apply(ctx, shared["mlp"], h2, seq_sharded=True)
        delta = xa @ shared["w_down"]
        delta = jax.lax.with_sharding_constraint(
            delta, ctx.sharding("batch", None, None))
        return h + delta, {"ssm": jnp.stack(ssms), "conv": jnp.stack(convs),
                           "k": k, "v": v}

    x, ys = lax.scan(group_body, x, params["groups"])
    tail_ssm, tail_conv = [], []
    for mb in params["tail"]:
        a, (s2, c2) = m2.mamba2_apply(ctx, mb["m"], cfg.mamba,
                                      rms_norm(x, mb["ln"]))
        x = x + a
        tail_ssm.append(s2)
        tail_conv.append(c2)
    cache = {"mamba": {"ssm": ys["ssm"], "conv": ys["conv"]},
             "attn": {"k": ys["k"], "v": ys["v"]},
             "tail": ({"ssm": jnp.stack(tail_ssm), "conv": jnp.stack(tail_conv)}
                      if params["tail"] else None)}
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), cache


def init_cache(cfg: Zamba2Config, batch_size: int):
    mc = cfg.mamba
    G, E = cfg.n_groups, cfg.attn_every
    def mstate(n):
        return {"ssm": jnp.zeros((n, E, batch_size, mc.n_heads, mc.d_state, mc.head_dim), jnp.float32),
                "conv": jnp.zeros((n, E, batch_size, mc.conv_width - 1, mc.d_inner + 2 * mc.d_state), cfg.cdtype)}
    cache = {
        "mamba": mstate(G),
        "attn": {"k": jnp.zeros((G, batch_size, cfg.max_seq, cfg.n_kv_heads, cfg.hd), cfg.cdtype),
                 "v": jnp.zeros((G, batch_size, cfg.max_seq, cfg.n_kv_heads, cfg.hd), cfg.cdtype)},
        "tail": {"ssm": jnp.zeros((max(cfg.n_tail, 1), batch_size, mc.n_heads, mc.d_state, mc.head_dim), jnp.float32),
                 "conv": jnp.zeros((max(cfg.n_tail, 1), batch_size, mc.conv_width - 1, mc.d_inner + 2 * mc.d_state), cfg.cdtype)},
    }
    return cache


def cache_logical_specs(cfg: Zamba2Config, cache):
    return {
        "mamba": {"ssm": (None, None, "batch", "heads", None, None),
                  "conv": (None, None, "batch", None, "tp")},
        "attn": {"k": (None, "batch", "seq", None, None),
                 "v": (None, "batch", "seq", None, None)},
        "tail": {"ssm": (None, "batch", "heads", None, None),
                 "conv": (None, "batch", None, "tp")},
    }


def decode_step(ctx: ParallelContext, params, cfg: Zamba2Config, tokens, cache, pos):
    pos = broadcast_pos(pos, tokens.shape[0])
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)
    x0 = x
    shared = params["shared"]

    def group_body(carry, gp):
        h, mcache, acache, gi = carry
        for i, mb in enumerate(gp["mamba"]):
            mst = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(
                    lax.dynamic_index_in_dim(c, gi, 0, keepdims=False),
                    i, 0, keepdims=False), mcache)
            a, (s2, c2) = m2.mamba2_apply(
                ctx, mb["m"], cfg.mamba, rms_norm(h, mb["ln"]),
                state=mst["ssm"], conv_state=mst["conv"])
            h = h + a
            new = {"ssm": s2, "conv": c2}
            mcache = jax.tree.map(
                lambda c, n: lax.dynamic_update_slice(
                    c, n[None, None],
                    (gi, jnp.int32(i)) + (jnp.int32(0),) * (c.ndim - 2)),
                mcache, new)
        hs = jnp.concatenate([h, x0], axis=-1)
        ast = jax.tree.map(
            lambda c: lax.dynamic_index_in_dim(c, gi, 0, keepdims=False), acache)
        delta, new_attn = _shared_attn(ctx, cfg, shared, gp, hs,
                                       cache=ast, pos=pos)
        acache = jax.tree.map(
            lambda c, n: lax.dynamic_update_slice_in_dim(c, n[None], gi, axis=0),
            acache, new_attn)
        return (h + delta, mcache, acache, gi + 1), ()

    (x, new_mamba, new_attn, _), _ = lax.scan(
        group_body, (x, cache["mamba"], cache["attn"], jnp.int32(0)),
        params["groups"])
    new_tail_ssm, new_tail_conv = [], []
    for i, mb in enumerate(params["tail"]):
        a, (s2, c2) = m2.mamba2_apply(
            ctx, mb["m"], cfg.mamba, rms_norm(x, mb["ln"]),
            state=cache["tail"]["ssm"][i], conv_state=cache["tail"]["conv"][i])
        x = x + a
        new_tail_ssm.append(s2)
        new_tail_conv.append(c2)
    new_cache = {"mamba": new_mamba, "attn": new_attn,
                 "tail": ({"ssm": jnp.stack(new_tail_ssm), "conv": jnp.stack(new_tail_conv)}
                          if params["tail"] else cache["tail"])}
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), new_cache
