"""Mixture-of-Experts layer with expert parallelism and fused GEMM+All-to-All.

Experts are sharded over the tp axis (EP); tokens arrive sequence-sharded,
so the dispatch/combine All-to-Alls move tokens between tp ranks within
each data row.  The combine All-to-All is fused into the expert FFN
(paper §III, GEMM+All-to-All): the FFN is evaluated per combine
destination and each destination's output block is shipped the moment it
is computed, farthest peer first, local block last.  The dispatch
All-to-All is decomposed the same way (beyond-paper symmetric fusion).

Capacity-based routing (top-k, capacity factor, dropped tokens fall back
to the residual stream) matches the paper's uniform-workload assumption
while staying robust to imbalance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.collectives import direct_all_to_all_compute, bulk_all_to_all
from repro.kernels.flatmesh import needs_flat_world
from repro.models.common import dense_init, key_iter
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                     # per-expert hidden dim
    n_shared_experts: int = 0     # deepseek-style shared expert(s)
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    router_scale: float = 1.0     # deepseek-v3 routed_scaling_factor
    act: str = "silu"


def moe_init(key, cfg: MoEConfig, dtype):
    ks = key_iter(key)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    params = {
        "router": dense_init(next(ks), (D, E), (None, None), jnp.float32),
        "w_gate": dense_init(next(ks), (E, D, F), ("tp", "fsdp", None), dtype),
        "w_up": dense_init(next(ks), (E, D, F), ("tp", "fsdp", None), dtype),
        "w_down": dense_init(next(ks), (E, F, D), ("tp", None, "fsdp"), dtype),
    }
    if cfg.n_shared_experts:
        Fs = cfg.d_ff * cfg.n_shared_experts
        params["shared"] = {
            "w_gate": dense_init(next(ks), (D, Fs), ("fsdp", None), dtype),
            "w_up": dense_init(next(ks), (D, Fs), ("fsdp", None), dtype),
            "w_down": dense_init(next(ks), (Fs, D), (None, "fsdp"), dtype),
        }
    return params


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def ep_world_axes(ctx: ParallelContext):
    """Axes the decode EP layout shards experts over: (data, model)."""
    return tuple(a for a in ctx.mesh.axis_names if a != "pod")


def moe_apply(ctx: ParallelContext, params, x, cfg: MoEConfig, *,
              mode: str | None = None):
    """x: [B, S, D] sequence-sharded over tp -> same shape/sharding."""
    mode = mode or ctx.fusion.resolve("moe_a2a")
    schedule = ctx.fusion.schedule
    axis, n_ep = ctx.tp_axis, ctx.tp
    B, S, D = x.shape
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    seq_sharded = S % n_ep == 0 and S >= n_ep
    x_spec = P(dp, axis, None) if seq_sharded else P(dp, None, None)
    act = _ACTS[cfg.act]

    # Decode (S==1): weight-stationary EP over the full (data x model)
    # world — each device holds whole experts, tokens move instead of
    # weights.  Kills the per-layer FSDP expert-weight all-gathers that
    # otherwise dominate the serve-step memory term.
    ep_ax = ep_world_axes(ctx)
    n_world_ep = 1
    for a in ep_ax:
        n_world_ep *= ctx.mesh.shape[a]
    if not seq_sharded and cfg.n_experts % n_world_ep == 0 and len(ep_ax) >= 2:
        return _moe_decode_ep(ctx, params, x, cfg, act, ep_ax, n_world_ep)

    if mode == "kernel":
        from repro.kernels.fused_gemm_a2a.ops import (
            fused_gemm_a2a_kernel_available)

        if not fused_gemm_a2a_kernel_available(ctx.mesh):
            mode = "fused"
        elif needs_flat_world(ctx.mesh):
            # the chained Pallas kernels cannot run inside the model's
            # multi-axis shard_map under the interpreter — stage the layer
            # as routing -> global chained kernel -> unpermute so the
            # kernel entry can flatten the mesh itself
            return _moe_kernel_staged(ctx, params, x, cfg, act, schedule,
                                      x_spec, dp)

    shared = params.get("shared")
    if shared is not None:
        def fn(xl, w_r, wg, wu, wd, swg, swu, swd):
            return _moe_local(cfg, xl, w_r, wg, wu, wd, (swg, swu, swd),
                              mode, schedule, axis, n_ep, act,
                              skew=ctx.fusion.skew)
        in_specs = (x_spec, P(None, None), P(axis, None, None),
                    P(axis, None, None), P(axis, None, None),
                    P(None, None), P(None, None), P(None, None))
        args = (x, params["router"], params["w_gate"], params["w_up"],
                params["w_down"], shared["w_gate"], shared["w_up"],
                shared["w_down"])
    else:
        def fn(xl, w_r, wg, wu, wd):
            return _moe_local(cfg, xl, w_r, wg, wu, wd, None,
                              mode, schedule, axis, n_ep, act,
                              skew=ctx.fusion.skew)
        in_specs = (x_spec, P(None, None), P(axis, None, None),
                    P(axis, None, None), P(axis, None, None))
        args = (x, params["router"], params["w_gate"], params["w_up"],
                params["w_down"])

    return shard_map(
        fn, mesh=ctx.mesh, in_specs=in_specs, out_specs=x_spec,
        check_vma=False,
    )(*args)


def _moe_decode_ep(ctx: ParallelContext, params, x, cfg: MoEConfig, act,
                   ep_ax, n_world_ep):
    """Weight-stationary decode MoE: experts sharded over (data x model).

    Tokens are all-gathered over 'data' (KB-scale), each rank runs its
    local experts on the tokens routed to it, and a psum over the EP axes
    combines contributions.  No expert-weight gathers at all."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // n_world_ep
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    data_ax = ep_ax[0]              # batch rides this axis
    n_data = ctx.mesh.shape[data_ax]

    def local_fn(xl, w_r, wg, wu, wd):
        # gather this pod's tokens over 'data' (tiny: [B_pod, D])
        toks = xl.reshape(-1, D)
        if dp is not None:
            toks = lax.all_gather(toks, data_ax, axis=0, tiled=True)
        T = toks.shape[0]
        # redundant routing (router weights replicated, T is small)
        logits = toks.astype(jnp.float32) @ w_r
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_i = lax.top_k(probs, K)
        if cfg.norm_topk_prob:
            gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        gate_w = gate_w * cfg.router_scale
        C = int(max(1, -(-T * K * cfg.capacity_factor // E)))
        flat_e = gate_i.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        # my expert range: [my_ep_idx * E_loc, ...)
        idxs = [lax.axis_index(a) for a in ep_ax]
        my_ep = idxs[0]
        for a, i in zip(ep_ax[1:], idxs[1:]):
            my_ep = my_ep * ctx.mesh.shape[a] + i
        e_rel = flat_e - my_ep * E_loc
        mine = (e_rel >= 0) & (e_rel < E_loc) & (pos < C)
        e_clip = jnp.where(mine, e_rel, 0)
        p_clip = jnp.where(mine, pos, 0)
        src = jnp.where(mine[:, None], jnp.repeat(toks, K, axis=0), 0)
        buf = jnp.zeros((E_loc, C, D), x.dtype).at[e_clip, p_clip].add(
            src.astype(x.dtype), mode="drop")
        # local expert FFN (weights stationary)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", act(g) * u, wd)
        # scatter my contributions back to token rows, weighted
        contrib = out_buf[e_clip, p_clip]                      # [T*K, D]
        w = jnp.where(mine, gate_w.reshape(-1), 0.0)
        y = jnp.zeros((T, D), jnp.float32).at[
            jnp.repeat(jnp.arange(T), K)].add(
            contrib.astype(jnp.float32) * w[:, None])
        y = lax.psum(y, ep_ax)                                 # combine
        if dp is not None:
            d = lax.axis_index(data_ax)
            t_loc = T // n_data
            y = lax.dynamic_slice_in_dim(y, d * t_loc, t_loc, axis=0)
        return y.reshape(xl.shape).astype(xl.dtype)

    out = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(ep_ax, None, None), P(ep_ax, None, None),
                  P(ep_ax, None, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])

    shared = params.get("shared")
    if shared is not None:
        out = out + ((act(x @ shared["w_gate"]) * (x @ shared["w_up"]))
                     @ shared["w_down"]).astype(out.dtype)
    return out


def _route(cfg: MoEConfig, toks, w_r):
    """Capacity-based top-k routing (f32).  Deterministic in the tokens,
    so the staged kernel path can recompute it on the unpermute side
    instead of threading index arrays through the exchange.

    Returns (gate_w [T, K], e_clip [T*K], p_clip [T*K], valid [T*K], C).
    """
    E, K = cfg.n_experts, cfg.top_k
    T = toks.shape[0]
    logits = toks.astype(jnp.float32) @ w_r
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = lax.top_k(probs, K)                  # [T, K]
    if cfg.norm_topk_prob:
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    gate_w = gate_w * cfg.router_scale
    # capacity floor 1 (a floor of 4 pads decode's few tokens/rank 4x)
    C = int(max(1, -(-T * K * cfg.capacity_factor // E)))
    flat_e = gate_i.reshape(-1)                           # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    valid = pos < C
    e_clip = jnp.where(valid, flat_e, 0)
    p_clip = jnp.where(valid, pos, 0)
    return gate_w, e_clip, p_clip, valid, C


def _dispatch_buf(cfg: MoEConfig, toks, e_clip, p_clip, valid, C, dtype):
    """Scatter routed tokens into the [E, C, D] capacity-slot buffer."""
    E, D = cfg.n_experts, cfg.d_model
    src = jnp.where(valid[:, None], jnp.repeat(toks, cfg.top_k, axis=0), 0)
    return jnp.zeros((E, C, D), dtype).at[e_clip, p_clip].add(
        src.astype(dtype), mode="drop")


def _unpermute(cfg: MoEConfig, out_buf, gate_w, e_clip, p_clip, valid, shape,
               dtype):
    """Gather expert outputs back to token rows, gate-weighted."""
    K, D = cfg.top_k, cfg.d_model
    picked = out_buf[e_clip, p_clip]                      # [T*K, D]
    picked = jnp.where(valid[:, None], picked, 0).reshape(-1, K, D)
    y = (picked.astype(jnp.float32) * gate_w[..., None]).sum(axis=1)
    return y.reshape(shape).astype(dtype)


def _moe_local(cfg, xl, w_r, wg, wu, wd, shared, mode, schedule, axis,
               n_ep, act, skew=0):
    """Per-rank MoE body: route -> dispatch A2A -> fused expert FFN+combine."""
    D, E = cfg.d_model, cfg.n_experts
    E_loc = E // n_ep
    toks = xl.reshape(-1, D)

    # --- routing + capacity slots -------------------------------------------
    gate_w, e_clip, p_clip, valid, C = _route(cfg, toks, w_r)
    buf = _dispatch_buf(cfg, toks, e_clip, p_clip, valid, C, xl.dtype)
    buf = buf.reshape(n_ep, E_loc, C, D)

    def ffn(xb):  # [E_loc, C, D] -> [E_loc, C, D]
        g = jnp.einsum("ecd,edf->ecf", xb, wg)
        u = jnp.einsum("ecd,edf->ecf", xb, wu)
        return jnp.einsum("ecf,efd->ecd", act(g) * u, wd)

    if mode == "kernel":
        # chained device-initiated dispatch -> FFN -> combine: the dispatch
        # kernel's rx buffer feeds the FFN+combine kernel directly
        from repro.kernels.fused_gemm_a2a.ops import fused_moe_chain_shard

        comb = fused_moe_chain_shard(
            buf[:, None], wu, wg, wd, axis, act=act,
            comm_aware=schedule == "comm_aware", skew=skew)[:, 0]
    elif mode == "bulk":
        recv = bulk_all_to_all(buf, axis)                 # [n_src, E_loc, C, D]
        y = jax.vmap(ffn)(recv)                           # all GEMMs first...
        comb = bulk_all_to_all(y, axis)                   # ...then one A2A
    else:
        # --- dispatch All-to-All (decomposed per destination) --------------
        def produce_d(dest):
            return lax.dynamic_index_in_dim(buf, dest, axis=0, keepdims=False)
        recv = direct_all_to_all_compute(
            produce_d, jax.ShapeDtypeStruct((E_loc, C, D), xl.dtype),
            axis, schedule=schedule)

        # --- expert FFN fused with combine A2A (the paper's GEMM+A2A) ------
        def produce_c(dest):
            xb = lax.dynamic_index_in_dim(recv, dest, axis=0, keepdims=False)
            return ffn(xb)
        comb = direct_all_to_all_compute(
            produce_c, jax.ShapeDtypeStruct((E_loc, C, D), xl.dtype),
            axis, schedule=schedule)

    # --- un-permute + weighted combine --------------------------------------
    out = _unpermute(cfg, comb.reshape(E, C, D), gate_w, e_clip, p_clip,
                     valid, xl.shape, xl.dtype)

    # --- shared expert (dense, sequence-local) ------------------------------
    if shared is not None:
        swg, swu, swd = shared
        out = out + ((act(xl @ swg) * (xl @ swu)) @ swd).astype(xl.dtype)
    return out


def _moe_kernel_staged(ctx: ParallelContext, params, x, cfg: MoEConfig, act,
                       schedule, x_spec, dp):
    """Three-stage kernel-mode layer for meshes the interpreter cannot map
    the chained kernels over directly (multi-axis under interpret mode):

      1. routing shard_map emits each rank's dispatch buffer into the
         global ``[rows, n_ep, E, C, D]`` layout,
      2. :func:`repro.core.fused.fused_moe_kernel` runs the chained
         dispatch -> FFN -> combine over its own (flattened) shard_map,
      3. an unpermute shard_map recomputes the (deterministic) routing
         and gathers expert outputs back to token rows.
    """
    from repro.kernels.fused_gemm_a2a.ops import fused_moe_kernel

    axis, n_ep = ctx.tp_axis, ctx.tp
    D, E = cfg.d_model, cfg.n_experts
    E_loc = E // n_ep
    w_r = params["router"]
    skew = ctx.fusion.skew

    def route_fn(xl, wr):
        toks = xl.reshape(-1, D)
        _, e_clip, p_clip, valid, C = _route(cfg, toks, wr)
        buf = _dispatch_buf(cfg, toks, e_clip, p_clip, valid, C, xl.dtype)
        return buf.reshape(1, n_ep, E_loc, C, D)

    buf_spec = P(dp, None, axis, None, None)
    buf = shard_map(route_fn, mesh=ctx.mesh,
                    in_specs=(x_spec, P(None, None)), out_specs=buf_spec,
                    check_vma=False)(x, w_r)

    comb = fused_moe_kernel(ctx, buf, params["w_up"], params["w_gate"],
                            params["w_down"], act=act,
                            comm_aware=schedule == "comm_aware", skew=skew)

    shared = params.get("shared")

    def unpermute_fn(xl, wr, cl, *sw):
        toks = xl.reshape(-1, D)
        gate_w, e_clip, p_clip, valid, _ = _route(cfg, toks, wr)
        out_buf = cl[0].reshape(E, -1, D)
        out = _unpermute(cfg, out_buf, gate_w, e_clip, p_clip, valid,
                         xl.shape, xl.dtype)
        if sw:
            swg, swu, swd = sw
            out = out + ((act(xl @ swg) * (xl @ swu)) @ swd).astype(xl.dtype)
        return out

    in_specs = (x_spec, P(None, None), buf_spec)
    args = (x, w_r, comb)
    if shared is not None:
        in_specs += (P(None, None), P(None, None), P(None, None))
        args += (shared["w_gate"], shared["w_up"], shared["w_down"])
    return shard_map(unpermute_fn, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=x_spec, check_vma=False)(*args)


def moe_aux_loss(router_probs, gate_i, n_experts: int):
    """Load-balance auxiliary loss (Switch-style)."""
    me = router_probs.mean(axis=0)
    onehot = jax.nn.one_hot(gate_i[:, 0], n_experts)
    ce = onehot.mean(axis=0)
    return n_experts * jnp.sum(me * ce)
