"""RWKV-6 "Finch": attention-free time-mix with data-dependent decay.

Arch-applicability (DESIGN.md): the WKV6 recurrence is head-local, so we
shard heads over tp — there is no dependent collective *inside* the
recurrence to fuse.  The paper's technique applies to the surrounding
projections: the time-mix output projection and the channel-mix value
projection are row-parallel matmuls whose AllReduce is fused
(matmul_allreduce), and the receptance/key/value/gate projections are
column-parallel.

The recurrence itself is evaluated chunkwise (GLA-style): pairwise decay
ratios exp(lc_t - lc_s), s<=t, stay in (0,1] so the chunked form is
numerically safe at any chunk length; cross-chunk state is carried by a
scan.  ``repro.kernels.rwkv6`` provides the Pallas TPU kernel for this
hot spot; this module is the XLA fallback and the kernels' oracle source.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.loss import sharded_cross_entropy
from repro.core.matmul_allreduce import matmul_allreduce
from repro.models.common import Param, dense_init, key_iter, zeros_init
from repro.models.layers import embedding_lookup, embedding_init, rms_norm, rms_norm_init
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    head_size: int = 64
    lora_r: int = 64            # decay/token-shift LoRA rank
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    chunk: int = 64
    remat: bool = True
    sub_quadratic: bool = True

    @property
    def n_heads(self):
        return self.d_model // self.head_size

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _layer_init(key, cfg: RWKV6Config):
    ks = key_iter(key)
    D, R = cfg.d_model, cfg.lora_r
    tm = {
        # data-dependent token-shift mixing (5 streams: r,k,v,w,g)
        "mu": zeros_init((5, D), (None, None), jnp.float32),
        "lora_a": dense_init(next(ks), (D, 5 * R), ("fsdp", None), cfg.pdtype, scale=0.01),
        "lora_b": dense_init(next(ks), (5, R, D), (None, None, "fsdp"), cfg.pdtype, scale=0.01),
        "w_r": dense_init(next(ks), (D, D), ("fsdp", "tp"), cfg.pdtype),
        "w_k": dense_init(next(ks), (D, D), ("fsdp", "tp"), cfg.pdtype),
        "w_v": dense_init(next(ks), (D, D), ("fsdp", "tp"), cfg.pdtype),
        "w_g": dense_init(next(ks), (D, D), ("fsdp", "tp"), cfg.pdtype),
        # data-dependent decay: w = exp(-exp(w0 + lora_w(x)))
        "w0": zeros_init((D,), (None,), jnp.float32),
        "wlora_a": dense_init(next(ks), (D, R), ("fsdp", None), cfg.pdtype, scale=0.01),
        "wlora_b": dense_init(next(ks), (R, D), (None, "fsdp"), cfg.pdtype, scale=0.01),
        "u": zeros_init((D,), (None,), jnp.float32),   # bonus
        "ln_x": rms_norm_init(D, jnp.float32),
        "w_o": dense_init(next(ks), (D, D), ("tp", "fsdp"), cfg.pdtype),
    }
    cm = {
        "mu": zeros_init((2, D), (None, None), jnp.float32),
        "w_k": dense_init(next(ks), (D, cfg.d_ff), ("fsdp", "tp"), cfg.pdtype),
        "w_v": dense_init(next(ks), (cfg.d_ff, D), ("tp", "fsdp"), cfg.pdtype),
        "w_r": dense_init(next(ks), (D, D), ("fsdp", None), cfg.pdtype),
    }
    return {"ln1": rms_norm_init(D, jnp.float32), "tm": tm,
            "ln2": rms_norm_init(D, jnp.float32), "cm": cm}


def rwkv6_init(key, cfg: RWKV6Config):
    from repro.models.transformer import stacked_init
    ks = key_iter(key)
    return {
        "embed": embedding_init(next(ks), cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": rms_norm_init(cfg.d_model, jnp.float32),
        "layers": stacked_init(next(ks), cfg.n_layers, lambda k: _layer_init(k, cfg)),
    }


# ---------------------------------------------------------------------------
# chunked WKV6 recurrence (per head):  S_t = diag(w_t) S_{t-1} + k_t^T v_t
#                                      o_t = r_t (diag(u) k_t^T v_t + S_{t-1}... )
# RWKV6 convention: o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
# ---------------------------------------------------------------------------
def wkv6_chunked(r, k, v, w, u, state, chunk: int):
    """r,k,v,w: [B, T, H, N] (w = per-channel decay in (0,1)); u: [H, N].
    state: [B, H, N, N] carry.  Returns (o [B,T,H,N], state')."""
    B, T, H, N = r.shape
    c = min(chunk, T)
    n_chunks = T // c
    rc = r.reshape(B, n_chunks, c, H, N)
    kc = k.reshape(B, n_chunks, c, H, N)
    vc = v.reshape(B, n_chunks, c, H, N)
    lw = jnp.log(jnp.clip(w, 1e-8, 1.0)).reshape(B, n_chunks, c, H, N)

    def chunk_step(S, xs):
        rr, kk, vv, ll = xs                       # [B, c, H, N]
        lc = jnp.cumsum(ll, axis=1)               # inclusive cumulative log-decay
        # intra-chunk: o_t += sum_{s<t} (r_t * exp(lc_{t-1} - lc_s)) . k_s  v_s
        #   decay from s (exclusive) to t (exclusive of t's own w): prod_{s<i<t} w_i
        #   = exp(lc_{t-1} - lc_s); plus the diag(u) bonus for s == t.
        lc_tm1 = lc - ll                          # cumulative up to t-1
        # pairwise per-channel decay: [B, c(t), c(s), H, N], bounded (0,1]
        dec = jnp.exp(jnp.clip(lc_tm1[:, :, None] - lc[:, None, :], -60.0, 0.0))
        mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])
        dec = dec * mask[None, :, :, None, None]
        att = jnp.einsum("bthn,btshn,bshn->btsh", rr, dec, kk)
        o = jnp.einsum("btsh,bshn->bthn", att, vv)
        # bonus (s == t): r_t . diag(u) k_t  scaling v_t
        o = o + (rr * u[None, None] * kk).sum(-1, keepdims=True) * vv
        # inter-chunk: o_t += (r_t * exp(lc_{t-1})) . S
        rdec = rr * jnp.exp(jnp.clip(lc_tm1, -60.0, 0.0))
        o = o + jnp.einsum("bthn,bhnm->bthm", rdec, S)
        # state update: S' = diag(prod w) S + sum_s exp(lc_end - lc_s) k_s^T v_s
        lc_end = lc[:, -1]                        # [B, H, N]
        kdec = kk * jnp.exp(jnp.clip(lc_end[:, None] - lc, -60.0, 0.0))
        S = jnp.exp(jnp.clip(lc_end, -60.0, 0.0))[..., None] * S + \
            jnp.einsum("bshn,bshm->bhnm", kdec, vv)
        return S, o

    xs = (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4))
    # checkpoint per chunk: without it the scan saves each chunk's pairwise
    # decay tensor [b,c,c,H,N] for backward — the dominant memory term
    state, o = lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                        state, xs)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N)
    return o, state


def wkv6_step(r, k, v, w, u, state):
    """Single-token recurrence (decode).  r..w: [B, 1, H, N]."""
    rr, kk, vv, ww = (t[:, 0] for t in (r, k, v, w))
    kv = jnp.einsum("bhn,bhm->bhnm", kk, vv)
    o = jnp.einsum("bhn,bhnm->bhm", rr, state + u[None, :, :, None] * kv)
    state = ww[..., None] * state + kv
    return o[:, None], state


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _ddlerp(x, x_prev, mu, lora_a, lora_b):
    """RWKV6 data-dependent token shift for 5 streams at once.

    x, x_prev: [B,T,D]; returns [5, B, T, D]."""
    delta = x_prev - x
    base = x + delta * mu[:, None, None]          # [5, B, T, D] via broadcast
    xx = x + delta * mu[0][None, None]            # probe stream for the lora
    r_ = jnp.tanh(xx @ lora_a)                    # [B,T,5R]
    R = lora_b.shape[1]
    r5 = r_.reshape(x.shape[0], x.shape[1], 5, R)
    adj = jnp.einsum("btfr,frd->fbtd", r5, lora_b)
    return (base + delta[None] * adj).astype(x.dtype)


def _shift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def time_mix(ctx, p, cfg: RWKV6Config, x, x_prev=None, state=None):
    """x: [B,T,D] replicated over tp (heads sharded inside projections)."""
    B, T, D = x.shape
    H, N = cfg.n_heads, cfg.head_size
    xp = _shift(x) if x_prev is None else jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    streams = _ddlerp(x, xp, p["mu"], p["lora_a"], p["lora_b"])
    xr, xk, xv, xw, xg = streams
    r = (xr @ p["w_r"]).reshape(B, T, H, N)
    k = (xk @ p["w_k"]).reshape(B, T, H, N)
    v = (xv @ p["w_v"]).reshape(B, T, H, N)
    g = jax.nn.silu(xg @ p["w_g"])
    lw = p["w0"][None, None] + jnp.tanh(xw @ p["wlora_a"]) @ p["wlora_b"]
    w = jnp.exp(-jnp.exp(lw.astype(jnp.float32))).reshape(B, T, H, N)
    u = p["u"].reshape(H, N)
    if state is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)
        o, new_state = wkv6_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                                    v.astype(jnp.float32), w, u, state0, cfg.chunk)
    else:
        o, new_state = wkv6_step(r.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), w, u, state)
    o = o.reshape(B, T, D).astype(x.dtype)
    o = rms_norm(o, p["ln_x"]) * g
    # row-parallel output projection: the paper's GEMV/GEMM + AllReduce
    return matmul_allreduce(ctx, o, p["w_o"]), new_state


def channel_mix(ctx, p, x, x_prev=None):
    xp = _shift(x) if x_prev is None else jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    delta = xp - x
    xk = (x + delta * p["mu"][0][None, None]).astype(x.dtype)
    xr = (x + delta * p["mu"][1][None, None]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    v = matmul_allreduce(ctx, k, p["w_v"])         # fused GEMM+AllReduce
    r = jax.nn.sigmoid(xr @ p["w_r"])
    return r * v


def train_forward(ctx: ParallelContext, params, cfg: RWKV6Config, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)

    def body(h, lp):
        a, _ = time_mix(ctx, lp["tm"], cfg, rms_norm(h, lp["ln1"]))
        h = h + a
        h = h + channel_mix(ctx, lp["cm"], rms_norm(h, lp["ln2"]))
        return h, ()

    fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = lax.scan(fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    # reshard to sequence-sharded for the fused vocab-parallel CE
    x = jax.lax.with_sharding_constraint(x, ctx.sharding("batch", "seq", None))
    return sharded_cross_entropy(ctx, x, params["embed"]["table"], batch["labels"])


def prefill_forward(ctx: ParallelContext, params, cfg: RWKV6Config, batch):
    """Prefill: forward over the prompt collecting the recurrent state per
    layer; returns (last-position logits [B,1,V], state)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)

    def body(h, lp):
        xin = rms_norm(h, lp["ln1"])
        a, wkv = time_mix(ctx, lp["tm"], cfg, xin)
        h = h + a
        xin2 = rms_norm(h, lp["ln2"])
        h = h + channel_mix(ctx, lp["cm"], xin2)
        return h, {"tm_x": xin[:, -1:], "cm_x": xin2[:, -1:], "wkv": wkv}

    x, state = lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), state


def init_state(cfg: RWKV6Config, batch_size: int):
    """Decode state: per layer (x_prev_tm, x_prev_cm, wkv state)."""
    D, H, N = cfg.d_model, cfg.n_heads, cfg.head_size
    L = cfg.n_layers
    return {
        "tm_x": jnp.zeros((L, batch_size, 1, D), cfg.cdtype),
        "cm_x": jnp.zeros((L, batch_size, 1, D), cfg.cdtype),
        "wkv": jnp.zeros((L, batch_size, H, N, N), jnp.float32),
    }


def state_logical_specs(cfg, state):
    return {
        "tm_x": (None, "batch", None, None),
        "cm_x": (None, "batch", None, None),
        "wkv": (None, "batch", "heads", None, None),
    }


def decode_step(ctx: ParallelContext, params, cfg: RWKV6Config, tokens, state, pos):
    B = tokens.shape[0]
    x = embedding_lookup(ctx, params["embed"], tokens, seq_shard=False)
    x = x.astype(cfg.cdtype)

    def body(h, scanned):
        lp, st = scanned
        xin = rms_norm(h, lp["ln1"])
        a, wkv = time_mix(ctx, lp["tm"], cfg, xin, x_prev=st["tm_x"], state=st["wkv"])
        h = h + a
        xin2 = rms_norm(h, lp["ln2"])
        h = h + channel_mix(ctx, lp["cm"], xin2, x_prev=st["cm_x"])
        return h, {"tm_x": xin, "cm_x": xin2, "wkv": wkv}

    x, new_state = lax.scan(body, x, (params["layers"], state))
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), new_state
