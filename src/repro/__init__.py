"""repro: fused computation-collective distributed ML framework (JAX/TPU).

Reproduction + extension of "Optimizing Distributed ML Communication with
Fused Computation-Collective Operations" (Punniyamurthy et al., 2023).
"""

__version__ = "1.0.0"
