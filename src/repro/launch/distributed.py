"""Multi-host initialization for real TPU pods.

On a v5e pod slice every host runs the same binary;
``jax.distributed.initialize()`` wires the hosts together (coordinator
from the TPU metadata on GCP, or explicit addresses elsewhere).  After
init, ``jax.devices()`` spans the slice and `make_production_mesh()`
builds the global mesh exactly as the dry-run proved it.
"""
from __future__ import annotations

import logging
import os

import jax

log = logging.getLogger("repro.launch")


def initialize_distributed(coordinator: str | None = None,
                           num_processes: int | None = None,
                           process_id: int | None = None):
    """Idempotent multi-host init.

    On GCP TPU VMs all arguments are discovered from the metadata server;
    elsewhere pass coordinator ("host:port"), num_processes, process_id
    (or set JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES / JAX_PROCESS_ID).
    """
    if jax.process_count() > 1:
        return  # already initialized
    kwargs = {}
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if coordinator:
        kwargs = dict(
            coordinator_address=coordinator,
            num_processes=num_processes or int(os.environ["JAX_NUM_PROCESSES"]),
            process_id=process_id or int(os.environ["JAX_PROCESS_ID"]),
        )
    try:
        jax.distributed.initialize(**kwargs)
        log.info("distributed init: process %d/%d, %d devices (%d local)",
                 jax.process_index(), jax.process_count(),
                 len(jax.devices()), len(jax.local_devices()))
    except Exception as e:  # single-host dev boxes
        log.info("single-host mode (%s)", e)


def assert_production_topology(multi_pod: bool = False):
    want = 512 if multi_pod else 256
    have = len(jax.devices())
    if have != want:
        raise RuntimeError(
            f"expected {want} chips for the "
            f"{'multi-pod' if multi_pod else 'single-pod'} mesh, found "
            f"{have}; adjust --mesh or the slice size")
