"""Multi-host initialization for real TPU pods (and the CPU test lane).

On a v5e pod slice every host runs the same binary;
``jax.distributed.initialize()`` wires the hosts together (coordinator
from the TPU metadata on GCP, or explicit addresses elsewhere).  After
init, ``jax.devices()`` spans the slice and `make_production_mesh()`
builds the global mesh exactly as the dry-run proved it.  The same entry
point wires the multi-process CPU lane (:mod:`repro.runtime.
multiprocess`), which passes explicit coordinator/world/rank.
"""
from __future__ import annotations

import logging
import os

import jax

log = logging.getLogger("repro.launch")

# Idempotency is tracked explicitly: ``jax.process_count() > 1`` only
# detects *multi*-process init, so a single-process distributed init
# (world of 1 — the shrunk-to-one elastic tail) used to re-initialize
# and crash on the second call.
_initialized = False


def _env_int(name: str) -> int | None:
    v = os.environ.get(name)
    return int(v) if v is not None else None


def initialize_distributed(coordinator: str | None = None,
                           num_processes: int | None = None,
                           process_id: int | None = None, *,
                           initialization_timeout: float | None = None
                           ) -> bool:
    """Idempotent multi-host init.  Returns True when this call (or an
    earlier one) actually initialized the distributed runtime.

    On GCP TPU VMs all arguments are discovered from the metadata server;
    elsewhere pass coordinator ("host:port"), num_processes, process_id
    (or set JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES / JAX_PROCESS_ID).

    Failure policy: with an explicit coordinator (argument or env var),
    any failure is a genuine misconfiguration — bad address, port in
    use, a peer missing — and **propagates**; silently degrading a
    configured multi-host run to single-host mode would train on 1/Nth
    of the data while looking healthy.  Only the known "nothing
    configured, auto-detection found nothing" case falls back to
    single-host mode (the dev-box path).
    """
    global _initialized
    if _initialized:
        return True
    # Probe for an out-of-band init through the distributed client, NOT
    # jax.process_count(): the latter initializes the backend, which
    # fails outright when gloo collectives are configured but the
    # distributed client does not exist yet (the exact state this
    # function is about to fix).
    from jax._src import distributed as _dist

    if getattr(_dist.global_state, "client", None) is not None:
        _initialized = True
        return True
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if num_processes is None:
        num_processes = _env_int("JAX_NUM_PROCESSES")
    if process_id is None:
        process_id = _env_int("JAX_PROCESS_ID")
    kwargs = {}
    if initialization_timeout is not None:
        kwargs["initialization_timeout"] = initialization_timeout
    if coordinator:
        if num_processes is None or process_id is None:
            raise ValueError(
                "coordinator address set but num_processes/process_id "
                "missing (pass them or set JAX_NUM_PROCESSES / "
                "JAX_PROCESS_ID)")
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id, **kwargs)
        _initialized = True
        log.info("distributed init: process %d/%d, %d devices (%d local)",
                 jax.process_index(), jax.process_count(),
                 len(jax.devices()), len(jax.local_devices()))
        return True
    # No explicit configuration: try cluster auto-detection (GCP TPU
    # metadata, SLURM, ...).  "coordinator_address should be defined" is
    # jax's way of saying no cluster environment was found — the one
    # case where single-host mode is the right answer.
    try:
        jax.distributed.initialize(**kwargs)
    except ValueError as e:
        if "coordinator_address" not in str(e):
            raise
        log.info("single-host mode (%s)", e)
        return False
    _initialized = True
    log.info("distributed init (auto-detected): process %d/%d, %d devices "
             "(%d local)", jax.process_index(), jax.process_count(),
             len(jax.devices()), len(jax.local_devices()))
    return True


def add_distributed_cli_args(ap) -> None:
    """Coordinator + liveness knobs shared by the train/serve launchers."""
    g = ap.add_argument_group("distributed / liveness")
    g.add_argument("--coordinator", default=None,
                   help="host:port of the jax.distributed coordinator "
                        "(or set JAX_COORDINATOR_ADDRESS); omit on GCP "
                        "TPU VMs (metadata auto-detect) and single-host")
    g.add_argument("--num-processes", type=int, default=None)
    g.add_argument("--process-id", type=int, default=None)
    g.add_argument("--heartbeat-dir", default=None,
                   help="shared directory for per-process heartbeat files; "
                        "enables the liveness watchdog — a dead peer "
                        "raises RankLost and the launcher exits with the "
                        "elastic-respawn protocol code instead of hanging")
    g.add_argument("--heartbeat-interval", type=float, default=0.25,
                   help="seconds between heartbeats")
    g.add_argument("--stall-after", type=float, default=2.0,
                   help="heartbeat staleness that marks a peer stalled/lost")
    g.add_argument("--step-deadline", type=float, default=None,
                   help="hard per-step deadline even with peers "
                        "heartbeating (deadlocked-collective backstop)")


def init_distributed_from_args(args) -> bool:
    """CLI/env-driven :func:`initialize_distributed` (no-op when nothing
    is configured — the single-host dev path)."""
    return initialize_distributed(args.coordinator, args.num_processes,
                                  args.process_id)


def build_liveness_from_args(args):
    """(HeartbeatWriter, LivenessMonitor) when ``--heartbeat-dir`` is
    set, else (None, None).  The writer is started; the monitor starts
    *disarmed* — arm it (``monitor.enabled = True``) after the first
    successful step so compile time is never misread as a stall."""
    if not getattr(args, "heartbeat_dir", None):
        return None, None
    from repro.runtime.watchdog import HeartbeatWriter, LivenessMonitor

    rank = jax.process_index()
    world = jax.process_count()
    writer = HeartbeatWriter(args.heartbeat_dir, rank,
                             interval_s=args.heartbeat_interval).start()
    monitor = LivenessMonitor(args.heartbeat_dir, rank, world,
                              stall_after_s=args.stall_after,
                              step_deadline_s=args.step_deadline)
    monitor.enabled = False
    return writer, monitor


def assert_production_topology(multi_pod: bool = False,
                               topology: str | None = None):
    """Fail fast when the visible chip count is not the target mesh's.

    The expected count comes from the topology registry
    (:data:`repro.launch.mesh.PRODUCTION_TOPOLOGIES`) — pass
    ``topology`` to check a non-default entry (dry-running a new slice
    shape needs a registry entry, not a code edit here)."""
    from repro.launch.mesh import production_mesh_shape

    shape = production_mesh_shape(multi_pod=multi_pod, topology=topology)
    want = 1
    for dim in shape:
        want *= dim
    have = len(jax.devices())
    if have != want:
        name = topology or ("multi-pod" if multi_pod else "single-pod")
        raise RuntimeError(
            f"expected {want} chips for the {name} mesh {shape}, found "
            f"{have}; adjust --mesh, the slice size, or register the "
            f"topology in repro.launch.mesh.PRODUCTION_TOPOLOGIES")
