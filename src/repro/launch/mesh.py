"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2x16x16 = 512 chips (pod, data, model) — the ``pod`` axis
composes with ``data`` into the DP/FSDP dimension everywhere, so the
same model code runs on both meshes and the multi-pod dry-run proves the
pod axis shards (its collectives cross the DCN boundary in the HLO).
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import FusionConfig, ParallelContext
from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_context(*, multi_pod: bool = False,
                 fusion: FusionConfig | None = None) -> ParallelContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    return ParallelContext.from_mesh(mesh, fusion=fusion)


def make_host_mesh(shape=None, axes=("data", "model"),
                   fusion: FusionConfig | None = None) -> ParallelContext:
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        model = min(4, n)
        shape = (n // model, model)
    mesh = make_mesh(shape, axes)
    return ParallelContext.from_mesh(mesh, fusion=fusion)
