"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model);
multi-pod: 2x16x16 = 512 chips (pod, data, model) — the ``pod`` axis
composes with ``data`` into the DP/FSDP dimension everywhere, so the
same model code runs on both meshes and the multi-pod dry-run proves the
pod axis shards (its collectives cross the DCN boundary in the HLO).
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import FusionConfig, ParallelContext
from repro.compat import make_mesh


# Topology registry: name -> (shape, axis names).  ``assert_production_
# topology`` and the dry-run launchers size themselves from here, so a
# new slice shape is one registry entry instead of scattered constants.
PRODUCTION_TOPOLOGIES = {
    "v5e-256": ((16, 16), ("data", "model")),
    "v5e-2pod-512": ((2, 16, 16), ("pod", "data", "model")),
}
DEFAULT_TOPOLOGY = "v5e-256"
DEFAULT_MULTI_POD_TOPOLOGY = "v5e-2pod-512"


def production_topology(*, multi_pod: bool = False,
                        topology: str | None = None):
    """(shape, axes) for a registered production topology."""
    if topology is None:
        topology = DEFAULT_MULTI_POD_TOPOLOGY if multi_pod else DEFAULT_TOPOLOGY
    try:
        return PRODUCTION_TOPOLOGIES[topology]
    except KeyError:
        raise KeyError(f"unknown topology {topology!r}; registered: "
                       f"{sorted(PRODUCTION_TOPOLOGIES)}") from None


def production_mesh_shape(*, multi_pod: bool = False,
                          topology: str | None = None):
    return production_topology(multi_pod=multi_pod, topology=topology)[0]


def make_production_mesh(*, multi_pod: bool = False,
                         topology: str | None = None):
    shape, axes = production_topology(multi_pod=multi_pod, topology=topology)
    return make_mesh(shape, axes)


def make_context(*, multi_pod: bool = False,
                 fusion: FusionConfig | None = None) -> ParallelContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    return ParallelContext.from_mesh(mesh, fusion=fusion)


def make_host_mesh(shape=None, axes=("data", "model"),
                   fusion: FusionConfig | None = None) -> ParallelContext:
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if shape is None:
        model = min(4, n)
        shape = (n // model, model)
    mesh = make_mesh(shape, axes)
    return ParallelContext.from_mesh(mesh, fusion=fusion)
