"""Roofline term derivation from compiled dry-run artifacts.

Terms (per device, seconds):
  compute    = HLO_FLOPs / peak_FLOPs            (cost_analysis is per-device)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

collective_bytes is not in cost_analysis; it is parsed from the optimized
HLO by summing *operand* sizes of all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute ops (async -start forms included).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?)([a-z0-9]+\[[0-9,]*\])"          # result (or first tuple elt)
    r".{0,120}?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
)
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _nbytes(type_str: str) -> int:
    m = _TYPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (operand-size convention)."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        is_tuple, rtype, kind, start = m.group(1) == "(", m.group(2), m.group(3), m.group(4)
        if start == "-done":
            continue  # counted at -start
        size = _nbytes(rtype)
        g = _group_size(line)
        if kind == "all-gather" and not (start == "-start" and is_tuple):
            # sync form: result is the gathered tensor; operand = result/g
            size = size // max(g, 1)
        if kind == "reduce-scatter":
            # result is the scattered tensor; operand = result*g
            if not (start == "-start" and is_tuple):
                size = size * g
        out[kind] = out.get(kind, 0) + float(size)
        count[kind] = count.get(kind, 0) + 1
    out["_counts"] = count
    return out


# ---------------------------------------------------------------------------
# Recursive HLO cost recount.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE, so scan-over-layers
# programs under-report flops/bytes/collective-bytes by ~n_layers.  We
# re-derive them from the optimized HLO text: per computation we sum dot
# flops (2 * prod(result) * prod(contracted)), materialized bytes
# (result sizes of top-level instructions, x2 for write+read), and
# collective operand bytes; the call graph is walked with while bodies
# multiplied by their known_trip_count.
# ---------------------------------------------------------------------------
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_HEAD_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_SCALAR_TYPE_RE = re.compile(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_OP_AFTER_TYPE_RE = re.compile(r"^\s*([a-z][a-z0-9\-]*)\(")
_CALLEE_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")


def _parse_instr(line: str):
    """-> (name, result_type_str, op, rest_after_op) or None."""
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rtype, tail = rest[: i + 1], rest[i + 1:]
                    break
        else:
            return None
    else:
        mt = _SCALAR_TYPE_RE.match(rest)
        if not mt:
            return None
        rtype, tail = mt.group(1), rest[mt.end():]
    mo = _OP_AFTER_TYPE_RE.match(tail)
    if not mo:
        return None
    return name, rtype, mo.group(1), tail[mo.end() - 1:]
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"\(([^)]*)\)")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_dims(type_str: str):
    m = _TYPE_RE.search(type_str)
    if not m:
        return None, []
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dt, dims


def _first_tuple_elt(type_str: str) -> str:
    if type_str.startswith("("):
        inner = type_str[1:]
        m = _TYPE_RE.search(inner)
        return m.group(0) if m else "f32[]"
    return type_str


def hlo_analysis(txt: str) -> dict:
    """Exact-ish per-device flops / bytes / collective bytes with loop
    trip-count multipliers."""
    # --- split into computations -----------------------------------------
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in txt.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)

    # --- per-computation local costs + call edges --------------------------
    local = {}
    for name, lines in comps.items():
        shapes: dict[str, str] = {}
        flops = 0.0
        bytes2 = 0.0
        bytes_f32 = 0.0
        big: dict[str, float] = {}
        colls: dict[str, float] = {}
        coll_counts: dict[str, int] = {}
        edges: list[tuple[str, float, bool]] = []   # (callee, mult, count_bytes)
        for line in lines:
            parsed = _parse_instr(line)
            if parsed is None:
                continue
            iname, rtype, op, tail = parsed
            shapes[iname] = rtype
            if op in ("call", "conditional"):
                for callee in _CALLEE_RE.findall(line):
                    edges.append((callee, 1.0, True))
            elif op in ("fusion", "map", "reduce", "scatter", "sort",
                        "reduce-window", "select-and-scatter"):
                # bodies are in-register: flops count, bytes don't
                for callee in _CALLEE_RE.findall(line):
                    edges.append((callee, 1.0, False))
            elif op == "while":
                trip = 1.0
                mt = _TRIP_RE.search(line)
                if mt:
                    trip = float(mt.group(1))
                for callee in _CALLEE_RE.findall(line):
                    edges.append((callee, trip, True))
            # bytes: materialized top-level results (~1 write + 1 read);
            # dynamic-update-slice aliases in place — count the update
            # operand, not the full buffer
            if op not in ("parameter", "tuple", "get-tuple-element",
                          "constant", "bitcast", "while", "call"):
                bt = _first_tuple_elt(rtype)
                if op == "dynamic-update-slice":
                    mo = _OPERAND_RE.search(tail)
                    names = re.findall(r"%([\w\.\-]+)", mo.group(1)) if mo else []
                    if len(names) >= 2 and names[1] in shapes:
                        bt = _first_tuple_elt(shapes[names[1]])
                nb = 2.0 * _nbytes_layout(bt)
                bytes2 += nb
                if bt.startswith("f32"):
                    bytes_f32 += nb
                if nb >= 2e6:  # track large contributors for attribution
                    key = f"{op} {bt.split('{')[0]}"
                    big[key] = big.get(key, 0.0) + nb
            if op == "dot":
                _, rdims = _shape_dims(_first_tuple_elt(rtype))
                mo = _OPERAND_RE.search(tail)
                k = 1
                mc = _CONTRACT_RE.search(line)
                if mo and mc:
                    names = re.findall(r"%([\w\.\-]+)", mo.group(1))
                    cdims = [int(d) for d in mc.group(1).split(",") if d]
                    if names and names[0] in shapes:
                        _, ldims = _shape_dims(_first_tuple_elt(shapes[names[0]]))
                        for d in cdims:
                            if d < len(ldims):
                                k *= ldims[d]
                prod_r = 1
                for d in rdims:
                    prod_r *= d
                flops += 2.0 * prod_r * max(k, 1)
            for kind in _COLL_KINDS:
                if op == kind or op == kind + "-start":
                    bt = _first_tuple_elt(rtype)
                    size = float(_nbytes_layout(bt))
                    g = _group_size(line)
                    if kind == "all-gather" and not op.endswith("-start"):
                        size = size / max(g, 1)
                    if kind == "reduce-scatter" and not op.endswith("-start"):
                        size = size * g
                    colls[kind] = colls.get(kind, 0.0) + size
                    coll_counts[kind] = coll_counts.get(kind, 0) + 1
                    if bt.startswith("f32"):
                        colls["_f32"] = colls.get("_f32", 0.0) + size
        local[name] = {"flops": flops, "bytes": bytes2,
                       "bytes_f32": bytes_f32, "colls": colls,
                       "counts": coll_counts, "edges": edges, "big": big}

    # --- DFS with multipliers ----------------------------------------------
    memo: dict[str, dict] = {}

    def total(name: str) -> dict:
        if name in memo:
            return memo[name]
        lc = local.get(name)
        if lc is None:
            return {"flops": 0.0, "bytes": 0.0, "bytes_f32": 0.0,
                    "colls": {}, "counts": {}, "big": {}}
        memo[name] = {"flops": 0.0, "bytes": 0.0, "bytes_f32": 0.0,
                      "colls": {}, "counts": {}, "big": {}}
        acc = {"flops": lc["flops"], "bytes": lc["bytes"],
               "bytes_f32": lc["bytes_f32"],
               "colls": dict(lc["colls"]), "counts": dict(lc["counts"]),
               "big": dict(lc["big"])}
        for callee, mult, count_bytes in lc["edges"]:
            sub = total(callee)
            acc["flops"] += mult * sub["flops"]
            if count_bytes:
                acc["bytes"] += mult * sub["bytes"]
                acc["bytes_f32"] += mult * sub["bytes_f32"]
                for k, v in sub["big"].items():
                    acc["big"][k] = acc["big"].get(k, 0.0) + mult * v
            for k, v in sub["colls"].items():
                acc["colls"][k] = acc["colls"].get(k, 0.0) + mult * v
            for k, v in sub["counts"].items():
                acc["counts"][k] = acc["counts"].get(k, 0) + mult * v
        memo[name] = acc
        return acc

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "colls": {}, "counts": {},
                "big": {}}
    res = total(entry)
    f32_coll = res["colls"].pop("_f32", 0.0)
    res["coll_total"] = sum(res["colls"].values())
    res["coll_f32_bytes"] = f32_coll
    # XLA CPU legalizes bf16 compute to f32, upcasting collective payloads
    # that are bf16 in the source and would ride bf16 on TPU; the adjusted
    # figure halves the f32 share (upper/lower bracket pair).
    res["coll_total_tpu_adjusted"] = res["coll_total"] - f32_coll * 0.5
    res["bytes_tpu_adjusted"] = res["bytes"] - res["bytes_f32"] * 0.5
    res["top_buffers"] = sorted(res.pop("big").items(),
                                key=lambda kv: -kv[1])[:15]
    return res


def _nbytes_layout(type_str: str) -> int:
    """bytes of 'bf16[2,3]{1,0}' style type strings."""
    return _nbytes(type_str)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float) -> dict:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = collective_bytes / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dom[0],
            "bound_s": dom[1]}


def model_flops(bundle, shape_name: str, param_count: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N per token
    (decode); N = active params for MoE."""
    from repro.configs.registry import SHAPES, DLRM_SHAPES

    cfg = bundle.config
    n_active = param_count
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        layers_moe = cfg.n_layers - getattr(cfg, "dense_prefix", 0)
        expert_total = layers_moe * moe.n_experts * 3 * moe.d_model * moe.d_ff
        expert_active = layers_moe * (moe.top_k + moe.n_shared_experts) * \
            3 * moe.d_model * moe.d_ff
        n_active = param_count - expert_total + expert_active
    if bundle.family == "dlrm":
        sh = DLRM_SHAPES[shape_name]
        dense = sum(a * b for a, b in zip(
            (cfg.n_dense,) + cfg.bottom_mlp[:-1], cfg.bottom_mlp))
        n_vec = cfg.n_tables + 1
        d_int = n_vec * (n_vec - 1) // 2 + cfg.embed_dim
        dense += sum(a * b for a, b in zip((d_int,) + cfg.top_mlp[:-1], cfg.top_mlp))
        return 6 * dense * sh["batch"]
    sh = SHAPES[shape_name]
    tokens = sh["batch"] * sh["seq"]
    if sh["kind"] == "train":
        return 6.0 * n_active * tokens
    if sh["kind"] == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh["batch"]  # decode: one token per sequence
