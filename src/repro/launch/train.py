"""Training launcher: end-to-end driver with fault tolerance.

Runs any registry architecture (reduced or full config) on the available
devices with the fused operators, synthetic data, async checkpointing and
restart-on-failure supervision.

  PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b \
      --reduced --steps 200 --batch 16 --seq 64
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os
import time

import jax
import numpy as np

from repro.runtime.chaos import CollectiveTimeout, RankLost

from repro.configs.registry import get_arch
from repro.core.autotune import (add_granularity_cli_args,
                                 load_cache_if_exists, save_cache)
from repro.core.calibrate import (add_calibration_cli_args,
                                  warmup_and_calibrate)
from repro.core.degrade import DegradationPolicy, set_degradation_policy
from repro.data.synthetic import DLRMBatches, LMBatches
from repro.launch.distributed import (add_distributed_cli_args,
                                      build_liveness_from_args,
                                      init_distributed_from_args)
from repro.launch.mesh import make_context, make_host_mesh
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig
from repro.runtime.chaos import add_chaos_cli_args, build_fault_plan
from repro.runtime.elastic import reshard_tree, shrink_context
from repro.runtime.fault_tolerance import SupervisorConfig, TrainSupervisor
from repro.runtime.straggler import SkewEstimator, SkewScheduler
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state, train_state_specs


def _shardings(ctx, logical_tree):
    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return jax.tree.map(lambda s: ctx.sharding(*s), logical_tree, is_leaf=is_spec)


def make_batches(bundle, batch: int, seq: int, seed: int = 0):
    cfg = bundle.config
    if bundle.family == "dlrm":
        return DLRMBatches(cfg.n_tables, cfg.table_vocab, cfg.pooling,
                           cfg.n_dense, batch, seed)
    base = LMBatches(cfg.vocab, batch, seq, seed)
    fe = getattr(cfg, "frontend", None)
    if fe is None:
        return base

    def gen():
        rng = np.random.default_rng(seed + 7)
        for b in base:
            if fe == "audio":
                b["frame_embeds"] = rng.standard_normal(
                    (batch, seq, cfg.d_model)).astype(np.float32) * 0.02
            if fe == "vision":
                b["vision_embeds"] = rng.standard_normal(
                    (batch, seq, cfg.d_model)).astype(np.float32) * 0.02
                b["vision_mask"] = np.arange(seq) < min(8, seq)
                b["positions_thw"] = np.tile(
                    np.arange(seq, dtype=np.int32)[None, None], (3, batch, 1))
            yield b

    return gen()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fusion", default="fused",
                    choices=["fused", "bulk", "kernel", "auto"])
    ap.add_argument("--auto-fuse", action="store_true",
                    help="trace the model with bulk collectives and let the "
                         "jaxpr comm-graph analyzer rewrite profitable "
                         "matches to the fused ops (same as --fusion auto)")
    ap.add_argument("--explain-comm", action="store_true",
                    help="report-only: print every collective in the step, "
                         "its fused-op family, the modeled bulk->fused "
                         "savings and the reason when not fusible, then "
                         "exit without training")
    add_granularity_cli_args(ap)
    add_calibration_cli_args(ap)
    ap.add_argument("--skew-schedule", action="store_true",
                    help="close the Fig. 14 loop: feed per-step telemetry "
                         "to the cross-rank skew estimator and re-jit the "
                         "fused-op schedules when the straggler bucket "
                         "changes (single-process runs see uniform times, "
                         "so the bucket stays 0 unless a cluster telemetry "
                         "provider is plugged in)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    add_distributed_cli_args(ap)
    add_chaos_cli_args(ap)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    if args.auto_fuse:
        args.fusion = "auto"

    init_distributed_from_args(args)
    hb_writer, liveness = build_liveness_from_args(args)

    load_cache_if_exists(args.tune_cache)
    fusion = FusionConfig(mode=args.fusion, granularity=args.granularity,
                          wire=args.wire)
    ctx = (make_context(fusion=fusion) if args.production_mesh
           else make_host_mesh(fusion=fusion))
    bundle = get_arch(args.arch)
    if args.reduced:
        bundle = bundle.reduced()

    params_p = bundle.init_params(jax.random.PRNGKey(0))
    params, param_specs = split_params(params_p)
    tc = TrainConfig(
        optimizer=OptimizerConfig(name=bundle.optimizer, lr=args.lr,
                                  warmup_steps=max(args.steps // 20, 5),
                                  total_steps=args.steps))
    state = init_train_state(tc, params)
    state_sh = _shardings(ctx, train_state_specs(tc, param_specs))
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, state_sh)

    if args.explain_comm:
        from repro.analysis import explain_comm
        import jax.numpy as jnp
        # the report always analyzes the bulk-traced graph ("auto"): that
        # is the form the rewrite pass sees, whatever --fusion says
        ectx = ctx.with_fusion(dataclasses.replace(fusion, mode="auto"))
        batch0 = jax.tree.map(
            jnp.asarray, next(iter(make_batches(bundle, args.batch, args.seq))))
        print(explain_comm(ectx, bundle.loss_fn(ectx), params, batch0))
        return []

    def build_step(skew: int = 0):
        c = ctx.with_fusion(dataclasses.replace(fusion, skew=skew))
        loss = bundle.loss_fn(c)
        if fusion.mode == "auto":
            from repro.analysis import auto_fuse
            loss = auto_fuse(c, loss)
        return jax.jit(build_train_step(loss, tc),
                       donate_argnums=(0,))

    step_fn = build_step()
    batches = make_batches(bundle, args.batch, args.seq)

    if args.calibrate:
        batch0 = next(iter(make_batches(bundle, args.batch, args.seq)))
        warmup_and_calibrate(ctx, step_fn, state, batch0,
                             iters=args.calibrate_iters,
                             granularity=args.granularity)
        step_fn = build_step()  # measured decisions are read at trace time

    skew_sched = None
    if args.skew_schedule:
        skew_sched = SkewScheduler(build_step,
                                   SkewEstimator(dict(ctx.mesh.shape)),
                                   axis=ctx.tp_axis)

    fault_plan = build_fault_plan(args.chaos, num_steps=args.steps)
    degradation = None
    if args.degrade:
        degradation = DegradationPolicy()
        set_degradation_policy(degradation)

    def on_rank_loss(st, exc):
        # Elastic shrink: halve the dp axis, keep going on the survivors.
        nonlocal ctx, state_sh
        ctx = shrink_context(ctx)
        st, state_sh = reshard_tree(st, train_state_specs(tc, param_specs),
                                    ctx)
        sup.state_shardings = state_sh
        return st, build_step()

    sup = TrainSupervisor(
        SupervisorConfig(checkpoint_dir=args.ckpt_dir,
                         checkpoint_every=args.ckpt_every),
        step_fn, state_shardings=state_sh, skew_scheduler=skew_sched,
        # multi-host: all-gather the local monitor's EWMA per process so
        # the estimator sees measured cross-rank times (single-process
        # runs degrade to the replicated local time — rotation stays 0)
        per_rank_times="process" if skew_sched is not None else None,
        fault_plan=fault_plan, degradation=degradation,
        rebuild_step=build_step, liveness=liveness,
        # With real liveness the in-process shrink cannot survive a dead
        # gloo world: RankLost must propagate so this process can exit
        # with the elastic-respawn protocol code for its driver.
        on_rank_loss=None if liveness is not None else on_rank_loss)

    t0 = time.time()
    losses = []

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if liveness is not None:
            hb_writer.beat(step=step)
            liveness.enabled = True   # armed once the first step lands
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / max(step, 1):.2f}s/step)",
                  flush=True)

    try:
        state, step = sup.run(state, batches, args.steps,
                              on_metrics=on_metrics)
        if hb_writer is not None:
            hb_writer.stop()
    except (RankLost, CollectiveTimeout) as e:
        if liveness is None:
            raise
        # Elastic respawn protocol: a real peer death/stall was detected
        # by the heartbeat watchdog.  Leave with the protocol exit code
        # so the driver relaunches the survivors (shrunk or same-size
        # world); training resumes from --ckpt-dir.
        from repro.runtime.multiprocess import EXIT_RESHARD, EXIT_RESTART

        code = EXIT_RESHARD if isinstance(e, RankLost) else EXIT_RESTART
        print(f"liveness failure: {e}; exiting with respawn code {code}",
              flush=True)
        hb_writer.stop()
        os._exit(code)
    span = (f"loss {losses[0]:.4f} -> {losses[-1]:.4f}" if losses
            else "no steps run (resumed at or past num_steps)")
    print(f"done at step {step}; {span}; "
          f"straggler stats {sup.straggler.summary()}")
    if fault_plan is not None:
        print(f"chaos: plan {fault_plan.summary()}; injected "
              f"{sup.faults_injected}, restarts {sup.restarts}, "
              f"rank losses {sup.rank_losses}, backoffs "
              f"{[round(b, 3) for b in sup.backoffs]}")
    if degradation is not None:
        print(f"degradation: {degradation.summary()}")
    if args.tune_cache:
        save_cache(args.tune_cache)
    return losses


if __name__ == "__main__":
    main()
