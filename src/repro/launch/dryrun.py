import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell and record memory/cost/collective analysis.

This proves the distribution config is coherent without real hardware:
a sharding mismatch, compile-time OOM, or unsupported collective fails
the cell.  Results are written incrementally to JSON so interrupted runs
resume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch chatglm3-6b \
      --shape train_4k --mesh single --fusion fused
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, get_arch
from repro.launch.mesh import make_context
from repro.launch.roofline import (hlo_analysis, model_flops,
                                   parse_collective_bytes, roofline_terms)
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig, ParallelContext
from repro.train.optimizer import OptimizerConfig
from repro.train.step import TrainConfig, build_train_step, init_train_state, train_state_specs


def _shardings(ctx: ParallelContext, logical_tree):
    is_spec = lambda x: isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)
    return jax.tree.map(lambda s: ctx.sharding(*s), logical_tree, is_leaf=is_spec)


def build_cell(bundle, shape_name: str, ctx: ParallelContext):
    """Returns (jitted fn, arg structs) for one cell."""
    shapes = bundle.shapes()
    sh = shapes[shape_name]
    kind = sh["kind"]
    if kind in ("decode",):
        bundle = bundle.with_max_seq(sh["seq"])

    params_struct_p = jax.eval_shape(
        lambda: bundle.init_params(jax.random.PRNGKey(0)))
    params_struct, param_specs = split_params(params_struct_p)
    param_sh = _shardings(ctx, param_specs)
    batch_struct, batch_specs = bundle.batch_struct(shape_name, ctx)
    batch_sh = _shardings(ctx, batch_specs)

    if kind in ("train", "dlrm_train"):
        tc = TrainConfig(optimizer=OptimizerConfig(name=bundle.optimizer),
                         microbatches=bundle.microbatches)
        state_struct = jax.eval_shape(
            lambda p: init_train_state(tc, p), params_struct)
        state_sh = _shardings(ctx, train_state_specs(tc, param_specs))
        step = build_train_step(bundle.loss_fn(ctx), tc)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn, (state_struct, batch_struct)

    if kind == "prefill":
        fn = jax.jit(bundle.prefill_fn(ctx),
                     in_shardings=(param_sh, batch_sh))
        return fn, (params_struct, batch_struct)

    if kind == "decode":
        B = sh["batch"]
        param_sh = _shardings(ctx, bundle.decode_param_specs(
            param_specs, params_struct))
        cache_struct = jax.eval_shape(lambda: bundle.init_cache(B))
        cache_specs = bundle.cache_specs(cache_struct)
        if B % ctx.dp != 0:  # e.g. long_500k batch=1: replicate batch dim
            is_spec = lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x)
            cache_specs = jax.tree.map(
                lambda s: tuple(None if e == "batch" else e for e in s),
                cache_specs, is_leaf=is_spec)
        cache_sh = _shardings(ctx, cache_specs)
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(bundle.decode_fn(ctx),
                     in_shardings=(param_sh, batch_sh["tokens"], cache_sh,
                                   None),
                     donate_argnums=(2,))
        return fn, (params_struct, batch_struct["tokens"], cache_struct,
                    pos_struct)

    raise ValueError(kind)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fusion_mode: str, outdir: str, schedule: str = "comm_aware"):
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}__{fusion_mode}"
    if schedule != "comm_aware":
        tag += f"__{schedule}"
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    os.makedirs(outdir, exist_ok=True)

    fusion = FusionConfig(mode=fusion_mode, schedule=schedule)
    ctx = make_context(multi_pod=multi_pod, fusion=fusion)
    bundle = get_arch(arch)
    if shape_name not in bundle.shapes():
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "fusion": fusion_mode, "status": "skipped",
               "reason": "quadratic attention at 500k (see DESIGN.md)"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "fusion": fusion_mode, "schedule": schedule}
    try:
        t0 = time.time()
        fn, args = build_cell(bundle, shape_name, ctx)
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes +
                                         mem.output_size_in_bytes +
                                         mem.temp_size_in_bytes -
                                         mem.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_raw"] = {"flops_per_device": float(ca.get("flops", 0.0)),
                           "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
                           "note": "HloCostAnalysis counts loop bodies once"}
        hlo = compiled.as_text()
        # exact recount: loop bodies multiplied by known_trip_count
        hc = hlo_analysis(hlo)
        flops = hc["flops"]
        bytes_acc = hc["bytes"]
        coll_total = hc["coll_total"]
        rec["cost"] = {"flops_per_device": flops,
                       "bytes_per_device": bytes_acc}
        rec["collectives"] = {"bytes_by_kind": hc["colls"],
                              "counts": hc["counts"],
                              "total_bytes_per_device": coll_total,
                              "f32_bytes": hc.get("coll_f32_bytes", 0.0),
                              "tpu_adjusted_bytes": hc.get(
                                  "coll_total_tpu_adjusted", coll_total)}
        rec["top_buffers"] = [[k, v] for k, v in hc.get("top_buffers", [])]
        rec["roofline"] = roofline_terms(flops, bytes_acc, coll_total)
        rec["roofline_tpu_adjusted"] = roofline_terms(
            flops, hc.get("bytes_tpu_adjusted", bytes_acc),
            hc.get("coll_total_tpu_adjusted", coll_total))
        import math
        n_params = sum(math.prod(l.shape)
                       for l in jax.tree.leaves(
                           jax.eval_shape(lambda: bundle.init_params(
                               jax.random.PRNGKey(0)))))
        mf = model_flops(bundle, shape_name, n_params)
        n_dev = 512 if multi_pod else 256
        rec["model_flops"] = {"total": mf, "n_params": int(n_params),
                              "hlo_total": flops * n_dev,
                              "useful_ratio": mf / max(flops * n_dev, 1.0)}
        rec["status"] = "ok"
    except Exception as e:  # record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--fusion", default="fused", choices=["fused", "bulk", "kernel"])
    ap.add_argument("--schedule", default="comm_aware",
                    choices=["comm_aware", "oblivious"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        bundle = get_arch(arch)
        shape_names = (list(bundle.shapes()) if args.shape == "all"
                       else [args.shape])
        for shape in shape_names:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               fusion_mode=args.fusion, outdir=args.out,
                               schedule=args.schedule)
                status = rec.get("status")
                r = rec.get("roofline", {})
                print(f"[{rec.get('arch')}|{rec.get('shape')}|{rec.get('mesh')}|"
                      f"{rec.get('fusion')}] {status} "
                      f"compile={rec.get('compile_s', '-')}s "
                      f"dom={r.get('dominant', '-')} "
                      f"bound={r.get('bound_s', 0):.2e}s "
                      f"mem={rec.get('memory', {}).get('peak_bytes_per_device', 0)/2**30:.2f}GiB",
                      flush=True)
                if status == "error":
                    print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
