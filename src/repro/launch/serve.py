"""Serving launcher: batched decode with the fused GEMV+AllReduce FFN.

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.autotune import (add_granularity_cli_args,
                                 load_cache_if_exists, save_cache)
from repro.core.calibrate import (add_calibration_cli_args,
                                  warmup_and_calibrate)
from repro.core.degrade import DegradationPolicy, set_degradation_policy
from repro.launch.distributed import (add_distributed_cli_args,
                                      build_liveness_from_args,
                                      init_distributed_from_args)
from repro.launch.mesh import make_context, make_host_mesh
from repro.models.common import split_params
from repro.parallel.sharding import FusionConfig
from repro.runtime.chaos import (CollectiveTimeout, RankLost,
                                 add_chaos_cli_args, build_fault_plan)
from repro.runtime.elastic import reshard_tree, shrink_context
from repro.serve.engine import (DecodeEngine, PagedDecodeEngine, Request,
                                request_journal, resubmit_journal,
                                serve_with_chaos)
from repro.serve.kv_cache import dense_cache_hbm_bytes, pool_hbm_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--fusion", default="fused",
                    choices=["fused", "bulk", "kernel", "auto"])
    ap.add_argument("--auto-fuse", action="store_true",
                    help="trace decode with bulk collectives and let the "
                         "jaxpr comm-graph analyzer rewrite profitable "
                         "matches to the fused ops (same as --fusion auto)")
    ap.add_argument("--explain-comm", action="store_true",
                    help="report-only: print every collective in one decode "
                         "step with its family, modeled savings and "
                         "not-fusible reasons, then exit without serving")
    ap.add_argument("--paged", action="store_true",
                    help="paged/block KV cache + chunked prefill "
                         "(continuous batching over a shared block pool)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged mode)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool blocks; 0 = half the dense B x S_max budget")
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk width C (paged mode)")
    add_granularity_cli_args(ap)
    add_calibration_cli_args(ap)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--journal", default=None,
                    help="request-journal path: unfinished requests are "
                         "persisted here on a liveness failure and "
                         "resubmitted (tokens intact) on the next launch "
                         "— the cross-process drain-reshard-resume story")
    add_distributed_cli_args(ap)
    add_chaos_cli_args(ap)
    args = ap.parse_args()
    if args.auto_fuse:
        args.fusion = "auto"

    init_distributed_from_args(args)
    hb_writer, liveness = build_liveness_from_args(args)

    load_cache_if_exists(args.tune_cache)
    fusion = FusionConfig(mode=args.fusion, granularity=args.granularity,
                          wire=args.wire)
    ctx = (make_context(fusion=fusion) if args.production_mesh
           else make_host_mesh(fusion=fusion))
    bundle = get_arch(args.arch)
    if args.reduced:
        bundle = bundle.reduced()
    cfg = bundle.config

    params_p = bundle.init_params(jax.random.PRNGKey(0))
    params, param_specs = split_params(params_p)
    decode = bundle.decode_fn(ctx)

    if args.explain_comm:
        import dataclasses

        from repro.analysis import explain_comm
        # analyze the bulk-traced decode graph, whatever --fusion says
        ectx = ctx.with_fusion(dataclasses.replace(fusion, mode="auto"))
        tok0 = np.zeros((args.batch, 1), np.int32)
        print(explain_comm(ectx, bundle.decode_fn(ectx), params, tok0,
                           bundle.init_cache(args.batch), 0))
        return []

    if args.fusion == "auto":
        from repro.analysis import auto_fuse
        decode = auto_fuse(ctx, decode)
    decode_jit = jax.jit(lambda t, c, pos: decode(params, t, c, pos))

    if args.calibrate:
        warm_cache = bundle.init_cache(args.batch)
        warm_tok = np.zeros((args.batch, 1), np.int32)
        warmup_and_calibrate(ctx, decode_jit, warm_tok, warm_cache, 0,
                             iters=args.calibrate_iters,
                             granularity=args.granularity)
        # measured decisions are read at trace time: re-jit for steady state
        decode_jit = jax.jit(lambda t, c, pos: decode(params, t, c, pos))

    if args.degrade:
        set_degradation_policy(DegradationPolicy())

    if args.paged:
        if not bundle.supports_paged:
            raise SystemExit(f"--paged requires a GQA transformer "
                             f"({args.arch} is {bundle.family}/"
                             f"{getattr(cfg, 'attn_type', '?')})")
        num_blocks = args.num_blocks
        if not num_blocks:
            # half the dense budget, rounded to a tp-divisible block count
            num_blocks = max(ctx.tp, (args.batch * cfg.max_seq // 2)
                             // args.block_size // ctx.tp * ctx.tp)
        serve_fn = bundle.serve_step_fn(ctx)
        serve_jit = jax.jit(
            lambda t, pl, tb, pos, nn: serve_fn(params, t, pl, tb, pos, nn))
        engine = PagedDecodeEngine(
            serve_jit, bundle.init_paged_pool, args.batch,
            num_blocks=num_blocks, block_size=args.block_size,
            max_seq=cfg.max_seq, chunk=args.chunk, n_stripes=ctx.tp)
        paged_b = pool_hbm_bytes(engine.pool)
        dense_b = dense_cache_hbm_bytes(bundle.init_cache(args.batch))
        print(f"paged pool: {num_blocks} x {args.block_size}-token blocks "
              f"= {paged_b / 2**20:.1f} MiB vs dense B x S_max "
              f"{dense_b / 2**20:.1f} MiB")
    else:
        engine = DecodeEngine(decode_jit, bundle.init_cache, args.batch,
                              max_seq=cfg.max_seq)
    if args.journal and os.path.exists(args.journal):
        with open(args.journal) as f:
            n = resubmit_journal(engine, json.load(f))
        print(f"journal: resubmitted {n} unfinished requests "
              f"(tokens intact) from {args.journal}")
    else:
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab,
                                  size=rng.integers(2, 6)).tolist()
            engine.submit(Request(uid=i, prompt=prompt,
                                  max_new=args.max_new))

    max_steps = args.requests * (getattr(cfg, "max_seq", 512) - 1)
    plan = build_fault_plan(args.chaos, num_steps=max_steps)

    def reshard_fn(eng):
        # drain-reshard-resume: shrink the mesh, re-jit for the surviving
        # devices, replay in-flight requests through the new cache/pool
        # (they keep their generated tokens; the paged engine rebuilds
        # their block tables through the chunked-prefill path)
        nonlocal ctx, params
        ctx = shrink_context(ctx)
        params, _ = reshard_tree(params, param_specs, ctx)
        if args.paged:
            sfn = bundle.serve_step_fn(ctx)
            new_jit = jax.jit(
                lambda t, pl, tb, pos, nn: sfn(params, t, pl, tb, pos, nn))
            n = eng.reshard(new_jit, bundle.init_paged_pool, args.batch,
                            n_stripes=ctx.tp)
        else:
            dec = bundle.decode_fn(ctx)
            if args.fusion == "auto":
                from repro.analysis import auto_fuse
                dec = auto_fuse(ctx, dec)
            new_jit = jax.jit(lambda t, c, pos: dec(params, t, c, pos))
            n = eng.reshard(new_jit, bundle.init_cache, args.batch)
        print(f"rank lost: mesh -> {dict(ctx.mesh.shape)}, "
              f"{n} in-flight requests re-queued")

    t0 = time.time()
    if liveness is not None:
        liveness.enabled = True   # serving has no compile-length steps
    try:
        if plan is not None:
            finished, stats = serve_with_chaos(engine, plan,
                                               reshard_fn=reshard_fn,
                                               max_steps=max_steps)
            print(f"chaos: plan {plan.summary()}; ticks {stats['ticks']}, "
                  f"dropped {stats['dropped']}, reshards "
                  f"{stats['reshards']}, drained {stats['drained']}")
        else:
            finished = engine.run_until_drained(max_steps=max_steps,
                                                liveness=liveness)
            if not finished.drained:
                print(f"WARNING: stopped at max_steps={max_steps} before "
                      f"draining — results truncated")
        if hb_writer is not None:
            hb_writer.stop()
    except (RankLost, CollectiveTimeout) as e:
        if liveness is None:
            raise
        # Real liveness failure mid-drain: journal the unfinished
        # requests (tokens intact) and leave with the respawn protocol
        # code — the relaunched engine resubmits them and every request
        # still drains to completion.
        from repro.runtime.multiprocess import EXIT_RESHARD, EXIT_RESTART

        if args.journal:
            with open(args.journal, "w") as f:
                json.dump(request_journal(engine), f)
            print(f"journal: persisted {len(request_journal(engine))} "
                  f"unfinished requests to {args.journal}")
        code = EXIT_RESHARD if isinstance(e, RankLost) else EXIT_RESTART
        print(f"liveness failure: {e}; exiting with respawn code {code}",
              flush=True)
        hb_writer.stop()
        os._exit(code)
    dt = time.time() - t0
    total_tokens = sum(len(r.tokens) for r in finished)
    print(f"served {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"batch={args.batch}, fusion={args.fusion})")
    for r in finished[:4]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.tokens[:12]}")
    if args.tune_cache:
        save_cache(args.tune_cache)
    return finished


if __name__ == "__main__":
    main()
