"""Comm-graph construction: classify collectives in a traced jaxpr.

The walker recurses through every sub-jaxpr a container equation carries
(``pjit``/``scan``/``remat2``/``custom_vjp``/``while``/``cond``/...), so
collectives buried inside a remat'd layer stack under ``lax.scan`` are
found at any depth.  Each ``shard_map`` equation is fingerprinted against
the fused-op pattern families this repo implements:

  matmul_allreduce       dot_general -> psum          (row-parallel layer)
  allgather_matmul       all_gather -> dot_general    (SP qkv/up proj)
  matmul_reducescatter   dot_general -> reduce_scatter (SP down proj)
  moe_dispatch_combine   dispatch A2A -> expert FFN -> combine A2A
  embedding_a2a          per-table pooling -> world-axis A2A (DLRM)

plus two recognized-but-not-rewritten classes: bodies already running a
ring schedule (``ppermute`` — the hand-fused ops and the vocab-sharded
CE/embedding rings) and the bulk KV all-gather attention (a ring rewrite
would reassociate the online softmax, so it is opt-in, never automatic).

Classification is deliberately conservative: a body that does not match a
family *exactly* (equation counts, feed edges, collective layout params)
is reported ``unmatched`` rather than guessed at — the rewriter only ever
touches sites whose replacement is bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any

from jax._src import core as jcore

from repro.parallel.sharding import ParallelContext

# Collective primitives tracked by the analyzer.  ``pmax``/``pmin`` ride
# along for reporting (the attention stat merge) but match no family.
COLLECTIVE_PRIMS = frozenset({
    "psum", "all_to_all", "all_gather", "reduce_scatter", "psum_scatter",
    "ppermute", "pmax", "pmin",
})

# Containers the rewriter knows how to rebuild around a rewritten site.
REBUILDABLE_CONTAINERS = frozenset({"pjit", "scan", "remat2", "checkpoint"})

# family tags
MATMUL_ALLREDUCE = "matmul_allreduce"
ALLGATHER_MATMUL = "allgather_matmul"
MATMUL_REDUCESCATTER = "matmul_reducescatter"
MOE_DISPATCH_COMBINE = "moe_dispatch_combine"
EMBEDDING_A2A = "embedding_a2a"
ALREADY_FUSED = "already_fused"
KV_ALLGATHER = "kv_allgather"
BARE_COLLECTIVE = "bare_collective"
UNMATCHED = "unmatched"

FUSIBLE_FAMILIES = frozenset({
    MATMUL_ALLREDUCE, ALLGATHER_MATMUL, MATMUL_REDUCESCATTER,
    MOE_DISPATCH_COMBINE, EMBEDDING_A2A,
})


@dataclasses.dataclass
class CollectiveSite:
    """One collective occurrence: a ``shard_map`` equation (or a bare
    collective), where it sits, and what family it matched."""

    family: str
    eqn: Any                          # the shard_map / collective eqn
    containers: tuple                 # container eqns from root to site
    path: tuple[str, ...]             # container primitive names
    prims: tuple[tuple[str, int], ...]  # recursive collective histogram
    axes: tuple[str, ...]             # mesh axes the collectives span
    in_shapes: tuple[tuple[int, ...], ...]  # global invar shapes
    rewritable: bool                  # every container can be rebuilt
    detail: dict = dataclasses.field(default_factory=dict)

    @property
    def pathstr(self) -> str:
        return "/".join(self.path) or "top"


@dataclasses.dataclass
class CommGraph:
    """Every collective site of one traced function, in trace order.
    Holds the ``ClosedJaxpr`` so equation identities stay stable for the
    rewrite plan keyed on them."""

    closed: Any
    sites: list[CollectiveSite]

    def families(self) -> Counter:
        return Counter(s.family for s in self.sites)


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------
def sub_jaxprs(eqn) -> list:
    """Every sub-jaxpr an equation's params carry (generic: any
    ``Jaxpr``/``ClosedJaxpr`` value, or tuple thereof — covers pjit, scan,
    remat2, shard_map, cond branches, custom_vjp/jvp calls)."""
    out = []
    for v in eqn.params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jcore.ClosedJaxpr):
                    out.append(x.jaxpr)
                elif isinstance(x, jcore.Jaxpr):
                    out.append(x)
    return out


def _axis_tuple(val) -> tuple[str, ...]:
    if val is None:
        return ()
    if isinstance(val, str):
        return (val,)
    return tuple(val)


def collective_axes(eqn) -> tuple[str, ...]:
    """Mesh axes one collective equation runs over (``axes`` for psum-family,
    ``axis_name`` for the permute/gather/a2a family)."""
    p = eqn.params
    return _axis_tuple(p.get("axes", p.get("axis_name")))


def _collect_collectives(jaxpr) -> tuple[Counter, set]:
    """Recursive (collective histogram, axis set) under one jaxpr."""
    prims: Counter = Counter()
    axes: set = set()
    for eqn in jaxpr.eqns:
        nm = eqn.primitive.name
        if nm in COLLECTIVE_PRIMS:
            prims[nm] += 1
            axes.update(collective_axes(eqn))
        for sj in sub_jaxprs(eqn):
            p, a = _collect_collectives(sj)
            prims.update(p)
            axes.update(a)
    return prims, axes


def _body_jaxpr(eqn):
    body = eqn.params["jaxpr"]
    if isinstance(body, jcore.ClosedJaxpr):
        body = body.jaxpr
    return body


def _invar_pos(body, var) -> int:
    for i, v in enumerate(body.invars):
        if v is var:
            return i
    return -1


def _first(body, name):
    for i, e in enumerate(body.eqns):
        if e.primitive.name == name:
            return i, e
    return -1, None


# ---------------------------------------------------------------------------
# shard_map fingerprinting
# ---------------------------------------------------------------------------
def _unmatched(why: str) -> tuple[str, dict]:
    return UNMATCHED, {"why": why}


def _match_allgather_matmul(body, ctx) -> tuple[str, dict]:
    _, ag = _first(body, "all_gather")
    _, dot = _first(body, "dot_general")
    if (ag.params.get("all_gather_dimension") != 1
            or not ag.params.get("tiled", False)):
        return _unmatched("all_gather layout is not the tiled seq-dim "
                          "gather the fused op implements")
    if dot.invars[0] is not ag.outvars[0]:
        return _unmatched("all_gather output does not feed the matmul lhs")
    x_pos = _invar_pos(body, ag.invars[0])
    w_pos = _invar_pos(body, dot.invars[1])
    if x_pos < 0 or w_pos < 0:
        return _unmatched("matmul operands are not shard_map inputs")
    return ALLGATHER_MATMUL, {"x_pos": x_pos, "w_pos": w_pos}


def _match_matmul_reducescatter(body, ctx) -> tuple[str, dict]:
    _, dot = _first(body, "dot_general")
    rs = next((e for e in body.eqns
               if e.primitive.name in ("reduce_scatter", "psum_scatter")), None)
    if (rs.params.get("scatter_dimension") != 1
            or not rs.params.get("tiled", False)):
        return _unmatched("reduce_scatter layout is not the tiled seq-dim "
                          "scatter the fused op implements")
    if rs.invars[0] is not dot.outvars[0]:
        return _unmatched("matmul output does not feed the reduce_scatter")
    x_pos = _invar_pos(body, dot.invars[0])
    w_pos = _invar_pos(body, dot.invars[1])
    if x_pos < 0 or w_pos < 0:
        return _unmatched("matmul operands are not shard_map inputs")
    return MATMUL_REDUCESCATTER, {"x_pos": x_pos, "w_pos": w_pos}


def _match_matmul_allreduce(body, ctx) -> tuple[str, dict]:
    _, dot = _first(body, "dot_general")
    _, ps = _first(body, "psum")
    if ps.invars[0] is not dot.outvars[0]:
        return _unmatched("matmul output does not feed the psum")
    x_pos = _invar_pos(body, dot.invars[0])
    w_pos = _invar_pos(body, dot.invars[1])
    if x_pos < 0 or w_pos < 0:
        return _unmatched("matmul operands are not shard_map inputs")
    return MATMUL_ALLREDUCE, {"x_pos": x_pos, "w_pos": w_pos}


def _a2a_layout_ok(eqn) -> bool:
    p = eqn.params
    return (p.get("split_axis") == 0 and p.get("concat_axis") == 0
            and not p.get("tiled", False)
            and p.get("axis_index_groups") is None)


def _match_moe(eqn, body, ctx) -> tuple[str, dict]:
    a2as = [(i, e) for i, e in enumerate(body.eqns)
            if e.primitive.name == "all_to_all"]
    if len(a2as) != 2:
        return _unmatched(f"{len(a2as)} all_to_alls in an MoE-shaped body "
                          "(expected dispatch + combine)")
    (di, disp), (ci, comb) = a2as
    for e in (disp, comb):
        if not _a2a_layout_ok(e):
            return _unmatched("all_to_all layout is not the leading-axis "
                              "per-destination exchange the fused op "
                              "implements")
        if collective_axes(e) != (ctx.tp_axis,):
            return _unmatched(f"all_to_all rings over "
                              f"{collective_axes(e)}, not the tp axis")
    buf_shape = tuple(disp.invars[0].aval.shape)
    if len(buf_shape) != 4:
        return _unmatched("dispatch payload is not the [n, E_loc, C, D] "
                          "capacity buffer")
    d_ff = 0
    for e in body.eqns[di + 1:ci]:
        if e.primitive.name == "dot_general":
            d_ff = int(e.invars[1].aval.shape[-1])
            break
    return MOE_DISPATCH_COMBINE, {
        "dispatch": di, "combine": ci, "axis": ctx.tp_axis,
        "buf_shape": buf_shape, "d_ff": d_ff,
        "body": jcore.ClosedJaxpr(body, ()),
    }


def _match_embedding(eqn, body, in_names, ctx) -> tuple[str, dict]:
    _, a2a = _first(body, "all_to_all")
    if not _a2a_layout_ok(a2a):
        return _unmatched("all_to_all layout is not the leading-axis "
                          "per-destination exchange the fused op implements")
    world_axes = tuple(ctx.dp_axes) + (ctx.tp_axis,)
    if set(collective_axes(a2a)) != set(world_axes):
        return _unmatched(f"all_to_all rings over {collective_axes(a2a)}, "
                          f"not the flattened world axes {world_axes}")
    if len(eqn.invars) != 2:
        return _unmatched("expected exactly (indices, tables) inputs")
    idx_pos = next((i for i, nm in enumerate(in_names) if set(nm) == {1}), -1)
    tab_pos = next((i for i, nm in enumerate(in_names) if set(nm) == {0}), -1)
    if idx_pos < 0 or tab_pos < 0 or idx_pos == tab_pos:
        return _unmatched("input shardings do not match the table-parallel "
                          "embedding layout")
    return EMBEDDING_A2A, {"indices_pos": idx_pos, "tables_pos": tab_pos}


def _classify_shard_map(eqn, ctx, containers, path) -> CollectiveSite:
    body = _body_jaxpr(eqn)
    top = Counter(e.primitive.name for e in body.eqns)
    colls, axes = _collect_collectives(body)
    in_names = tuple(dict(n) for n in eqn.params["in_names"])
    rewritable = all(c.primitive.name in REBUILDABLE_CONTAINERS
                     for c in containers)

    if colls.get("ppermute"):
        family, detail = ALREADY_FUSED, {
            "why": "already fused: body runs a ppermute ring schedule"}
    elif top.get("all_to_all", 0) >= 2 and top.get("top_k", 0) >= 1:
        family, detail = _match_moe(eqn, body, ctx)
    elif (top.get("all_to_all") == 1 and not colls.get("dot_general")
          and "dot_general" not in top
          and len(collective_axes(body.eqns[_first(body, "all_to_all")[0]])) > 1):
        family, detail = _match_embedding(eqn, body, in_names, ctx)
    elif (top.get("all_gather") == 1 and top.get("dot_general") == 1
          and sum(colls.values()) == 1):
        family, detail = _match_allgather_matmul(body, ctx)
    elif (top.get("dot_general") == 1 and sum(colls.values()) == 1
          and (top.get("reduce_scatter", 0) + top.get("psum_scatter", 0)) == 1):
        family, detail = _match_matmul_reducescatter(body, ctx)
    elif (top.get("dot_general") == 1 and top.get("psum") == 1
          and sum(colls.values()) == 1):
        family, detail = _match_matmul_allreduce(body, ctx)
    elif colls.get("all_gather", 0) >= 2:
        family, detail = KV_ALLGATHER, {
            "why": "bulk KV all-gather attention: a ring rewrite "
                   "reassociates the online softmax (not value-preserving; "
                   "opt in via FusionConfig.fuse_kv_ag)"}
    else:
        family, detail = _unmatched(
            "no fusible compute/collective adjacency matched")

    return CollectiveSite(
        family=family, eqn=eqn, containers=containers, path=path,
        prims=tuple(sorted(colls.items())), axes=tuple(sorted(axes)),
        in_shapes=tuple(tuple(v.aval.shape) for v in eqn.invars),
        rewritable=rewritable, detail=detail)


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------
def build_comm_graph(closed, ctx: ParallelContext) -> CommGraph:
    """Walk ``closed`` (a ``jax.make_jaxpr`` result) and classify every
    collective site against the fused-op families."""
    sites: list[CollectiveSite] = []

    def walk(jaxpr, containers, path):
        for eqn in jaxpr.eqns:
            nm = eqn.primitive.name
            if nm == "shard_map":
                sites.append(_classify_shard_map(eqn, ctx, containers, path))
            elif nm in COLLECTIVE_PRIMS:
                sites.append(CollectiveSite(
                    family=BARE_COLLECTIVE, eqn=eqn, containers=containers,
                    path=path, prims=((nm, 1),),
                    axes=tuple(sorted(collective_axes(eqn))),
                    in_shapes=tuple(tuple(v.aval.shape)
                                    for v in eqn.invars),
                    rewritable=False,
                    detail={"why": f"bare {nm} outside shard_map (left to "
                                   "the partitioner)"}))
            else:
                subs = sub_jaxprs(eqn)
                if subs:
                    for sj in subs:
                        walk(sj, containers + (eqn,), path + (nm,))

    walk(closed.jaxpr, (), ())
    return CommGraph(closed=closed, sites=sites)
