"""Static comm-graph analysis over jaxprs (CoCoNet/Inductor-style pass).

Three layers:

  :mod:`repro.analysis.commgraph` — walk a traced ``ClosedJaxpr`` and
  classify every collective (inside and outside ``shard_map`` bodies)
  into the repo's fused-op pattern families.

  :mod:`repro.analysis.rewrite` — score each match bulk-vs-fused with the
  alpha-beta model (per-axis hardware, autotune cache, degradation
  quarantines) and return a rewritten callable that routes profitable
  matches through the existing fused ops (``--auto-fuse``).

  :mod:`repro.analysis.lint` — report-only explain mode plus the static
  schedule verifier shared with the property-test suite
  (``--explain-comm`` / ``scripts/lint_comm.py``).
"""
from repro.analysis.commgraph import (CollectiveSite, CommGraph,
                                      build_comm_graph)
from repro.analysis.rewrite import FusionPlan, SiteReport, auto_fuse, plan_rewrites
from repro.analysis.lint import (explain_comm, render_report,
                                 schedule_violations, verify_schedules)

__all__ = [
    "CollectiveSite", "CommGraph", "build_comm_graph",
    "FusionPlan", "SiteReport", "auto_fuse", "plan_rewrites",
    "explain_comm", "render_report", "schedule_violations",
    "verify_schedules",
]
