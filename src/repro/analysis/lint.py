"""Collective lint: the ``--explain-comm`` report and the static schedule
verifier.

The report is report-only by design: it traces the user's step function
(shapes only — ``jax.ShapeDtypeStruct`` args work), runs the same
classification and scoring the ``--auto-fuse`` pass uses, and prints one
line per collective site — family, location, shapes, the modeled
bulk→fused times, and a concrete reason whenever a site is not fusible
(indivisible shape, unsupported axis, quarantined key, wire constraint,
opaque container, no modeled win).

The schedule verifier proves, before anything is traced, that the static
send schedule of the direct-A2A family is a permutation: for every
``skew ∈ [0, world)`` and sub-chunk factor ``q``, each rank's
``sub_chunk_send_events`` covers every (destination, fine-chunk) pair
exactly once, and ``sub_chunk_service_order`` is a permutation of the
sub-rings.  The expected cover comes from
:func:`repro.core.scheduling.expected_send_cover` — the same single
definition the hypothesis property suite checks against, so the lint lane
and the tests cannot drift.  ``events_fn``/``order_fn`` are injectable so
a unit test can prove the verifier actually rejects a corrupted schedule
(the PR-3 dropped-skew bug class).
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Iterable, Sequence

import jax

from repro.core.scheduling import (expected_send_cover, sub_chunk_send_events,
                                   sub_chunk_service_order)
from repro.parallel.sharding import ParallelContext


# ---------------------------------------------------------------------------
# static schedule verification
# ---------------------------------------------------------------------------
def schedule_violations(world: int, chunks_per_rank: int,
                        schedule: str = "comm_aware", skew: int = 0, *,
                        events_fn: Callable | None = None,
                        order_fn: Callable | None = None) -> list[str]:
    """Check one (world, q, schedule, skew) point; return violation
    messages (empty = the schedule is a valid exact cover)."""
    events_fn = events_fn or sub_chunk_send_events
    order_fn = order_fn or sub_chunk_service_order
    q = chunks_per_rank
    tag = f"world={world} q={q} {schedule} skew={skew}"
    want = expected_send_cover(world, q)
    msgs: list[str] = []
    events = events_fn(world, q, schedule, skew)
    if len(events) != world:
        return [f"{tag}: {len(events)} per-rank schedules for {world} ranks"]
    for r, sends in enumerate(events):
        seen = Counter(tuple(ev) for ev in sends)
        for pair, cnt in sorted(seen.items()):
            if cnt > 1:
                msgs.append(f"{tag} rank {r}: (dest,fine)={pair} sent "
                            f"{cnt} times")
            if pair not in want:
                msgs.append(f"{tag} rank {r}: spurious send {pair} "
                            "(fine chunk does not belong to dest)")
        missing = sorted(want - set(seen))
        for pair in missing:
            msgs.append(f"{tag} rank {r}: (dest,fine)={pair} never sent")
    order = order_fn(q, skew)
    if sorted(order) != list(range(max(q, 1))):
        msgs.append(f"{tag}: service order {order} is not a permutation "
                    f"of {max(q, 1)} sub-rings")
    return msgs


def verify_schedules(worlds: Iterable[int] = (2, 4, 8),
                     qs: Iterable[int] = (1, 2, 4),
                     schedules: Iterable[str] = ("comm_aware", "oblivious"),
                     *, events_fn: Callable | None = None,
                     order_fn: Callable | None = None) -> list[str]:
    """Sweep every skew rotation of every (world, q, schedule) candidate
    — the full space a launch could configure — and return all
    violations."""
    msgs: list[str] = []
    for world in worlds:
        for q in qs:
            for sched in schedules:
                for skew in range(world):
                    msgs.extend(schedule_violations(
                        world, q, sched, skew,
                        events_fn=events_fn, order_fn=order_fn))
    return msgs


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------
def _fmt_shapes(shapes: Sequence) -> str:
    return " ".join("x".join(str(d) for d in s) if s else "scalar"
                    for s in shapes)


def render_report(reports, ctx: ParallelContext) -> str:
    """Human-readable comm-graph report from ``plan_rewrites`` output."""
    mesh = dict(ctx.mesh.shape)
    fam = Counter(r.family for r in reports)
    lines = [f"comm-graph report: {len(reports)} collective site(s) on mesh "
             f"{mesh}, fusion mode {ctx.fusion.mode!r}",
             "families: " + (", ".join(f"{k} x{v}"
                                       for k, v in sorted(fam.items()))
                             or "none")]
    for i, r in enumerate(reports):
        lines.append(f"[{i}] {r.family}  at {r.path}  "
                     f"axes={','.join(r.axes) or '-'}  "
                     f"shapes {_fmt_shapes(r.shapes)}")
        if r.bulk_us is not None:
            dec = f"q={r.q} wire={r.wire}"
            sav = (f"  ({r.savings_pct:+.1f}%)"
                   if r.savings_pct is not None else "")
            lines.append(f"    modeled bulk {r.bulk_us:.2f}us -> fused "
                         f"{r.fused_us:.2f}us{sav}  [{dec}]")
        if r.rewritten:
            lines.append("    fusible: yes — rewritten to the fused op")
        elif r.fusible:
            lines.append("    fusible: yes")
        else:
            lines.append(f"    fusible: no — {r.reason}")
        if r.kernel:
            lines.append(f"    kernel: {r.kernel}")
    n_rw = sum(1 for r in reports if r.rewritten)
    lines.append(f"{n_rw}/{len(reports)} site(s) rewritten")
    return "\n".join(lines)


def explain_comm(ctx: ParallelContext, fn, *args) -> str:
    """Trace ``fn(*args)`` (arrays or ShapeDtypeStructs), classify and
    score every collective, and render the report.  Report-only: nothing
    is rewritten or executed."""
    from repro.analysis.commgraph import build_comm_graph
    from repro.analysis.rewrite import plan_rewrites

    closed = jax.make_jaxpr(fn)(*args)
    graph = build_comm_graph(closed, ctx)
    plan = plan_rewrites(graph, ctx)
    return render_report(plan.reports, ctx)
