"""Automatic fusion pass: score comm-graph matches, rewrite the winners.

Scoring mirrors the fused-op call sites exactly — same ``resolve_overlap``
/ ``tune_*`` invocations (so decisions land in, and are served from, the
same autotune cache the hand-fused path uses), same per-axis hardware
resolution, same degradation-quarantine keys.  A site is rewritten only
when every gate passes:

  * the family's ``FusionConfig.fuse_*`` flag is on,
  * the collective rings over the axis the fused op supports,
  * the chunked dimension divides the ring (indivisible shapes stay bulk),
  * the ``(op, shape)`` key is not quarantined by the degradation policy,
  * a pinned fp8 wire is only honored on fp8-capable links,
  * the alpha-beta model projects a win (fused < bulk).

The rewrite itself is an interpreter over the traced jaxpr.  Matched
``shard_map`` equations are replaced by calls to the *actual* fused-op
wrappers (``matmul_allreduce``/``allgather_matmul``/...) under a
mode="fused" context — bit-identity with the hand-fused path holds by
construction because it *is* the hand-fused path.  The MoE body (whose
routing config is not recoverable from the jaxpr) is instead rebuilt as a
shard_map interpreting the original body with the two all_to_alls
replaced by ``direct_all_to_all_compute``; the expert-FFN chain between
them is re-played per destination so each output block ships the moment
it is computed (the paper's GEMM+A2A fusion).  Containers on the path to
a rewritten site (``scan``/``remat2``/``pjit``) are rebuilt around the
interpreted body; everything untouched binds verbatim.

The interpreter must run under ``jax.jit`` (shard_map bodies cannot be
evaluated eagerly) — both launchers and ``auto_fuse`` arrange that.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax import lax
from jax.sharding import PartitionSpec as P
from jax._src import core as jcore

from repro.analysis import commgraph as cg
from repro.compat import shard_map
from repro.core.autotune import (resolve_overlap, tune_all_to_all,
                                 tune_allgather_matmul, tune_matmul_allreduce)
from repro.core.collectives import direct_all_to_all_compute, wire_itemsize
from repro.core.degrade import is_quarantined
from repro.core.perfmodel import model_pair
from repro.parallel.sharding import ParallelContext


@dataclasses.dataclass
class SiteReport:
    """One line of the ``--explain-comm`` report."""

    family: str
    path: str
    axes: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]
    fusible: bool
    rewritten: bool
    reason: str = ""
    bulk_us: float | None = None
    fused_us: float | None = None
    q: int | None = None
    wire: str | None = None
    kernel: str = ""

    @property
    def savings_pct(self) -> float | None:
        if self.bulk_us and self.fused_us is not None:
            return 100.0 * (self.bulk_us - self.fused_us) / self.bulk_us
        return None


@dataclasses.dataclass
class FusionPlan:
    """Rewrite actions keyed by equation identity, plus the per-site
    reports.  Holds the traced ``ClosedJaxpr`` so ``id(eqn)`` keys stay
    valid for the plan's lifetime."""

    closed: Any
    actions: dict[int, Any]
    rebuild: set[int]
    reports: list[SiteReport]

    @property
    def n_rewritten(self) -> int:
        return sum(1 for r in self.reports if r.rewritten)


# ---------------------------------------------------------------------------
# scoring (mirrors the wrapper call sites term for term)
# ---------------------------------------------------------------------------
def _itemsize(site, pos_key: str) -> int:
    return site.eqn.invars[site.detail[pos_key]].aval.dtype.itemsize


def _gate_common(site, ctx, *, flag: str, op: str, key_shape) -> str:
    fused = dataclasses.replace(ctx.fusion, mode="fused")
    if fused.resolve(flag) != "fused":
        return f"disabled by FusionConfig.fuse_{flag}"
    if is_quarantined(op, key_shape):
        return f"quarantined by the degradation policy ({op})"
    return ""


def _wire_gate(ctx, axis) -> str:
    if ctx.fusion.wire == "fp8" and not ctx.hw_for(axis).fp8_wire:
        return "wire constraint: fp8 payload pinned on a non-fp8 link"
    return ""


def _score_allgather_matmul(site, ctx) -> SiteReport:
    f = ctx.fusion
    x = site.in_shapes[site.detail["x_pos"]]
    w = site.in_shapes[site.detail["w_pos"]]
    b, s, k = x
    nout = w[1]
    axis, n = ctx.tp_axis, ctx.tp
    rpt = SiteReport(site.family, site.pathstr, site.axes, (x, w),
                     fusible=False, rewritten=False)
    if site.axes != (axis,):
        rpt.reason = f"unsupported axis: rings over {site.axes}, fused op " \
                     f"supports the tp axis {axis!r}"
        return rpt
    reason = (_gate_common(site, ctx, flag="ag_matmul",
                           op="allgather_matmul", key_shape=x + w)
              or _wire_gate(ctx, axis))
    if reason:
        rpt.reason = reason
        return rpt
    if s % n:
        rpt.reason = f"indivisible shape: seq {s} does not split over {n}"
        return rpt
    ds = _itemsize(site, "x_pos")
    dec = resolve_overlap(
        None, f.granularity, None, f.wire,
        lambda fq, wr: tune_allgather_matmul(
            b, s // n, k, nout // n, dtype_bytes=ds, n_dev=n, hw=ctx.hw,
            axis=axis, skew=f.skew, wire=wr, fixed_q=fq),
        dim=s // n, ring=1)
    flops = 2.0 * b * (s // n) * n * k * (nout // n)
    hbm = float(k * (nout // n) * ds)
    wire_b = float(b * (s // n) * k * ds) * (n - 1)
    return _finish(rpt, ctx, axis, dec, flops, hbm, wire_b, n * dec.q, ds)


def _score_matmul_reducescatter(site, ctx) -> SiteReport:
    f = ctx.fusion
    x = site.in_shapes[site.detail["x_pos"]]
    w = site.in_shapes[site.detail["w_pos"]]
    b, s, k = x
    nout = w[1]
    axis, n = ctx.tp_axis, ctx.tp
    rpt = SiteReport(site.family, site.pathstr, site.axes, (x, w),
                     fusible=False, rewritten=False)
    if site.axes != (axis,):
        rpt.reason = f"unsupported axis: rings over {site.axes}, fused op " \
                     f"supports the tp axis {axis!r}"
        return rpt
    reason = (_gate_common(site, ctx, flag="matmul_rs",
                           op="matmul_reducescatter", key_shape=x + w)
              or _wire_gate(ctx, axis))
    if reason:
        rpt.reason = reason
        return rpt
    if s % n:
        rpt.reason = f"indivisible shape: seq {s} does not split over {n}"
        return rpt
    ds = _itemsize(site, "x_pos")
    dec = resolve_overlap(
        None, f.granularity, None, f.wire,
        lambda fq, wr: tune_matmul_allreduce(
            b * s, k // n, nout, dtype_bytes=ds, n_dev=n, chunk_dim=s,
            allgather_phase=False, hw=ctx.hw, axis=axis, skew=f.skew,
            wire=wr, fixed_q=fq),
        dim=s, ring=n)
    flops = 2.0 * (b * s) * (k // n) * nout
    hbm = float((k // n) * nout * ds)
    wire_b = float(b * s * nout * ds)
    return _finish(rpt, ctx, axis, dec, flops, hbm, wire_b, n * dec.q, ds)


def _score_matmul_allreduce(site, ctx) -> SiteReport:
    f = ctx.fusion
    x = site.in_shapes[site.detail["x_pos"]]
    w = site.in_shapes[site.detail["w_pos"]]
    rows, k = x
    nout = w[1]
    axis, n = ctx.tp_axis, ctx.tp
    rpt = SiteReport(site.family, site.pathstr, site.axes, (x, w),
                     fusible=False, rewritten=False)
    if site.axes != (axis,):
        rpt.reason = f"unsupported axis: psum over {site.axes}, fused op " \
                     f"supports the tp axis {axis!r}"
        return rpt
    reason = (_gate_common(site, ctx, flag="matmul_rs",
                           op="matmul_allreduce", key_shape=(rows, k, nout))
              or _wire_gate(ctx, axis))
    if reason:
        rpt.reason = reason
        return rpt
    dp = ctx.dp if rows % ctx.dp == 0 else 1
    rows_local = rows // dp
    use_rows = rows_local % n == 0 and rows_local >= n
    chunk_dim = rows_local if use_rows else nout
    if chunk_dim % n:
        rpt.reason = (f"indivisible shape: neither rows {rows_local} nor "
                      f"cols {nout} split over the {n}-rank ring")
        return rpt
    ds = _itemsize(site, "x_pos")
    dec = resolve_overlap(
        None, f.granularity, None, f.wire,
        lambda fq, wr: tune_matmul_allreduce(
            rows_local, k // n, nout, dtype_bytes=ds, n_dev=n,
            chunk_dim=chunk_dim, hw=ctx.hw, axis=axis, skew=f.skew,
            wire=wr, fixed_q=fq),
        dim=chunk_dim, ring=n)
    flops = 2.0 * rows_local * (k // n) * nout
    hbm = float((k // n) * nout * ds)
    wire_b = float(rows_local * nout * ds) * 2.0
    return _finish(rpt, ctx, axis, dec, flops, hbm, wire_b, n * dec.q, ds)


def _score_embedding(site, ctx) -> SiteReport:
    f = ctx.fusion
    idx = site.in_shapes[site.detail["indices_pos"]]
    tab = site.in_shapes[site.detail["tables_pos"]]
    B, T, L = idx
    D = tab[2]
    world_axes = tuple(ctx.dp_axes) + (ctx.tp_axis,)
    n = ctx.world
    rpt = SiteReport(site.family, site.pathstr, site.axes, (idx, tab),
                     fusible=False, rewritten=False)
    reason = (_gate_common(site, ctx, flag="embed_a2a",
                           op="embedding_a2a", key_shape=idx + tab)
              or _wire_gate(ctx, world_axes))
    if reason:
        rpt.reason = reason
        return rpt
    if B % n or T % n:
        rpt.reason = (f"indivisible shape: batch {B} / tables {T} do not "
                      f"split over the {n}-rank world")
        return rpt
    ds = _itemsize(site, "tables_pos")
    t_loc = T // n
    dec = resolve_overlap(
        None, f.granularity, None, f.wire,
        lambda fq, wr: tune_all_to_all(
            (B // n) * t_loc * D, float((B // n) * t_loc * L * D),
            dtype_bytes=ds, n_dev=n, sub_dim=B // n, hw=ctx.hw,
            axis=world_axes, skew=f.skew_world, wire=wr, fixed_q=fq),
        dim=B // n, ring=1)
    chunk = (B // n) * t_loc * D
    flops = float((B // n) * t_loc * L * D) * n
    hbm = float(chunk * ds * n)
    wire_b = float(chunk * ds) * (n - 1)
    return _finish(rpt, ctx, world_axes, dec, flops, hbm, wire_b,
                   n * dec.q, ds)


def _moe_kernel_note(ctx, key_shape) -> str:
    """Device-initiated dispatch-kernel availability for an MoE site:
    mesh-shape gate (the interpreter maps multi-axis meshes through the
    flattened world), degradation quarantine, and the wire constraint
    (the PUT payload has no per-chunk fp8 scale — fp8 clamps to bf16)."""
    from repro.kernels.fused_dispatch_a2a.ops import (
        fused_dispatch_a2a_kernel_available)
    if not fused_dispatch_a2a_kernel_available(ctx.mesh):
        return "unavailable — interpret mode needs a known mesh shape"
    if is_quarantined("moe_a2a_kernel", key_shape):
        return "unavailable — quarantined by the degradation policy"
    note = ("available — device-initiated dispatch PUT ring chained "
            "with the FFN+combine kernel (mode='kernel')")
    if ctx.fusion.wire == "fp8":
        note += "; wire='fp8' clamps to bf16 on the PUT payload"
    return note


def _score_moe(site, ctx) -> SiteReport:
    n_ring, e_loc, cap, d = site.detail["buf_shape"]
    d_ff = site.detail["d_ff"] or d
    axis, n = ctx.tp_axis, ctx.tp
    rpt = SiteReport(site.family, site.pathstr, site.axes, site.in_shapes,
                     fusible=False, rewritten=False)
    rpt.kernel = _moe_kernel_note(ctx, (n_ring, e_loc, cap, d))
    reason = (_gate_common(site, ctx, flag="moe_a2a", op="moe_a2a",
                           key_shape=(n_ring, e_loc, cap, d))
              or _wire_gate(ctx, axis))
    if reason:
        rpt.reason = reason
        return rpt
    if n_ring != n:
        rpt.reason = (f"unsupported axis: dispatch buffer splits {n_ring} "
                      f"ways, tp ring is {n}")
        return rpt
    ds = site.eqn.invars[0].aval.dtype.itemsize
    chunk = e_loc * cap * d
    flops = 6.0 * e_loc * cap * d * d_ff  # gate+up+down GEMMs per dest
    hbm = float(chunk * ds * n)
    wire_b = 2.0 * float(chunk * ds) * (n - 1)  # dispatch + combine
    # the MoE A2As ship whole per-destination blocks (no sub-chunking or
    # wire compression in the hand-fused op, so none here either)
    bulk_t, fused_t = model_pair(flops * n, hbm, wire_b, n,
                                 hw=ctx.hw, axis=axis)
    rpt.fusible = True
    rpt.bulk_us, rpt.fused_us = bulk_t * 1e6, fused_t * 1e6
    rpt.q, rpt.wire = 1, "f32"
    if fused_t >= bulk_t:
        rpt.fusible = False
        rpt.reason = "modeled no win: fused time >= bulk at this shape"
    return rpt


def _finish(rpt, ctx, axis, dec, flops, hbm, wire_b, chunks, ds) -> SiteReport:
    factor = wire_itemsize(dec.wire, ds) / float(ds)
    bulk_t, fused_t = model_pair(flops, hbm, wire_b, chunks,
                                 wire_factor=factor, hw=ctx.hw, axis=axis)
    rpt.fusible = True
    rpt.bulk_us, rpt.fused_us = bulk_t * 1e6, fused_t * 1e6
    rpt.q, rpt.wire = dec.q, dec.wire
    if fused_t >= bulk_t:
        rpt.fusible = False
        rpt.reason = "modeled no win: fused time >= bulk at this shape"
    return rpt


_SCORERS: dict[str, Callable] = {
    cg.ALLGATHER_MATMUL: _score_allgather_matmul,
    cg.MATMUL_REDUCESCATTER: _score_matmul_reducescatter,
    cg.MATMUL_ALLREDUCE: _score_matmul_allreduce,
    cg.EMBEDDING_A2A: _score_embedding,
    cg.MOE_DISPATCH_COMBINE: _score_moe,
}


# ---------------------------------------------------------------------------
# rewrite actions
# ---------------------------------------------------------------------------
class _WrapperCall:
    """Replace a whole matched shard_map eqn with a call to the real
    fused-op wrapper under a mode="fused" context — the same code path,
    tuner keys and degrade keys as hand-written fused model code."""

    def __init__(self, fn, arg_positions, fctx):
        self.fn, self.arg_positions, self.fctx = fn, arg_positions, fctx

    def apply(self, invals):
        return [self.fn(self.fctx, *(invals[p] for p in self.arg_positions))]


def _names_to_specs(names, avals):
    return tuple(P(*(nm.get(i) for i in range(len(av.shape))))
                 for nm, av in zip(names, avals))


class _MoeRewrite:
    """Rebuild the MoE shard_map with the dispatch/combine all_to_alls
    replaced by per-destination direct sends; the FFN chain between them
    is replayed per destination (sunk into the combine producer)."""

    def __init__(self, site, fctx):
        self.site, self.fctx = site, fctx
        self.body = site.detail["body"]
        self.sink = _plan_sink(self.body.jaxpr, site.detail["dispatch"],
                               site.detail["combine"])

    def apply(self, invals):
        eqn = self.site.eqn
        in_specs = _names_to_specs(
            tuple(dict(n) for n in eqn.params["in_names"]),
            [v.aval for v in eqn.invars])
        out_specs = _names_to_specs(
            tuple(dict(n) for n in eqn.params["out_names"]),
            [v.aval for v in eqn.outvars])
        single = len(eqn.outvars) == 1

        def local_fn(*largs):
            outs = _eval_moe_body(self.body, largs, self.site.detail,
                                  self.sink, self.fctx)
            return outs[0] if single else tuple(outs)

        out = shard_map(local_fn, mesh=self.fctx.mesh, in_specs=in_specs,
                        out_specs=out_specs[0] if single else out_specs,
                        check_vma=False)(*invals)
        return [out] if single else list(out)


# -- combine-producer sinking ------------------------------------------------
@dataclasses.dataclass
class _SinkPlan:
    ok: bool
    chain: tuple[int, ...] = ()      # body eqn indices feeding the combine
    why: str = ""


# replay-safe primitives: shape-polymorphic under a size-1 slice of the
# tracked (per-destination) dimension
_SLICE_POLY = frozenset({
    "dot_general", "transpose", "broadcast_in_dim", "convert_element_type",
    "add", "sub", "mul", "div", "max", "min", "pow", "neg", "exp", "log",
    "tanh", "logistic", "sign", "integer_pow", "select_n", "custom_jvp_call",
    "pjit",
})


def _track_through(eqns, in_dims: dict) -> "dict | None":
    """Propagate the tracked (destination) dim through a chain of eqns.
    ``in_dims`` maps Var -> dim index; returns the extended map, or None
    when any eqn cannot be replayed shape-polymorphically."""
    dims = dict(in_dims)
    for eqn in eqns:
        nm = eqn.primitive.name
        tracked = [(i, dims[v]) for i, v in enumerate(eqn.invars)
                   if isinstance(v, jcore.Var) and v in dims]
        if not tracked:
            continue
        if nm not in _SLICE_POLY:
            return None
        if nm == "dot_general":
            if len(tracked) != 1:
                return None
            pos, t = tracked[0]
            (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            if pos == 0:
                if t in lc or t in lb:
                    return None
                free = [i for i in range(len(lhs.shape))
                        if i not in lc and i not in lb]
                out_t = len(lb) + free.index(t)
            else:
                if t in rc or t in rb:
                    return None
                lfree = [i for i in range(len(lhs.shape))
                         if i not in lc and i not in lb]
                rfree = [i for i in range(len(rhs.shape))
                         if i not in rc and i not in rb]
                out_t = len(lb) + len(lfree) + rfree.index(t)
            dims[eqn.outvars[0]] = out_t
        elif nm == "transpose":
            pos, t = tracked[0]
            dims[eqn.outvars[0]] = eqn.params["permutation"].index(t)
        elif nm == "broadcast_in_dim":
            pos, t = tracked[0]
            dims[eqn.outvars[0]] = eqn.params["broadcast_dimensions"][t]
        elif nm in ("pjit", "custom_jvp_call"):
            sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
            sub_dims = {}
            for i, v in enumerate(eqn.invars):
                if isinstance(v, jcore.Var) and v in dims:
                    sub_dims[sub.jaxpr.invars[i]] = dims[v]
            inner = _track_through(sub.jaxpr.eqns, sub_dims)
            if inner is None:
                return None
            for ov, bv in zip(eqn.outvars, sub.jaxpr.outvars):
                if isinstance(bv, jcore.Var) and bv in inner:
                    dims[ov] = inner[bv]
        else:
            # elementwise: every non-scalar operand must carry the same
            # tracked dim (lax elementwise ops do not broadcast)
            t0 = tracked[0][1]
            for i, v in enumerate(eqn.invars):
                if isinstance(v, jcore.Var) and len(v.aval.shape):
                    if v not in dims or dims[v] != t0:
                        return None
            for ov in eqn.outvars:
                dims[ov] = t0
    return dims


def _plan_sink(body, dispatch_idx: int, combine_idx: int) -> _SinkPlan:
    recv = body.eqns[dispatch_idx].outvars[0]
    y = body.eqns[combine_idx].invars[0]
    split_axis = body.eqns[combine_idx].params["split_axis"]
    downstream = {recv}
    chain = []
    for i in range(dispatch_idx + 1, combine_idx):
        eqn = body.eqns[i]
        if any(isinstance(v, jcore.Var) and v in downstream
               for v in eqn.invars):
            chain.append(i)
            downstream.update(eqn.outvars)
    # chain values must not escape: anything outside the chain (or the
    # combine itself) reading them would go uncomputed after sinking
    chain_set = set(chain)
    for i, eqn in enumerate(body.eqns):
        if i in chain_set or i == dispatch_idx or i == combine_idx:
            continue
        for v in eqn.invars:
            if isinstance(v, jcore.Var) and v in downstream:
                return _SinkPlan(False, why="chain value escapes")
    for v in body.outvars:
        if isinstance(v, jcore.Var) and v in downstream:
            return _SinkPlan(False, why="chain value escapes to outputs")
    dims = _track_through([body.eqns[i] for i in chain], {recv: 0})
    if dims is None:
        return _SinkPlan(False, why="chain not slice-polymorphic")
    if dims.get(y) != split_axis:
        return _SinkPlan(False, why="tracked dim does not reach split axis")
    return _SinkPlan(True, chain=tuple(chain))


def _replay_eqn(eqn, invals):
    """Bind one chain eqn with per-destination (size-1 tracked dim)
    operands.  pjit/custom_jvp bodies are inlined (their stored jaxprs
    carry baked full-size avals); broadcast_in_dim re-derives its shape
    from the live operand; everything else is shape-polymorphic."""
    nm = eqn.primitive.name
    if nm in ("pjit", "custom_jvp_call"):
        sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
        return _replay_jaxpr(sub.jaxpr, sub.consts, invals)
    if nm == "broadcast_in_dim":
        shape = list(eqn.params["shape"])
        for i, bd in enumerate(eqn.params["broadcast_dimensions"]):
            shape[bd] = invals[0].shape[i]
        out = lax.broadcast_in_dim(
            invals[0], tuple(shape), eqn.params["broadcast_dimensions"])
        return [out]
    subfuns, bp = eqn.primitive.get_bind_params(eqn.params)
    ans = eqn.primitive.bind(*subfuns, *invals, **bp)
    return list(ans) if eqn.primitive.multiple_results else [ans]


def _replay_jaxpr(jaxpr, consts, args):
    env = {}
    for v, c in zip(jaxpr.constvars, consts):
        env[v] = c
    for v, a in zip(jaxpr.invars, args):
        env[v] = a

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    for eqn in jaxpr.eqns:
        outs = _replay_eqn(eqn, [read(v) for v in eqn.invars])
        for ov, o in zip(eqn.outvars, outs):
            env[ov] = o
    return [read(v) for v in jaxpr.outvars]


def _eval_moe_body(closed, args, detail, sink: _SinkPlan, fctx):
    """Interpret the MoE shard_map body with fused dispatch/combine."""
    jaxpr = closed.jaxpr
    di, ci = detail["dispatch"], detail["combine"]
    axis = detail["axis"]
    schedule = fctx.fusion.schedule
    env: dict = {}
    for v, c in zip(jaxpr.constvars, closed.consts):
        env[v] = c
    for v, a in zip(jaxpr.invars, args):
        env[v] = a

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    skip = set(sink.chain) if sink.ok else set()
    for i, eqn in enumerate(jaxpr.eqns):
        if i in skip:
            continue
        if i == di:
            buf = read(eqn.invars[0])

            def produce_d(dest):
                return lax.dynamic_index_in_dim(buf, dest, axis=0,
                                                keepdims=False)

            recv = direct_all_to_all_compute(
                produce_d, jax.ShapeDtypeStruct(buf.shape[1:], buf.dtype),
                axis, schedule=schedule)
            env[eqn.outvars[0]] = recv
            continue
        if i == ci:
            if sink.ok:
                # replay the FFN chain per destination so each output
                # block is produced right before its direct send
                recv_var = jaxpr.eqns[di].outvars[0]
                y_var = eqn.invars[0]
                chain_eqns = [jaxpr.eqns[j] for j in sink.chain]
                recv_full = env[recv_var]
                chunk_shape = tuple(s for d, s in
                                    enumerate(y_var.aval.shape) if d != 0)

                def produce_c(dest):
                    local = {recv_var: lax.dynamic_slice_in_dim(
                        recv_full, dest, 1, axis=0)}

                    def rd(v):
                        if isinstance(v, jcore.Literal):
                            return v.val
                        return local[v] if v in local else env[v]

                    for ce in chain_eqns:
                        outs = _replay_eqn(ce, [rd(v) for v in ce.invars])
                        for ov, o in zip(ce.outvars, outs):
                            local[ov] = o
                    return lax.squeeze(local[y_var], dimensions=(0,))
            else:
                y_full = read(eqn.invars[0])
                chunk_shape = tuple(y_full.shape[1:])

                def produce_c(dest):
                    return lax.dynamic_index_in_dim(y_full, dest, axis=0,
                                                    keepdims=False)

            comb = direct_all_to_all_compute(
                produce_c,
                jax.ShapeDtypeStruct(chunk_shape,
                                     eqn.invars[0].aval.dtype),
                axis, schedule=schedule)
            env[eqn.outvars[0]] = comb
            continue
        subfuns, bp = eqn.primitive.get_bind_params(eqn.params)
        ans = eqn.primitive.bind(*subfuns, *[read(v) for v in eqn.invars],
                                 **bp)
        outs = list(ans) if eqn.primitive.multiple_results else [ans]
        for ov, o in zip(eqn.outvars, outs):
            env[ov] = o
    return [read(v) for v in jaxpr.outvars]


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------
def plan_rewrites(graph: cg.CommGraph, ctx: ParallelContext) -> FusionPlan:
    """Score every site of ``graph``; build actions for the winners."""
    from repro.core.allgather_matmul import (allgather_matmul,
                                             matmul_reducescatter)
    from repro.core.embedding_all_to_all import embedding_all_to_all
    from repro.core.matmul_allreduce import matmul_allreduce

    fctx = ctx.with_fusion(dataclasses.replace(ctx.fusion, mode="fused"))
    actions: dict[int, Any] = {}
    rebuild: set[int] = set()
    reports: list[SiteReport] = []
    wrappers = {
        cg.ALLGATHER_MATMUL: allgather_matmul,
        cg.MATMUL_REDUCESCATTER: matmul_reducescatter,
        cg.MATMUL_ALLREDUCE: matmul_allreduce,
    }
    for site in graph.sites:
        scorer = _SCORERS.get(site.family)
        if scorer is None:
            reports.append(SiteReport(
                site.family, site.pathstr, site.axes, site.in_shapes,
                fusible=False, rewritten=False,
                reason=site.detail.get("why", "")))
            continue
        rpt = scorer(site, ctx)
        if rpt.fusible and not site.rewritable:
            opaque = [c.primitive.name for c in site.containers
                      if c.primitive.name not in cg.REBUILDABLE_CONTAINERS]
            rpt.fusible = False
            rpt.reason = (f"inside a {opaque[0]} boundary — container "
                          "cannot be rebuilt")
        if rpt.fusible:
            if site.family in wrappers:
                pos = (site.detail["x_pos"], site.detail["w_pos"])
                actions[id(site.eqn)] = _WrapperCall(
                    wrappers[site.family], pos, fctx)
            elif site.family == cg.EMBEDDING_A2A:
                pos = (site.detail["indices_pos"],
                       site.detail["tables_pos"])
                actions[id(site.eqn)] = _WrapperCall(
                    embedding_all_to_all, pos, fctx)
            else:
                actions[id(site.eqn)] = _MoeRewrite(site, fctx)
            rpt.rewritten = True
            for c in site.containers:
                rebuild.add(id(c))
        reports.append(rpt)
    return FusionPlan(closed=graph.closed, actions=actions,
                      rebuild=rebuild, reports=reports)


# ---------------------------------------------------------------------------
# plan execution (the rewritten step)
# ---------------------------------------------------------------------------
def _rebuild_container(eqn, invals, plan, fctx):
    nm = eqn.primitive.name
    if nm == "pjit":
        closed = eqn.params["jaxpr"]
        return _eval_jaxpr(closed.jaxpr, closed.consts, invals, plan, fctx)
    if nm in ("remat2", "checkpoint"):
        jx = eqn.params["jaxpr"]
        consts = ()
        if isinstance(jx, jcore.ClosedJaxpr):
            jx, consts = jx.jaxpr, jx.consts

        def fn(*a):
            return tuple(_eval_jaxpr(jx, consts, a, plan, fctx))

        out = jax.checkpoint(fn, policy=eqn.params.get("policy"),
                             prevent_cse=eqn.params.get("prevent_cse", True))(
            *invals)
        return list(out)
    if nm == "scan":
        closed = eqn.params["jaxpr"]
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        consts_v = tuple(invals[:nc])
        init = tuple(invals[nc:nc + ncar])
        xs = tuple(invals[nc + ncar:])

        def body_fn(carry, x):
            outs = _eval_jaxpr(closed.jaxpr, closed.consts,
                               list(consts_v) + list(carry) + list(x),
                               plan, fctx)
            return tuple(outs[:ncar]), tuple(outs[ncar:])

        carry_out, ys = lax.scan(body_fn, init, xs,
                                 length=eqn.params["length"],
                                 reverse=eqn.params["reverse"],
                                 unroll=eqn.params.get("unroll", 1))
        return list(carry_out) + list(ys)
    raise NotImplementedError(
        f"cannot rebuild a {nm} container around a rewritten site")


def _eval_jaxpr(jaxpr, consts, args, plan: FusionPlan, fctx):
    env: dict = {}
    for v, c in zip(jaxpr.constvars, consts):
        env[v] = c
    for v, a in zip(jaxpr.invars, args):
        env[v] = a

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    for eqn in jaxpr.eqns:
        invals = [read(v) for v in eqn.invars]
        act = plan.actions.get(id(eqn))
        if act is not None:
            outs = act.apply(invals)
        elif id(eqn) in plan.rebuild:
            outs = _rebuild_container(eqn, invals, plan, fctx)
        else:
            subfuns, bp = eqn.primitive.get_bind_params(eqn.params)
            ans = eqn.primitive.bind(*subfuns, *invals, **bp)
            outs = list(ans) if eqn.primitive.multiple_results else [ans]
        for ov, o in zip(eqn.outvars, outs):
            env[ov] = o
    return [read(v) for v in jaxpr.outvars]


def run_plan(plan: FusionPlan, ctx: ParallelContext, flat_args):
    """Evaluate the planned rewrite over flat arguments (jit-traced)."""
    closed = plan.closed
    return _eval_jaxpr(closed.jaxpr, closed.consts, flat_args, plan, ctx)


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------
def auto_fuse(ctx: ParallelContext, fn, *, reports: "list | None" = None):
    """Wrap ``fn`` (a loss/decode callable whose collectives trace bulk —
    ``FusionConfig(mode="auto")`` arranges that) so matched subgraphs run
    through the fused ops.  Tracing/planning happens once per distinct
    argument structure; the wrapped callable is differentiable and must
    run under ``jax.jit``.  ``reports`` (optional list) receives the
    per-trace ``list[SiteReport]`` for introspection."""
    cache: dict = {}

    def wrapped(*args):
        import numpy as np

        leaves, treedef = jax.tree.flatten(args)
        key = (treedef,
               tuple((tuple(np.shape(l)), str(np.result_type(l)))
                     for l in leaves))
        entry = cache.get(key)
        if entry is None:
            closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
            graph = cg.build_comm_graph(closed, ctx)
            plan = plan_rewrites(graph, ctx)
            out_tree = jax.tree.structure(out_shape)
            cache[key] = entry = (plan, out_tree)
            if reports is not None:
                reports.append(plan.reports)
        plan, out_tree = entry
        out_flat = run_plan(plan, ctx, leaves)
        return jax.tree.unflatten(out_tree, out_flat)

    wrapped.cache = cache
    return wrapped
