"""Fused GEMV/GEMM + AllReduce (paper §III-B, Fig. 7).

Megatron row-parallel layer: ``x`` carries the contraction dim sharded
over TP, ``w`` is row-sharded; every rank produces a *partial* full-size
output that must be summed across TP ranks.

  bulk   : y = psum(x_local @ w_local)           (RCCL-baseline analogue)
  fused  : the output is chunked; a matmul-interleaved ring reduce-scatter
           accumulates each chunk while other chunks are still being
           computed, followed by an all-gather of reduced chunks — the
           two phases of the paper's direct AllReduce, with phase one
           fused into the GEMV/GEMM.  Comm-aware scheduling: a rank's own
           output chunk is computed last (Fig. 7b).
  kernel : Pallas device-initiated kernel (remote DMA writes straight
           into the peer's reduction buffer — zero-copy scale-up path).

Chunking dimension is chosen automatically: rows (flattened leading dims)
when they divide the ring, else output columns — decode-shape GEMV
(B·1 rows) always chunks over columns, matching the paper's output-tile
granularity for matrix-vector work.

Granularity (paper Fig. 13): ``chunks_per_rank`` splits each ring step's
payload into sub-chunks, every sub-chunk shipped the moment its partial
matmul finishes.  ``None`` defers to ``FusionConfig.granularity`` (an
int, or ``"auto"`` for the shape-keyed alpha-beta autotuner); infeasible
values are clamped to the largest factor dividing the chunked dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import resolve_overlap, tune_matmul_allreduce
from repro.core.collectives import (all_gather_wire,
                                    ring_reduce_scatter_compute)
from repro.core.degrade import degrade_mode
from repro.parallel.sharding import ParallelContext
from repro.compat import axis_size, shard_map


def _bulk(xl, wl, axis):
    return lax.psum(xl @ wl, axis)


def _fused_rows(xl, wl, axis, schedule, q, skew, wire):
    n = axis_size(axis)
    chunk = xl.shape[0] // (n * q)

    def partial(f):
        xi = lax.dynamic_slice_in_dim(xl, f * chunk, chunk, axis=0)
        return xi @ wl

    mine = ring_reduce_scatter_compute(partial, axis, schedule=schedule,
                                       chunks_per_rank=q, sub_axis=0,
                                       skew=skew, wire=wire)
    return all_gather_wire(mine, axis, n, axis=0, wire=wire)


def _fused_cols(xl, wl, axis, schedule, q, skew, wire):
    n = axis_size(axis)
    chunk = wl.shape[1] // (n * q)

    def partial(f):
        wi = lax.dynamic_slice_in_dim(wl, f * chunk, chunk, axis=1)
        return xl @ wi

    mine = ring_reduce_scatter_compute(partial, axis, schedule=schedule,
                                       chunks_per_rank=q, sub_axis=1,
                                       skew=skew, wire=wire)
    return all_gather_wire(mine, axis, n, axis=1, wire=wire)


def matmul_allreduce(
    ctx: ParallelContext,
    x,
    w,
    *,
    mode: str | None = None,
    schedule: str | None = None,
    chunks_per_rank: int | str | None = None,
    skew: int | None = None,
    wire: str | None = None,
):
    """y = AllReduce_tp(x @ w) for row-parallel ``w``.

    x: [..., K] global, K sharded over tp.   w: [K, N] global, row-sharded.
    Returns [..., N] replicated over tp (sharded over dp on leading dims).

    ``chunks_per_rank``: sub-chunk granularity of the fused ring (int or
    "auto"); ``None`` uses ``ctx.fusion.granularity``.  ``skew``: measured
    straggler rotation (Fig. 14); ``None`` uses ``ctx.fusion.skew``.
    ``wire``: ring-payload wire dtype ("f32"/"bf16"/"fp8"/"auto" — the RS
    carry and the phase-2 AG both compress; local accumulation stays f32);
    ``None`` uses ``ctx.fusion.wire``.
    """
    mode = mode or ctx.fusion.resolve("matmul_rs")
    mode = degrade_mode("matmul_allreduce", x.shape[:-1] + w.shape, mode)
    schedule = schedule or ctx.fusion.schedule
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis = ctx.tp_axis
    n = ctx.tp

    lead = x.shape[:-1]
    k, nout = w.shape
    xf = x.reshape((-1, x.shape[-1]))
    rows = xf.shape[0]
    # batch=1 decode shapes cannot shard rows over dp -> replicate there
    dp = ctx.batch_axes if rows % ctx.dp == 0 else None
    rows_local = rows // (ctx.dp if dp is not None else 1)
    use_rows = rows_local % n == 0 and rows_local >= n and mode != "bulk"

    if mode == "kernel":
        # Device-initiated Pallas path (scale-up zero-copy); the kernel is
        # registered lazily to avoid import cycles.
        from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce_kernel_available

        if not fused_matmul_allreduce_kernel_available(ctx.mesh):
            mode = "fused"

    chunk_dim = rows_local if use_rows else nout
    if mode in ("fused", "kernel"):
        dec = resolve_overlap(
            chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
            lambda fq, w: tune_matmul_allreduce(
                rows_local, k // n, nout, dtype_bytes=x.dtype.itemsize,
                n_dev=n, chunk_dim=chunk_dim, hw=ctx.hw, axis=axis,
                skew=skew, wire=w, fixed_q=fq),
            dim=chunk_dim, ring=n)
        q, wire_dt = dec.q, dec.wire
        if mode == "kernel":
            q = 1  # the kernel's granularity is its own tile pipeline
    else:
        q, wire_dt = 1, "f32"  # bulk: one collective at compute dtype

    def local_fn(xl, wl):
        if mode == "bulk":
            return _bulk(xl, wl, axis)
        if mode == "kernel":
            from repro.kernels.fused_gemv_allreduce.ops import fused_matmul_allreduce_shard

            return fused_matmul_allreduce_shard(xl, wl, axis, wire=wire_dt)
        if use_rows:
            return _fused_rows(xl, wl, axis, schedule, q, skew, wire_dt)
        return _fused_cols(xl, wl, axis, schedule, q, skew, wire_dt)

    yf = shard_map(
        local_fn,
        mesh=ctx.mesh,
        in_specs=(P(dp, ctx.tp_axis), P(ctx.tp_axis, None)),
        out_specs=P(dp, None),
        check_vma=False,
    )(xf, w)
    return yf.reshape(lead + (nout,))
