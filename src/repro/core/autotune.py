"""Shape-keyed overlap-granularity autotuner (paper Fig. 13).

The paper's central observation is that overlap quality is governed by
slice granularity: finer slices hide more wire time until per-slice
overhead wins, and the sweet spot is workload-dependent.  This module
picks the ``chunks_per_rank`` sub-chunk factor for the XLA-level fused
combinators and the output-tile size for the Pallas pipelined kernels,
using the promoted alpha-beta model (:mod:`repro.core.perfmodel`), with
an optional measured-sweep refinement.

Choices are memoized under a shape key so steady-state serve/train loops
pay the (cheap) model sweep once per distinct workload shape.  Setting
``FusionConfig.granularity = "auto"`` routes every fused op through
:func:`choose_chunks_per_rank`; an integer pins the knob globally.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Mapping, Sequence

from typing import NamedTuple

from repro.core.collectives import (WIRE_SETTINGS, feasible_chunks_per_rank,
                                    wire_itemsize)
from repro.core.perfmodel import (V5E, HardwareModel, MeshHardwareModel,
                                  model_fused, resolve_hw)

MAX_CHUNKS_PER_RANK = 16

# A narrower wire dtype must beat the current pick's modeled time by this
# relative margin to be adopted: compression only pays where wire time is
# actually exposed, and exactness wins ties (a fast axis with the wire
# fully hidden keeps "f32").
WIRE_MARGIN = 0.02


class Decision(NamedTuple):
    """One memoized overlap decision: the sub-chunk factor and the wire
    dtype the payload travels at (``"f32"`` = uncompressed)."""

    q: int
    wire: str = "f32"


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """Cache key: op family + every fact that moves the decision — shape,
    dtype, world size, the divisibility constraint, the (per-axis)
    hardware model, the measured skew bucket, and the wire *request*
    (two call sites that differ in any of these must not share a cached
    decision).  The alpha-beta model is skew-oblivious, but a *measured*
    decision is not: a straggler-rotated schedule overlaps differently,
    so calibrated entries must be keyed by the bucket they were measured
    under.  ``wire`` is the caller's request ("f32"/"bf16"/"fp8"/"auto");
    the resolved dtype lives in the cached :class:`Decision`, so a pinned
    and an "auto" call site never collide.  ``fixed_q`` is the pinned
    granularity under a wire-only sweep (``--granularity N --wire auto``)
    — part of the key for the same reason: a decision made under one pin
    must not answer for another pin or for the free sweep."""

    op: str
    shape: tuple
    dtype_bytes: int
    n_dev: int
    divisor_of: int | None
    divisor_ring: int
    hw: "HardwareModel"
    skew: int = 0
    wire: str = "f32"
    fixed_q: int | None = None


_GRANULARITY_CACHE: dict[TuneKey, Decision] = {}


def cache_info() -> Mapping[TuneKey, Decision]:
    """Read-only view of the memoized decisions (tests/diagnostics)."""
    return dict(_GRANULARITY_CACHE)


def clear_cache() -> None:
    _GRANULARITY_CACHE.clear()


def set_decision(key: TuneKey, dec: "Decision | int") -> None:
    """Overwrite one memoized decision — the measured-calibration pass
    replaces model choices with measured winners through this (and only
    this) door, so the overwrite is greppable and testable."""
    _GRANULARITY_CACHE[key] = _as_decision(dec)


def _as_decision(dec) -> Decision:
    if isinstance(dec, Decision):
        return dec
    if isinstance(dec, (tuple, list)):
        return Decision(int(dec[0]), str(dec[1]))
    return Decision(int(dec), "f32")


def wire_candidates(request: str, hw: HardwareModel) -> list[str]:
    """Wire dtypes the model may choose from, widest first.  A concrete
    request pins the choice (an explicit ``fp8`` is honored even off
    fp8-capable links — the caller's call); ``"auto"`` considers fp8 only
    where the link model declares support."""
    if request == "auto":
        return ["f32", "bf16"] + (["fp8"] if hw.fp8_wire else [])
    if request not in WIRE_SETTINGS:
        raise ValueError(f"unknown wire setting {request!r}; expected one "
                         f"of {WIRE_SETTINGS}")
    return [request]


def calibration_candidates(key: TuneKey,
                           max_q: int = MAX_CHUNKS_PER_RANK) -> list[Decision]:
    """Feasible ``(chunks_per_rank, wire)`` candidates for one cached key
    — the same (divisor ladder x wire dtypes) the model sweep scored, for
    the measured sweep to re-score on real hardware."""
    qs = ([int(key.fixed_q)] if key.fixed_q is not None
          else _divisor_candidates(key.divisor_of, key.divisor_ring, max_q))
    return [Decision(q, w) for w in wire_candidates(key.wire, key.hw)
            for q in qs]


# ---------------------------------------------------------------------------
# cache persistence (serve warm-up calibration across processes)
# ---------------------------------------------------------------------------
def _key_to_json(key: TuneKey) -> dict:
    d = dataclasses.asdict(key)
    d["hw"] = dataclasses.asdict(key.hw)
    d["shape"] = list(key.shape)
    return d


def _key_from_json(d: Mapping) -> TuneKey:
    d = dict(d)
    # tolerate both directions of hw-schema drift: a legacy flat dict may
    # lack fields added since (defaults fill in) and a foreign cache may
    # carry fields this build does not know (dropped)
    known = {f.name for f in dataclasses.fields(HardwareModel)}
    d["hw"] = HardwareModel(**{k: v for k, v in d["hw"].items()
                               if k in known})
    d["shape"] = tuple(d["shape"])
    d.setdefault("skew", 0)  # caches written before the skew field existed
    d.setdefault("wire", "f32")  # ... and before the wire field
    d.setdefault("fixed_q", None)  # ... and before the pinned-q field
    return TuneKey(**d)


def save_cache(path: str) -> int:
    """Serialize every memoized decision to ``path`` (JSON).  Returns the
    number of entries written.  The full ``TuneKey`` — including the
    hardware-model constants — is recorded, so a reloaded cache can never
    serve a decision made under different assumptions.  The write is
    atomic (temp file + rename) so a killed process never leaves a
    truncated cache behind."""
    import os

    entries = [{"key": _key_to_json(k), "chunks_per_rank": dec.q,
                "wire": dec.wire}
               for k, dec in _GRANULARITY_CACHE.items()]
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2,
                  sort_keys=True)
    os.replace(tmp, path)
    return len(entries)


def load_cache(path: str, *, merge: bool = True) -> int:
    """Load decisions serialized by :func:`save_cache` into the in-process
    cache (``merge=False`` replaces it).  Returns the number of entries
    loaded.  Existing in-process entries win on key collision — a live
    measurement beats a stale file."""
    with open(path) as f:
        blob = json.load(f)
    if not merge:
        _GRANULARITY_CACHE.clear()
    n = 0
    for e in blob["entries"]:
        key = _key_from_json(e["key"])
        if key not in _GRANULARITY_CACHE:
            # entries serialized before the wire field default to the
            # uncompressed wire (the pre-wire behavior)
            _GRANULARITY_CACHE[key] = Decision(int(e["chunks_per_rank"]),
                                               str(e.get("wire", "f32")))
            n += 1
    return n


def _divisor_candidates(divisor_of: int | None, ring: int,
                        max_q: int) -> list[int]:
    """Power-of-two sub-chunk factors q whose fine split divides the
    chunked dimension.  ``ring`` is the factor the dimension must absorb
    *besides* q: the ring world size for reduce-scatter-style chunking
    (fine chunks = ring * q of one dim), 1 for the A2A family (the
    payload is per-destination already; only q | divisor_of matters)."""
    qs = []
    q = 1
    while q <= max_q:
        if divisor_of is None or divisor_of % (ring * q) == 0:
            qs.append(q)
        q *= 2
    return qs or [1]


def choose_overlap(
    op: str,
    *,
    shape: Sequence[int],
    dtype_bytes: int,
    n_dev: int,
    flops: float,
    hbm_bytes: float,
    wire_bytes: float,
    divisor_of: int | None = None,
    divisor_ring: int | None = None,
    max_q: int = MAX_CHUNKS_PER_RANK,
    hw: HardwareModel | MeshHardwareModel = V5E,
    axis=None,
    skew: int = 0,
    wire: str = "f32",
    fixed_q: int | None = None,
    allow_fp8: bool = True,
) -> Decision:
    """Pick ``(chunks_per_rank, wire_dtype)`` minimizing the modeled fused
    time, jointly per (op, mesh axis).

    ``divisor_of`` constrains q candidates to factors that evenly split
    the chunked dimension (``None`` = unconstrained); ``divisor_ring`` is
    the ring factor that dimension must additionally absorb (defaults to
    ``n_dev`` — the reduce-scatter convention; pass 1 for per-destination
    payloads).  ``hw`` may be a flat :class:`HardwareModel` or a
    hierarchical :class:`MeshHardwareModel` resolved for ``axis`` — the
    per-axis link constants are what make a slow DCN axis pick a narrow
    wire while the fast ICI axis keeps f32.  ``wire`` is the request:
    a concrete dtype pins the choice, ``"auto"`` sweeps the candidates
    the link model supports, widest first, adopting a narrower dtype only
    when it improves modeled time by :data:`WIRE_MARGIN` (compression
    must pay; exactness wins ties).  ``fixed_q`` pins the granularity
    (a pinned ``--granularity`` with ``--wire auto``).  ``skew`` is the
    measured schedule rotation the caller is running under — it does not
    move the alpha-beta model, but keys the decision so a later measured
    sweep can record per-bucket winners.  ``allow_fp8=False`` clamps fp8
    candidates (including an explicit ``wire="fp8"`` request) to bf16 —
    the device-initiated kernels have no per-chunk-scale path, and the
    clamp must be recorded in the cached :class:`Decision` so the cache
    never promises a wire the kernel cannot ship.  The decision is
    memoized under the full constraint key.
    """
    hw = resolve_hw(hw, axis)
    ring = n_dev if divisor_ring is None else divisor_ring
    key = TuneKey(op, tuple(int(s) for s in shape), int(dtype_bytes),
                  int(n_dev), None if divisor_of is None else int(divisor_of),
                  int(ring), hw, int(skew), str(wire),
                  None if fixed_q is None else int(fixed_q))
    hit = _GRANULARITY_CACHE.get(key)
    if hit is not None:
        return hit
    qs = ([int(fixed_q)] if fixed_q is not None
          else _divisor_candidates(divisor_of, ring, max_q))
    cands = wire_candidates(wire, hw)
    if not allow_fp8:
        cands = list(dict.fromkeys("bf16" if w == "fp8" else w
                                   for w in cands))
    best: Decision | None = None
    best_t = float("inf")
    for w in cands:
        factor = wire_itemsize(w, dtype_bytes) / float(dtype_bytes)
        w_best_q, w_best_t = qs[0], float("inf")
        for q in qs:
            t = model_fused(flops, hbm_bytes, wire_bytes * factor,
                            n_dev * q, hw=hw)
            if t < w_best_t:
                w_best_q, w_best_t = q, t
        if best is None or w_best_t < best_t * (1.0 - WIRE_MARGIN):
            best, best_t = Decision(w_best_q, w), w_best_t
    _GRANULARITY_CACHE[key] = best
    return best


def choose_chunks_per_rank(op: str, **kwargs) -> int:
    """Granularity-only convenience over :func:`choose_overlap` (the
    pre-wire entry point; decisions share the same cache)."""
    return choose_overlap(op, **kwargs).q


def tune_matmul_allreduce(rows: int, k_local: int, n_out: int, *,
                          dtype_bytes: int, n_dev: int, chunk_dim: int,
                          divisor_ring: int | None = None,
                          allgather_phase: bool = True,
                          hw: HardwareModel | MeshHardwareModel = V5E,
                          axis=None, skew: int = 0, wire: str = "f32",
                          fixed_q: int | None = None) -> Decision:
    """Granularity for the row-parallel GEMM/GEMV + AllReduce family.

    ``chunk_dim`` is the dimension being ring-chunked (rows or output
    columns); the ring carries ``rows * n_out / n_dev`` elements per hop.
    ``divisor_ring`` defaults to ``n_dev`` (chunk_dim splits into
    ``n_dev * q`` fine chunks).  ``allgather_phase=False`` models a bare
    reduce-scatter (``matmul_reducescatter`` — no phase-2 all-gather, so
    half the wire traffic).
    """
    flops = 2.0 * rows * k_local * n_out
    hbm = float(k_local * n_out * dtype_bytes)
    # RS carry, plus the final AG for the full AllReduce form
    wire_b = float(rows * n_out * dtype_bytes) * (2.0 if allgather_phase
                                                  else 1.0)
    return choose_overlap(
        "matmul_allreduce" if allgather_phase else "matmul_reducescatter",
        shape=(rows, k_local, n_out),
        dtype_bytes=dtype_bytes, n_dev=n_dev, flops=flops, hbm_bytes=hbm,
        wire_bytes=wire_b, divisor_of=chunk_dim, divisor_ring=divisor_ring,
        hw=hw, axis=axis, skew=skew, wire=wire, fixed_q=fixed_q)


def tune_allgather_matmul(b: int, s_loc: int, k: int, n_out_local: int, *,
                          dtype_bytes: int, n_dev: int,
                          hw: HardwareModel | MeshHardwareModel = V5E,
                          axis=None, skew: int = 0, wire: str = "f32",
                          fixed_q: int | None = None) -> Decision:
    """Granularity for the AllGather x matmul family.

    Unlike the reduce-scatter ring (which carries *output* chunks), the
    all-gather ring forwards the *input* sequence chunk ``[b, s_loc, k]``
    — each arriving (sub-)chunk is consumed by a GEMM against the
    column-sharded weights.  Only ``q | s_loc`` constrains the split.
    """
    flops = 2.0 * b * s_loc * n_dev * k * n_out_local
    hbm = float(k * n_out_local * dtype_bytes)
    wire_b = float(b * s_loc * k * dtype_bytes) * (n_dev - 1)
    return choose_overlap(
        "allgather_matmul", shape=(b, s_loc, k, n_out_local),
        dtype_bytes=dtype_bytes, n_dev=n_dev, flops=flops, hbm_bytes=hbm,
        wire_bytes=wire_b, divisor_of=s_loc, divisor_ring=1, hw=hw,
        axis=axis, skew=skew, wire=wire, fixed_q=fixed_q)


def tune_all_to_all(chunk_elems: int, flops_per_dest: float, *,
                    dtype_bytes: int, n_dev: int, sub_dim: int,
                    hw: HardwareModel | MeshHardwareModel = V5E,
                    axis=None, skew: int = 0, wire: str = "f32",
                    fixed_q: int | None = None,
                    kernel: bool = False) -> Decision:
    """Granularity for the direct-send compute + All-to-All family.

    The payload is per-destination already, so only ``q | sub_dim``
    constrains the sub split (``divisor_ring=1``).  ``kernel=True``
    tunes the device-initiated Pallas path under its own ``TuneKey`` op
    (``"all_to_all_kernel"``): the decision space differs — the kernel
    stages PUT payloads without a per-chunk-scale path, so fp8
    candidates are clamped to bf16 and the clamp is recorded in the
    cached :class:`Decision`."""
    wire_b = float(chunk_elems * dtype_bytes) * (n_dev - 1)
    return choose_overlap(
        "all_to_all_kernel" if kernel else "all_to_all",
        shape=(chunk_elems, int(flops_per_dest)),
        dtype_bytes=dtype_bytes, n_dev=n_dev,
        flops=flops_per_dest * n_dev,
        hbm_bytes=float(chunk_elems * dtype_bytes * n_dev),
        wire_bytes=wire_b, divisor_of=sub_dim, divisor_ring=1, hw=hw,
        axis=axis, skew=skew, wire=wire, fixed_q=fixed_q,
        allow_fp8=not kernel)


def tune_ring_attention(b: int, s_loc: int, n_heads: int, n_kv_heads: int,
                        head_dim: int, *, dtype_bytes: int, n_dev: int,
                        hops: int | None = None,
                        hw: HardwareModel | MeshHardwareModel = V5E,
                        axis=None, skew: int = 0, wire: str = "f32",
                        fixed_q: int | None = None) -> Decision:
    """Granularity for the ring-attention KV ring (fused AG x attention).

    The ring forwards the local ``[b, s_loc, Hkv, hd]`` K and V chunks;
    each arriving (sub-)chunk is flash-consumed against the resident
    queries, with online-softmax stats merged per sub-chunk.  The payload
    is the local KV chunk, so only ``q | s_loc`` constrains the split
    (``divisor_ring=1``).  ``hops`` bounds the ring for sliding-window
    layers (default full ring ``n_dev - 1``).
    """
    hops = n_dev - 1 if hops is None else hops
    # per-rank flops: qk + pv over the visited context
    ctx_len = s_loc * (hops + 1)
    flops = 4.0 * b * s_loc * ctx_len * n_heads * head_dim
    kv_chunk = float(b * s_loc * n_kv_heads * head_dim * dtype_bytes)
    # hops moves flops AND wire (sliding-window layers bound the ring), so
    # it must be part of the cache key — same shapes, different ratios
    return choose_overlap(
        "ring_attention",
        shape=(b, s_loc, n_heads, n_kv_heads, head_dim, hops),
        dtype_bytes=dtype_bytes, n_dev=n_dev, flops=flops,
        hbm_bytes=2.0 * kv_chunk * (hops + 1),
        wire_bytes=2.0 * kv_chunk * hops,
        divisor_of=s_loc, divisor_ring=1, hw=hw, axis=axis, skew=skew,
        wire=wire, fixed_q=fixed_q)


def tune_ce_ring(b: int, s_loc: int, d_model: int, v_loc: int, *,
                 dtype_bytes: int, n_dev: int,
                 hw: HardwareModel | MeshHardwareModel = V5E,
                 axis=None, skew: int = 0, wire: str = "f32",
                 fixed_q: int | None = None) -> Decision:
    """Granularity for the vocab-sharded cross-entropy ring.

    The forward stats ring forwards the local ``[b, s_loc, D]`` activation
    chunk (each arriving sub-chunk is reduced to per-token softmax stats
    against the local vocab slice); the backward dx ring replays it with a
    same-shaped dx accumulator riding along — so the payload to tune is
    the activation chunk either way.  Only ``q | s_loc`` constrains the
    split (``divisor_ring=1``).
    """
    flops = 2.0 * b * s_loc * n_dev * d_model * v_loc
    x_chunk = float(b * s_loc * d_model * dtype_bytes)
    return choose_overlap(
        "ce_ring", shape=(b, s_loc, d_model, v_loc),
        dtype_bytes=dtype_bytes, n_dev=n_dev, flops=flops,
        hbm_bytes=float(v_loc * d_model * dtype_bytes),
        wire_bytes=x_chunk * (n_dev - 1),
        divisor_of=s_loc, divisor_ring=1, hw=hw, axis=axis, skew=skew,
        wire=wire, fixed_q=fixed_q)


# ---------------------------------------------------------------------------
# Pallas kernel tile selection
# ---------------------------------------------------------------------------
def choose_tile_n(b: int, k_local: int, n_total: int, *, n_dev: int,
                  dtype_bytes: int, vmem_budget_bytes: int = 8 << 20,
                  lane: int = 128) -> int:
    """Output-tile width for the pipelined fused GEMV/GEMM kernels.

    Mirrors the kernel's actual scratch allocation: two ``[K, tile]``
    weight panels (double buffer), the remote-tile tx staging
    (``(n_dev-1) * b * bn`` — independent of the tile width), the
    per-source rx slots (``n_dev * b * bn``), and the f32 accumulator.
    The tile must divide the per-rank output chunk ``n_total // n_dev``.
    Prefer the largest lane-aligned divisor that fits the VMEM budget,
    then the largest fitting divisor; if the tile-independent buffers
    alone bust the budget, the smallest divisor (cheapest weight panels)
    is the best that can be done.
    """
    bn = n_total // n_dev

    def working_set(tile: int) -> int:
        weights = 2 * k_local * tile * dtype_bytes
        x_block = b * k_local * dtype_bytes       # whole-x VMEM input block
        out_block = b * n_total * dtype_bytes     # whole-N VMEM output block
        tx = (n_dev - 1) * b * bn * dtype_bytes
        rx = n_dev * b * bn * dtype_bytes
        acc = b * bn * 4                          # f32 accumulator
        return weights + x_block + out_block + tx + rx + acc

    divisors = [t for t in range(1, bn + 1) if bn % t == 0]
    aligned = [t for t in divisors if t % lane == 0]
    for pool in (aligned, divisors):
        fitting = [t for t in pool if working_set(t) <= vmem_budget_bytes]
        if fitting:
            return max(fitting)
    return 1


def choose_tile_k(b: int, k: int, n_total: int, tile_n: int, *, n_dev: int,
                  dtype_bytes: int, vmem_budget_bytes: int = 8 << 20,
                  sublane: int = 8) -> int:
    """Contraction-panel depth for the K-streamed pipelined kernels.

    Given a chosen ``tile_n``, picks the largest ``tile_k`` such that two
    ``[tile_k, tile_n]`` weight panels plus the tile-independent buffers
    (whole-x input block, whole-N output block, tx/rx staging, f32
    accumulators) fit the VMEM budget.  ``tile_k`` need not divide ``K``
    — the kernel handles a ragged final panel — but is rounded down to a
    sublane multiple when possible so DMA rows stay aligned.
    """
    bn = n_total // n_dev
    fixed = (b * k * dtype_bytes            # whole-x VMEM input block
             + b * n_total * dtype_bytes    # whole-N VMEM output block
             + (n_dev - 1) * b * bn * dtype_bytes   # tx staging
             + n_dev * b * bn * dtype_bytes         # rx slots
             + b * bn * 4                   # reduction accumulator
             + b * tile_n * 4)              # K-panel accumulator
    per_row = 2 * tile_n * dtype_bytes      # double-buffered panel row
    tk = (vmem_budget_bytes - fixed) // per_row if per_row else k
    tk = max(1, min(int(tk), k))
    if tk >= sublane and tk != k:
        # align streamed panels, but never round a full-depth panel down
        # into an unnecessary ragged tail
        tk -= tk % sublane
    return tk


def feasible_tile(dim: int, requested: int) -> int:
    """Largest tile <= ``requested`` that divides ``dim`` (uniform tiles
    keep the DMA-semaphore byte accounting exact)."""
    t = max(1, min(int(requested), dim))
    while dim % t:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# Optional measured refinement
# ---------------------------------------------------------------------------
def measured_best(build_fn: Callable, candidates: Sequence, *,
                  iters: int = 5, warmup: int = 2,
                  fallback=None) -> tuple:
    """Time ``build_fn(cand)()`` for each candidate (an int q or a
    :class:`Decision`); return (best, times).

    ``build_fn`` returns a zero-arg jitted closure for one granularity;
    blocking is the caller's responsibility inside the closure (return a
    jax array — it is block_until_ready'd here).

    A candidate that raises (OOM at a too-fine granularity, a mesh the
    shape cannot split over, ...) is excluded from the sweep rather than
    aborting it.  If *every* candidate raises, the model decision passed
    as ``fallback`` is returned (with an empty times dict) so the caller
    degrades to the alpha-beta choice instead of crashing the warm-up
    pass; with no fallback the last error propagates.
    """
    import jax

    times: dict = {}
    err: Exception | None = None
    for q in candidates:
        try:
            fn = build_fn(q)
            for _ in range(warmup):
                jax.block_until_ready(fn())
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            times[q] = (time.perf_counter() - t0) / iters
        except Exception as e:  # noqa: BLE001 — sweep must survive any build
            err = e
    if not times:
        if fallback is not None:
            return fallback, times
        raise err if err is not None else ValueError("no candidates")
    best = min(times, key=times.get)
    return best, times


def parse_granularity(value: str):
    """CLI-facing parser: ``"auto"`` or a positive int (argparse ``type=``;
    raises ValueError with the knob's contract in the message)."""
    if value == "auto":
        return value
    try:
        q = int(value)
    except ValueError:
        raise ValueError(f"granularity must be an int >= 1 or 'auto', "
                         f"got {value!r}") from None
    if q < 1:
        raise ValueError(f"granularity must be >= 1 or 'auto', got {q}")
    return q


def add_granularity_cli_args(ap) -> None:
    """Install the shared ``--granularity`` / ``--wire`` / ``--tune-cache``
    flags on an argparse parser (one definition for every launcher)."""
    ap.add_argument("--granularity", default=1, type=parse_granularity,
                    help="chunks_per_rank sub-chunk factor for every fused "
                         "ring (matmul/MoE/embedding collectives, the "
                         "KV-ring attention and the CE-loss ring): an int "
                         ">= 1, or 'auto' for the shape-keyed alpha-beta "
                         "autotuner (paper Fig. 13)")
    ap.add_argument("--wire", default="f32",
                    choices=["f32", "bf16", "fp8", "auto"],
                    help="wire dtype of every ring/A2A payload: f32 keeps "
                         "the compute dtype on the wire (exact), bf16/fp8 "
                         "compress the payload on the send side while all "
                         "local accumulation stays f32 (fp8 ships a "
                         "per-chunk max-abs scale alongside), and 'auto' "
                         "lets the per-mesh-axis hardware model choose — "
                         "narrow wire on a slow DCN axis, exact f32 where "
                         "the wire hides behind compute")
    ap.add_argument("--tune-cache", default=None,
                    help="path to a persisted autotune cache: loaded (if "
                         "present) at startup, saved on exit — 'auto' "
                         "decisions then survive across processes")


def load_cache_if_exists(path: str | None) -> int:
    """Launcher-side cache preload: a missing/unset path is not an error
    (first run simply starts cold), and neither is a corrupt file — a
    half-written cache from a killed process degrades to a cold start
    instead of wedging every subsequent launch.  Returns entries loaded."""
    import os

    if path and os.path.exists(path):
        try:
            return load_cache(path)
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            return 0
    return 0


def resolve_granularity(granularity, pick: Callable[[], int]) -> int:
    """Map a ``FusionConfig.granularity`` setting to a concrete
    ``chunks_per_rank``: integers pass through, ``"auto"`` defers to the
    supplied shape-aware chooser."""
    if granularity == "auto":
        return pick()
    q = int(granularity)
    if q < 1:
        raise ValueError(f"granularity must be >= 1 or 'auto', got {granularity!r}")
    return q


def resolve_chunks_per_rank(override, config_granularity,
                            pick: Callable[[], int], *, dim: int,
                            ring: int) -> int:
    """One-stop resolution shared by every fused-op call site.

    An explicit per-call ``override`` beats ``config_granularity``
    (``FusionConfig.granularity``); ``"auto"`` defers to the shape-aware
    ``pick``; the result is clamped so ``dim`` splits evenly into
    ``ring * q`` fine chunks (``ring`` = the ring world for
    reduce-scatter-style chunking, 1 for per-destination payloads).
    """
    gran = config_granularity if override is None else override
    return feasible_chunks_per_rank(dim, ring,
                                    resolve_granularity(gran, pick))


def resolve_overlap(override_q, config_q, override_wire, config_wire,
                    pick: Callable, *, dim: int, ring: int) -> Decision:
    """Joint ``(chunks_per_rank, wire)`` resolution shared by every
    fused-op call site.

    Explicit per-call overrides beat the ``FusionConfig`` settings; when
    either knob is ``"auto"`` the shape-aware ``pick(fixed_q, wire_req)``
    runs the model sweep (``fixed_q`` pins a concrete granularity while
    the wire is still auto-chosen, and vice versa).  The granularity is
    clamped so ``dim`` splits evenly into ``ring * q`` fine chunks.
    """
    gran = config_q if override_q is None else override_q
    wire = config_wire if override_wire is None else override_wire
    if wire not in WIRE_SETTINGS:
        raise ValueError(f"wire must be one of {WIRE_SETTINGS}, "
                         f"got {wire!r}")
    if gran == "auto" or wire == "auto":
        fixed_q = None if gran == "auto" else int(gran)
        if fixed_q is not None and fixed_q < 1:
            raise ValueError(f"granularity must be >= 1 or 'auto', "
                             f"got {gran!r}")
        dec = _as_decision(pick(fixed_q, wire))
    else:
        q = int(gran)
        if q < 1:
            raise ValueError(f"granularity must be >= 1 or 'auto', "
                             f"got {gran!r}")
        dec = Decision(q, wire)
    return Decision(feasible_chunks_per_rank(dim, ring, dec.q), dec.wire)
