"""Measured-sweep calibration pass (serve/train warm-up).

The autotuner's alpha-beta model picks ``chunks_per_rank`` per
:class:`~repro.core.autotune.TuneKey` at trace time; this module is the
ROADMAP's "measured-sweep calibration pass": after a few warm-up steps
have populated the decision cache with the *hot* keys, each key's
workload is reconstructed from the key itself as an op-level
microbenchmark, every feasible candidate is timed with
:func:`~repro.core.autotune.measured_best`, and the model decision is
overwritten with the measured winner — so steady state runs on measured
choices, persisted across processes via the existing ``--tune-cache``.

The reconstruction is a *proxy*: operand values are random and the
surrounding model is absent, but shape, dtype, sharding, ring world and
schedule (including the skew bucket in the key) — everything the overlap
depends on — are exact.
"""
from __future__ import annotations

import logging
from typing import Callable, Iterable, Mapping

import numpy as np

from repro.core import autotune
from repro.core.autotune import TuneKey, calibration_candidates, measured_best
from repro.parallel.sharding import ParallelContext

log = logging.getLogger("repro.calibrate")


def _dtype(key: TuneKey):
    import jax.numpy as jnp

    return {2: jnp.bfloat16, 4: np.float32}.get(key.dtype_bytes, np.float32)


def _rng(key: TuneKey):
    return np.random.default_rng(abs(hash((key.op, key.shape))) % (2 ** 31))


# ---------------------------------------------------------------------------
# per-op-family microbench builders: (ctx, key) -> build_fn(q) -> closure
# ---------------------------------------------------------------------------
def _build_matmul_allreduce(ctx: ParallelContext, key: TuneKey):
    import jax

    from repro.core.matmul_allreduce import matmul_allreduce

    rows_local, k_local, n_out = key.shape
    dt = _dtype(key)
    rng = _rng(key)
    x = rng.standard_normal((rows_local * ctx.dp, k_local * ctx.tp)).astype(dt)
    w = rng.standard_normal((k_local * ctx.tp, n_out)).astype(dt)

    def build(dec):
        fn = jax.jit(lambda: matmul_allreduce(
            ctx, x, w, mode="fused", chunks_per_rank=dec.q, wire=dec.wire,
            skew=key.skew))
        return fn

    return build


def _build_matmul_reducescatter(ctx: ParallelContext, key: TuneKey):
    import jax

    from repro.core.allgather_matmul import matmul_reducescatter

    rows, k_local, n_out = key.shape
    s = key.divisor_of or rows
    b = max(rows // s, 1)
    dt = _dtype(key)
    rng = _rng(key)
    x = rng.standard_normal((b, s, k_local * ctx.tp)).astype(dt)
    w = rng.standard_normal((k_local * ctx.tp, n_out)).astype(dt)

    def build(dec):
        return jax.jit(lambda: matmul_reducescatter(
            ctx, x, w, mode="fused", chunks_per_rank=dec.q, wire=dec.wire,
            skew=key.skew))

    return build


def _build_allgather_matmul(ctx: ParallelContext, key: TuneKey):
    import jax

    from repro.core.allgather_matmul import allgather_matmul

    b, s_loc, k, n_out_local = key.shape
    dt = _dtype(key)
    rng = _rng(key)
    x = rng.standard_normal((b, s_loc * ctx.tp, k)).astype(dt)
    w = rng.standard_normal((k, n_out_local * ctx.tp)).astype(dt)

    def build(dec):
        return jax.jit(lambda: allgather_matmul(
            ctx, x, w, mode="fused", chunks_per_rank=dec.q, wire=dec.wire))

    return build


def _build_all_to_all(ctx: ParallelContext, key: TuneKey):
    """Raw direct-send A2A with the key's per-destination payload — the
    shared microbench for the MoE dispatch/combine and embedding families
    (the key records payload bytes, sub axis and per-destination flops,
    not the producing op).  The recorded compute is reproduced by a proxy
    GEMM contracting a synthetic ``k_eq`` dimension sized so each
    destination's produce costs ~``flops_per_dest`` — without it a
    compute-heavy family (the fused FFN+combine) would be re-scored on a
    compute-free wire microbench and measured_best would reward the wrong
    granularity."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.collectives import direct_all_to_all_compute
    from jax import lax

    chunk_elems = int(key.shape[0])
    flops_per_dest = float(key.shape[1])
    sub_dim = key.divisor_of or 1
    rows = max(chunk_elems // max(sub_dim, 1), 1)
    n = key.n_dev
    if n == ctx.tp:
        axes = ctx.tp_axis
        spec3 = P(ctx.tp_axis, None, None)
    elif n == ctx.world:
        axes = tuple(ctx.dp_axes) + (ctx.tp_axis,)
        spec3 = P(axes, None, None)
    else:
        raise ValueError(f"A2A key world {n} matches neither tp={ctx.tp} "
                         f"nor world={ctx.world}")
    dt = _dtype(key)
    rng = _rng(key)
    # 2 * sub_dim * k_eq * rows flops per destination ~= flops_per_dest
    k_eq = int(round(flops_per_dest / max(2.0 * sub_dim * rows, 1.0)))
    x = rng.standard_normal((n * n, sub_dim, max(k_eq, rows))).astype(dt)
    w_proxy = (rng.standard_normal((k_eq, rows)).astype(dt)
               if k_eq > 0 else None)

    def build(dec):
        q = dec.q

        def local_fn(xl, wl):
            # xl: [n, sub_dim, k_eq|rows] — one payload per destination
            sub = sub_dim // q

            def produce(f):
                dest, s = (f // q, f % q) if q > 1 else (f, 0)
                xb = lax.dynamic_index_in_dim(xl, dest, axis=0,
                                              keepdims=False)
                if q > 1:
                    xb = lax.dynamic_slice_in_dim(xb, s * sub, sub, axis=0)
                if wl is None:
                    return xb[:, :rows]
                return xb[:, :k_eq] @ wl  # the op's per-dest compute proxy

            return direct_all_to_all_compute(
                produce, jax.ShapeDtypeStruct((sub_dim, rows), xl.dtype),
                axes, chunks_per_rank=q, sub_axis=0, skew=key.skew,
                wire=dec.wire)

        return jax.jit(lambda: shard_map(
            lambda xl: local_fn(xl, None if w_proxy is None
                                else jnp.asarray(w_proxy)),
            mesh=ctx.mesh, in_specs=(spec3,), out_specs=spec3,
            check_vma=False)(jnp.asarray(x)))

    return build


def _build_ring_attention(ctx: ParallelContext, key: TuneKey):
    import jax

    from repro.models.attention import context_attention

    b_loc, s_loc, hq, hkv, hd, hops = key.shape
    n = ctx.tp
    window = None if hops >= n - 1 else hops * s_loc
    dt = _dtype(key)
    rng = _rng(key)
    B = b_loc * ctx.dp
    S = s_loc * n
    q_ = rng.standard_normal((B, S, hq, hd)).astype(dt)
    k_ = rng.standard_normal((B, S, hkv, hd)).astype(dt)
    v_ = rng.standard_normal((B, S, hkv, hd)).astype(dt)

    def build(dec):
        return jax.jit(lambda: context_attention(
            ctx, q_, k_, v_, causal=True, window=window, mode="fused",
            q_block=min(64, s_loc), kv_block=min(64, s_loc),
            chunks_per_rank=dec.q, wire=dec.wire, skew=key.skew))

    return build


def _build_ce_ring(ctx: ParallelContext, key: TuneKey):
    import jax

    from repro.core.loss import sharded_cross_entropy

    b_loc, s_loc, d_model, v_loc = key.shape
    n = ctx.tp
    dt = _dtype(key)
    rng = _rng(key)
    B = b_loc * ctx.dp
    S = s_loc * n
    V = v_loc * n
    x = rng.standard_normal((B, S, d_model)).astype(dt)
    e = rng.standard_normal((V, d_model)).astype(dt)
    y = rng.integers(0, V, (B, S)).astype(np.int32)

    def build(dec):
        return jax.jit(lambda: sharded_cross_entropy(
            ctx, x, e, y, chunks_per_rank=dec.q, wire=dec.wire,
            skew=key.skew))

    return build


_BUILDERS: Mapping[str, Callable] = {
    "matmul_allreduce": _build_matmul_allreduce,
    "matmul_reducescatter": _build_matmul_reducescatter,
    "allgather_matmul": _build_allgather_matmul,
    "all_to_all": _build_all_to_all,
    "ring_attention": _build_ring_attention,
    "ce_ring": _build_ce_ring,
}


def add_calibration_cli_args(ap) -> None:
    """Install the shared ``--calibrate`` warm-up flags on an argparse
    parser (one definition for both launchers)."""
    ap.add_argument("--calibrate", action="store_true",
                    help="measured-sweep warm-up: after tracing the step "
                         "once (which records the hot autotune keys), time "
                         "every feasible chunks_per_rank per key and "
                         "overwrite the model decisions with measured "
                         "winners before steady state (pair with "
                         "--granularity auto; persists via --tune-cache)")
    ap.add_argument("--calibrate-iters", type=int, default=3,
                    help="timing iterations per calibration candidate")


def warmup_and_calibrate(ctx: ParallelContext, trace_fn: Callable, *args,
                         iters: int = 3, max_q: int | None = None,
                         granularity=None) -> dict:
    """One-call launcher warm-up: abstractly evaluate ``trace_fn(*args)``
    — granularity decisions are made at Python trace time, so this
    populates the hot-key cache without running a step — then run the
    measured pass over those keys.  ``granularity`` is the launcher's
    CLI setting, only used to warn when it is pinned (the sweep can only
    drive ``"auto"`` decisions).

    Only keys *added by this trace* are swept: a preloaded ``--tune-cache``
    can hold entries from other workloads (and already-measured winners
    from prior warm-ups), and re-timing the whole file would make warm-up
    cost grow with cache age.  Clear the cache file to force a full
    re-calibration."""
    import jax

    if granularity is not None and granularity != "auto":
        print("calibrate: --granularity is pinned; the measured sweep "
              "only drives 'auto' decisions")
    before = set(autotune.cache_info())
    jax.eval_shape(trace_fn, *args)
    hot = [k for k in autotune.cache_info() if k not in before]
    rep = measured_calibration_pass(ctx, keys=hot, iters=iters, max_q=max_q)
    print(f"calibrate: {len(rep)}/{len(hot)} newly traced hot keys "
          f"re-scored by measurement")
    return rep


def measured_calibration_pass(
    ctx: ParallelContext,
    *,
    keys: Iterable[TuneKey] | None = None,
    iters: int = 3,
    warmup: int = 1,
    max_q: int | None = None,
) -> dict[TuneKey, dict]:
    """Re-score every hot TuneKey's candidate ladder by measurement and
    overwrite the cached decision with the winner.

    Candidates are joint ``(chunks_per_rank, wire)`` :class:`~repro.core.
    autotune.Decision` pairs — the measured sweep re-scores the wire
    dtype together with the granularity, so a cast whose overhead the
    alpha-beta model underestimates loses on real hardware.

    ``keys`` defaults to every currently cached decision (the keys the
    warm-up steps touched).  A key whose op family has no builder, whose
    world does not match the live mesh, or whose every candidate fails to
    build is left on its model decision (``measured_best``'s fallback).
    Returns a per-key report: ``{"model_q", "measured_q", "times"}``
    (Decision-valued).
    """
    report: dict[TuneKey, dict] = {}
    todo = list(keys) if keys is not None else list(autotune.cache_info())
    for key in todo:
        builder = _BUILDERS.get(key.op)
        model_q = autotune.cache_info().get(key)
        if builder is None or model_q is None:
            continue
        if key.n_dev not in (ctx.tp, ctx.world):
            log.info("calibrate: skipping %s (world %d not on this mesh)",
                     key.op, key.n_dev)
            continue
        cands = calibration_candidates(
            key, max_q if max_q is not None else autotune.MAX_CHUNKS_PER_RANK)
        try:
            build_fn = builder(ctx, key)
        except Exception as e:  # noqa: BLE001 — a bad rebuild must not kill warm-up
            log.info("calibrate: cannot rebuild %s: %s", key.op, e)
            continue
        best, times = measured_best(build_fn, cands, iters=iters,
                                    warmup=warmup, fallback=model_q)
        autotune.set_decision(key, best)
        report[key] = {"model_q": model_q, "measured_q": best,
                       "times": times}
        log.info("calibrate: %s%s model %s -> measured %s",
                 key.op, key.shape, tuple(model_q), tuple(best))
    return report
