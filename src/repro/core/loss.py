"""Vocab-sharded cross-entropy with fused AllGather x logits-matmul and an
analytic ring backward (custom VJP).

Forward: activations arrive sequence-sharded over tp, the embedding table
vocab-sharded.  The ring that gathers sequence chunks is fused with the
logits matmul AND the softmax statistics: each arriving chunk is reduced
to per-token (max, sumexp, label-logit) stats immediately — the full
[tokens, vocab] logits tensor never exists.

Backward: autodiff through the unrolled ring would keep every chunk's f32
logits alive simultaneously (~tokens*V_loc*4 bytes per rank).  The custom
VJP instead *recomputes* one chunk's logits at a time: the x-chunk ring is
replayed, and each chunk's dx accumulator travels around the ring *with*
its chunk, collecting every rank's vocab-slice contribution — after a
full loop it lands back on the owning rank fully reduced.  Peak backward
memory is one chunk's logits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import resolve_overlap, tune_ce_ring
from repro.core.collectives import (ring_permute, split_ring_payload,
                                    wire_cast, wire_uncast)
from repro.core.scheduling import sub_chunk_service_order
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map

NEG = -1e30


def _perm(n, shift=1):
    return [(j, (j + shift) % n) for j in range(n)]


def _cap_fwd(lg, cap):
    if not cap:
        return lg
    return jnp.tanh(lg / cap) * cap


def _cap_bwd(lg_raw, cap):
    """d capped / d raw."""
    if not cap:
        return 1.0
    t = jnp.tanh(lg_raw / cap)
    return 1.0 - t * t


def _make_local_ce(axis: str, n: int, dp, n_dp: int, seq_sharded: bool,
                   logit_softcap, n_world: int, n_sub: int = 1,
                   skew: int = 0, wire: str = "f32"):
    """Builds the per-rank CE with custom VJP (runs inside shard_map).

    ``n_sub`` (= ``chunks_per_rank``, paper Fig. 13) splits the ring
    payload — the local sequence chunk — into sub-chunks that ring
    independently: each arriving sub-chunk is reduced to its softmax
    stats (fwd) or its dx contribution (bwd) while the next sub-chunk's
    collective-permute is in flight.  ``skew`` (measured straggler
    rotation, Fig. 14) rotates the sub-ring service order within each
    hop; stats land in disjoint slots, so the forward is bit-identical
    under any skew.

    ``wire`` compresses the ring payloads: the forwarded x sub-chunks are
    cast once at their source (one rounding no matter how many hops), and
    the traveling dx accumulators are cast on every send while the local
    accumulation stays f32.  ``wire="f32"`` keeps the pre-wire graphs
    bit-identical (dx then travels in the operand dtype, as before)."""
    order = sub_chunk_service_order(n_sub, skew)
    compress = wire not in (None, "f32")

    @jax.custom_vjp
    def local_ce(xl, el, yl):
        loss, _ = _fwd(xl, el, yl)
        return loss

    def _stats_chunk(xc, yc, el, v_off, v_loc):
        lg = _cap_fwd((xc @ el.T).astype(jnp.float32), logit_softcap)
        m = lg.max(axis=-1)
        se = jnp.exp(lg - m[..., None]).sum(-1)
        rel = yc - v_off
        ok = (rel >= 0) & (rel < v_loc)
        picked = jnp.take_along_axis(
            lg, jnp.clip(rel, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        return m, se, jnp.where(ok, picked, 0.0)

    def _fwd(xl, el, yl):
        d = lax.axis_index(axis)
        v_loc = el.shape[0]
        v_off = d * v_loc
        b = xl.shape[0]

        if seq_sharded:
            s_loc = xl.shape[1]
            sub = s_loc // n_sub
            S = s_loc * n
            m_all = jnp.full((b, S), NEG, jnp.float32)
            se_all = jnp.zeros((b, S), jnp.float32)
            lab_all = jnp.zeros((b, S), jnp.float32)

            def place(buf, val, start):
                return lax.dynamic_update_slice_in_dim(buf, val, start,
                                                       axis=1)

            bufs = split_ring_payload(xl, n_sub)
            for i in range(n):
                src = (d - i) % n
                if i == 1:
                    # the ring payload rounds once at its source; every
                    # later hop forwards the compressed representation
                    bufs = [wire_cast(b, wire) if compress else b
                            for b in bufs]
                for j in (order if i > 0 else range(n_sub)):
                    if i > 0:
                        # forward sub-chunk j the moment sub-chunk j-1's
                        # stats reduction is issued (Fig. 13 granularity)
                        bufs[j] = ring_permute(bufs[j], axis, n)
                    start = src * s_loc + j * sub
                    yc = lax.dynamic_slice_in_dim(yl, start, sub, axis=1)
                    xc = (wire_uncast(bufs[j], xl.dtype) if i > 0 and compress
                          else bufs[j])
                    m, se, lab = _stats_chunk(xc, yc, el, v_off, v_loc)
                    m_all = place(m_all, m, start)
                    se_all = place(se_all, se, start)
                    lab_all = place(lab_all, lab, start)
        else:
            m_all, se_all, lab_all = _stats_chunk(xl, yl, el, v_off, v_loc)

        m_g = lax.pmax(m_all, axis)
        se_g = lax.psum(se_all * jnp.exp(m_all - m_g), axis)
        lab_g = lax.psum(lab_all, axis)
        nll = jnp.log(se_g) + m_g - lab_g
        loss = nll.mean()
        if dp is not None:
            loss = lax.pmean(loss, dp)
        return loss[None], (m_g, se_g)

    def fwd_rule(xl, el, yl):
        loss, (m_g, se_g) = _fwd(xl, el, yl)
        return loss, (xl, el, yl, m_g, se_g)

    def bwd_rule(res, g):
        xl, el, yl, m_g, se_g = res
        d = lax.axis_index(axis)
        v_loc = el.shape[0]
        v_off = d * v_loc
        b = xl.shape[0]
        s_loc = xl.shape[1]
        n_tok = b * s_loc * (n if seq_sharded else 1) * n_dp
        # check_vma=False splits a replicated output's cotangent evenly
        # across ranks; undo it (validated numerically in tests)
        gt = (g[0] * n_world / n_tok).astype(jnp.float32)

        def chunk_grads(xc, yc, mc, sec):
            """(d logits_raw) for one chunk vs my vocab slice -> dx, dEl.

            d logits = gt * (p - onehot(label)).  The onehot term is never
            materialized at [tokens, V]: its dx contribution is a row
            gather of el and its dEl contribution a small scatter-add —
            a [tokens, V] scatter would dominate backward memory."""
            raw = (xc @ el.T).astype(jnp.float32)
            lg = _cap_fwd(raw, logit_softcap)
            p = jnp.exp(lg - mc[..., None]) / sec[..., None]
            draw = (p * _cap_bwd(raw, logit_softcap) * gt).astype(xc.dtype)
            dxc = (draw @ el).astype(jnp.float32)               # [b,s,D]
            dEl = jnp.einsum("bsv,bsd->vd", draw,
                             xc.astype(draw.dtype)).astype(jnp.float32)
            # label (onehot) corrections
            rel = yc - v_off
            ok = (rel >= 0) & (rel < v_loc)
            clip = jnp.clip(rel, 0, v_loc - 1)
            if logit_softcap:
                raw_lab = jnp.take_along_axis(raw, clip[..., None], -1)[..., 0]
                cb_lab = _cap_bwd(raw_lab, logit_softcap)
            else:
                cb_lab = 1.0
            w_lab = jnp.where(ok, gt * cb_lab, 0.0)             # [b,s]
            dxc = dxc - w_lab[..., None] * jnp.take(el, clip, axis=0
                                                    ).astype(jnp.float32)
            dEl = dEl.at[clip.reshape(-1)].add(
                -(w_lab[..., None] * xc.astype(jnp.float32)
                  ).reshape(-1, xc.shape[-1]))
            return dxc, dEl

        if not seq_sharded:
            dxc, dEl = chunk_grads(xl, yl, m_g, se_g)
            return dxc.astype(xl.dtype), dEl.astype(el.dtype), None

        # ring replay: each sub-chunk's dx accumulator travels with its
        # sub-chunk.  Uncompressed wire: the accumulator rides in the
        # operand dtype (bf16 for bf16 models — halves ring bytes; f32
        # models keep f32 exactness).  Compressed wire: the accumulator
        # is cast on every send (per-chunk fp8 scale riding along) while
        # the local add stays f32, and the replayed x sub-chunks round
        # once at their source.
        sub = s_loc // n_sub
        dEl_acc = jnp.zeros(el.shape, jnp.float32)
        xbufs = split_ring_payload(xl, n_sub)
        dxbufs = []

        def sub_grads(j, src, xsub):
            start = src * s_loc + j * sub
            yc = lax.dynamic_slice_in_dim(yl, start, sub, axis=1)
            mc = lax.dynamic_slice_in_dim(m_g, start, sub, axis=1)
            sec = lax.dynamic_slice_in_dim(se_g, start, sub, axis=1)
            return chunk_grads(xsub, yc, mc, sec)

        for j in range(n_sub):
            dxc, dEl = sub_grads(j, d, xbufs[j])
            dxbufs.append(dxc if compress else dxc.astype(xl.dtype))
            dEl_acc += dEl
        if compress:
            xbufs = [wire_cast(b, wire) for b in xbufs]
        for i in range(1, n):
            src = (d - i) % n
            for j in order:
                xbufs[j] = ring_permute(xbufs[j], axis, n)
                if compress:
                    dxbufs[j] = wire_uncast(
                        ring_permute(wire_cast(dxbufs[j], wire), axis, n),
                        jnp.float32)
                    dxc, dEl = sub_grads(j, src,
                                         wire_uncast(xbufs[j], xl.dtype))
                    dxbufs[j] = dxbufs[j] + dxc
                else:
                    dxbufs[j] = ring_permute(dxbufs[j], axis, n)
                    dxc, dEl = sub_grads(j, src, xbufs[j])
                    dxbufs[j] = (dxbufs[j].astype(jnp.float32)
                                 + dxc).astype(xl.dtype)
                dEl_acc += dEl
        # one final hop returns each sub-chunk's accumulated dx home
        if compress:
            dxbufs = [wire_uncast(ring_permute(wire_cast(s, wire), axis, n),
                                  jnp.float32) for s in dxbufs]
        else:
            dxbufs = [ring_permute(s, axis, n) for s in dxbufs]
        dxl = dxbufs[0] if n_sub == 1 else jnp.concatenate(dxbufs, axis=1)
        return dxl.astype(xl.dtype), dEl_acc.astype(el.dtype), None

    local_ce.defvjp(fwd_rule, bwd_rule)
    return local_ce


def sharded_cross_entropy(
    ctx: ParallelContext,
    x,          # [B, S, D] global, S sharded over tp (or replicated if small)
    embed,      # [V, D] global, V sharded over tp
    labels,     # [B, S] int32 global
    *,
    mode: str | None = None,
    logit_softcap: float | None = None,
    chunks_per_rank: int | str | None = None,
    skew: int | None = None,
    wire: str | None = None,
):
    """Mean token cross-entropy; logits stay chunk-local in fwd AND bwd.

    ``chunks_per_rank`` sub-chunks the ring payload in the forward stats
    ring and the backward dx ring (paper Fig. 13); ``None`` defers to
    ``FusionConfig.granularity`` and ``"auto"`` to the shape-keyed
    alpha-beta tuner (:func:`tune_ce_ring`).  ``skew`` rotates the
    sub-ring service order by the measured straggler bucket (Fig. 14;
    ``None`` uses ``ctx.fusion.skew``).  ``wire`` compresses the fwd
    x-ring and the bwd traveling dx accumulators (f32 local accumulation;
    ``None`` uses ``ctx.fusion.wire``).
    """
    axis, n = ctx.tp_axis, ctx.tp
    skew = ctx.fusion.skew if skew is None else int(skew)
    B, S, D = x.shape
    V = embed.shape[0]
    dp = ctx.batch_axes if B % ctx.dp == 0 else None
    n_dp = ctx.dp if dp is not None else 1
    seq_sharded = S % n == 0 and S >= n

    n_sub, wire_dt = 1, "f32"
    if seq_sharded:
        s_loc = S // n
        b_loc = B // n_dp
        # the ring payload is the local sequence chunk: only q | s_loc
        # matters (the fwd stats ring and the bwd dx ring share the split)
        dec = resolve_overlap(
            chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
            lambda fq, wr: tune_ce_ring(b_loc, s_loc, D, V // n,
                                        dtype_bytes=x.dtype.itemsize,
                                        n_dev=n, hw=ctx.hw, axis=axis,
                                        skew=skew, wire=wr, fixed_q=fq),
            dim=s_loc, ring=1)
        n_sub, wire_dt = dec.q, dec.wire

    local_ce = _make_local_ce(axis, n, dp, n_dp, seq_sharded, logit_softcap,
                              ctx.mesh.size, n_sub=n_sub, skew=skew,
                              wire=wire_dt)

    x_spec = P(dp, axis, None) if seq_sharded else P(dp, None, None)
    loss = shard_map(
        local_ce, mesh=ctx.mesh,
        in_specs=(x_spec, P(axis, None), P(dp, None)),
        out_specs=P(None),
        check_vma=False,
    )(x, embed, labels)
    return loss.mean()
