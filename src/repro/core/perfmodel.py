"""Alpha-beta overlap model shared by serve/train consumers and benchmarks.

Promoted out of ``benchmarks/common.py`` so production code (the overlap
autotuner in :mod:`repro.core.autotune`, launch-time planning) can use the
same roofline constants and fused/bulk time models the paper figures are
projected with.

Terms:
  compute    = max(flops / peak_flops, hbm_bytes / hbm_bw)
  bulk       = compute + kernel-boundary sync + collective launch + wire
  fused      = first chunk's compute exposed, the remaining chunks' wire
               time hidden behind compute, the last chunk's wire exposed,
               plus a per-chunk issue overhead — the paper's Fig. 13 curve:
               finer slices hide more wire time until per-slice overhead
               wins.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Alpha-beta constants for one accelerator generation — or, under a
    :class:`MeshHardwareModel`, for one *mesh-axis link class* (the ICI
    ring inside a pod vs the DCN links between pods)."""

    peak_flops: float = 197e12   # bf16 MXU peak
    hbm_bw: float = 819e9        # HBM bytes/s
    ici_bw: float = 50e9         # per-link interconnect bytes/s
    ici_lat: float = 1e-6        # collective setup/launch latency (alpha)
    boundary: float = 2e-6       # kernel-boundary sync the fused form removes
    chunk_overhead: float = 2e-7  # per-chunk issue cost (device-initiated
    # comm is cheap — the paper's point; ROC_SHMEM API is ns-scale)
    fp8_wire: bool = False       # links + DMA engines accept fp8 payloads
    # (quantized collectives need both endpoints to agree; "auto" wire
    # selection only considers fp8 where the link model declares support)

    def compute_time(self, flops: float, hbm_bytes: float) -> float:
        """Roofline compute time: MXU- or HBM-bound, whichever binds."""
        return max(flops / self.peak_flops, hbm_bytes / self.hbm_bw)


V5E = HardwareModel()

# Pod-boundary data-center network: ~100 Gb/s per host and 10s-of-us
# latency — the slow axis of a multi-pod mesh.  Compute-side constants are
# the device's own (a DCN hop does not change the chip).
DCN = HardwareModel(ici_bw=12.5e9, ici_lat=25e-6)


@dataclasses.dataclass(frozen=True)
class MeshHardwareModel:
    """Per-mesh-axis hardware models (hierarchical alpha-beta).

    A multi-pod mesh is not one flat link class: the ``model``/``data``
    axes ride the intra-pod ICI while a ``pod`` axis crosses the DCN.
    ``axes`` maps axis names to their link model; anything unlisted uses
    ``default``.  Stored as a tuple of pairs so instances stay hashable
    (they ride inside ``TuneKey`` indirectly via the resolved per-axis
    :class:`HardwareModel`).
    """

    axes: tuple = ()                       # ((axis_name, HardwareModel), ...)
    default: HardwareModel = V5E

    @classmethod
    def uniform(cls, hw: HardwareModel = V5E) -> "MeshHardwareModel":
        return cls(axes=(), default=hw)

    @classmethod
    def from_mapping(cls, mapping: Mapping[str, HardwareModel],
                     default: HardwareModel = V5E) -> "MeshHardwareModel":
        return cls(axes=tuple(sorted(mapping.items())), default=default)

    @classmethod
    def for_mesh_axes(cls, axis_names: Sequence[str], *,
                      ici: HardwareModel = V5E,
                      dcn: HardwareModel = DCN) -> "MeshHardwareModel":
        """Convention used by the launchers: a ``pod`` axis crosses the
        DCN, every other axis rides the intra-pod ICI."""
        return cls(axes=tuple((a, dcn) for a in axis_names if a == "pod"),
                   default=ici)

    def axis(self, name: str | None) -> HardwareModel:
        for a, hw in self.axes:
            if a == name:
                return hw
        return self.default

    def for_axes(self, names) -> HardwareModel:
        """Bottleneck composition for a ring spanning several mesh axes
        (the flattened-world embedding A2A): the slowest link class the
        ring crosses governs its wire time, the largest latency its alpha,
        and fp8 is only available if *every* crossed link class takes it."""
        if names is None:
            return self.default
        if isinstance(names, str):
            return self.axis(names)
        hws = [self.axis(n) for n in names] or [self.default]
        slowest = min(hws, key=lambda h: h.ici_bw)
        return dataclasses.replace(
            slowest,
            ici_lat=max(h.ici_lat for h in hws),
            fp8_wire=all(h.fp8_wire for h in hws))


def resolve_hw(hw, axis=None) -> HardwareModel:
    """Accept either a flat :class:`HardwareModel` or a hierarchical
    :class:`MeshHardwareModel` (resolved for ``axis`` — a name, a tuple of
    names, or None for the default link class)."""
    if isinstance(hw, MeshHardwareModel):
        return hw.for_axes(axis)
    return hw


def model_bulk(flops, hbm_bytes, wire_bytes, *, bw=None,
               hw: HardwareModel | MeshHardwareModel = V5E, axis=None):
    """Bulk-synchronous: full compute kernel, boundary sync, collective."""
    hw = resolve_hw(hw, axis)
    bw = hw.ici_bw if bw is None else bw
    return (hw.compute_time(flops, hbm_bytes) + hw.boundary + hw.ici_lat
            + wire_bytes / bw)


def model_fused(flops, hbm_bytes, wire_bytes, chunks, *, bw=None,
                zero_copy_saving=0.0,
                hw: HardwareModel | MeshHardwareModel = V5E, axis=None):
    """Fused: chunk i's wire time hides behind chunks i+1..n's compute.

    total = first chunk compute + max(rest compute, rest wire) +
            last chunk wire + per-chunk issue overhead - zero-copy saving."""
    hw = resolve_hw(hw, axis)
    bw = hw.ici_bw if bw is None else bw
    c = hw.compute_time(flops, hbm_bytes)
    w = wire_bytes / bw + hw.ici_lat
    per_c, per_w = c / chunks, w / chunks
    overlapped = per_c + max(c - per_c, w - per_w) + per_w
    return max(overlapped + chunks * hw.chunk_overhead - zero_copy_saving, 0.0)


def model_pair(flops, hbm_bytes, wire_bytes, chunks, *, wire_factor=1.0,
               hw: HardwareModel | MeshHardwareModel = V5E, axis=None):
    """(bulk, fused) modeled seconds for one site under one decision —
    the side-by-side comparison the comm-graph analyzer reports and gates
    rewrites on.  ``wire_factor`` scales the fused wire bytes for a
    compressed payload (the bulk baseline always ships the compute
    dtype)."""
    return (model_bulk(flops, hbm_bytes, wire_bytes, hw=hw, axis=axis),
            model_fused(flops, hbm_bytes, wire_bytes * wire_factor, chunks,
                        hw=hw, axis=axis))


def pct_reduction(bulk: float, fused: float) -> float:
    return 100.0 * (bulk - fused) / bulk
