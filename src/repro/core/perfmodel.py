"""Alpha-beta overlap model shared by serve/train consumers and benchmarks.

Promoted out of ``benchmarks/common.py`` so production code (the overlap
autotuner in :mod:`repro.core.autotune`, launch-time planning) can use the
same roofline constants and fused/bulk time models the paper figures are
projected with.

Terms:
  compute    = max(flops / peak_flops, hbm_bytes / hbm_bw)
  bulk       = compute + kernel-boundary sync + collective launch + wire
  fused      = first chunk's compute exposed, the remaining chunks' wire
               time hidden behind compute, the last chunk's wire exposed,
               plus a per-chunk issue overhead — the paper's Fig. 13 curve:
               finer slices hide more wire time until per-slice overhead
               wins.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Alpha-beta constants for one accelerator generation."""

    peak_flops: float = 197e12   # bf16 MXU peak
    hbm_bw: float = 819e9        # HBM bytes/s
    ici_bw: float = 50e9         # per-link interconnect bytes/s
    ici_lat: float = 1e-6        # collective setup/launch latency (alpha)
    boundary: float = 2e-6       # kernel-boundary sync the fused form removes
    chunk_overhead: float = 2e-7  # per-chunk issue cost (device-initiated
    # comm is cheap — the paper's point; ROC_SHMEM API is ns-scale)

    def compute_time(self, flops: float, hbm_bytes: float) -> float:
        """Roofline compute time: MXU- or HBM-bound, whichever binds."""
        return max(flops / self.peak_flops, hbm_bytes / self.hbm_bw)


V5E = HardwareModel()


def model_bulk(flops, hbm_bytes, wire_bytes, *, bw=None,
               hw: HardwareModel = V5E):
    """Bulk-synchronous: full compute kernel, boundary sync, collective."""
    bw = hw.ici_bw if bw is None else bw
    return (hw.compute_time(flops, hbm_bytes) + hw.boundary + hw.ici_lat
            + wire_bytes / bw)


def model_fused(flops, hbm_bytes, wire_bytes, chunks, *, bw=None,
                zero_copy_saving=0.0, hw: HardwareModel = V5E):
    """Fused: chunk i's wire time hides behind chunks i+1..n's compute.

    total = first chunk compute + max(rest compute, rest wire) +
            last chunk wire + per-chunk issue overhead - zero-copy saving."""
    bw = hw.ici_bw if bw is None else bw
    c = hw.compute_time(flops, hbm_bytes)
    w = wire_bytes / bw + hw.ici_lat
    per_c, per_w = c / chunks, w / chunks
    overlapped = per_c + max(c - per_c, w - per_w) + per_w
    return max(overlapped + chunks * hw.chunk_overhead - zero_copy_saving, 0.0)


def pct_reduction(bulk: float, fused: float) -> float:
    return 100.0 * (bulk - fused) / bulk
