"""Decomposed compute-collective combinators (TPU-adapted paper core).

The paper's GPU kernels issue a non-blocking RDMA PUT per output slice as
soon as the slice's workgroups finish.  The XLA-level TPU equivalent is a
chunked loop in which each chunk's collective (a ``collective-permute``
ring hop or direct offset permute) is issued immediately after that
chunk's compute, while the loop body continues with the next chunk.  The
loops are *unrolled* in python so XLA's latency-hiding scheduler can hoist
``collective-permute-start`` above the following chunk's compute —
yielding the paper's fine-grained overlap without kernel-boundary sync.

All functions here execute *inside* ``jax.shard_map``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.scheduling import ring_offsets
from repro.compat import axis_size, optimization_barrier


def _ring_perm(n: int, shift: int = 1):
    return [(j, (j + shift) % n) for j in range(n)]


def ring_permute(x, axis_name: str, n: int, shift: int = 1):
    """ppermute with the payload dtype pinned.

    Without the barrier XLA may hoist a downstream bf16->f32 convert
    through the permute ("convert of permute == permute of convert"),
    silently doubling wire bytes; the barrier keeps the narrow dtype on
    the wire."""
    return lax.ppermute(optimization_barrier(x), axis_name,
                        _ring_perm(n, shift))


# ---------------------------------------------------------------------------
# reduce-scatter fused with per-chunk compute (GEMV/GEMM + AllReduce core)
# ---------------------------------------------------------------------------
def ring_reduce_scatter_compute(
    partial_fn: Callable,
    axis_name: str,
    *,
    schedule: str = "comm_aware",
):
    """sum_over_ranks(partial_fn(chunk)) -> own rank's reduced chunk.

    ``partial_fn(c)`` returns this rank's *partial* contribution to output
    chunk ``c`` (``c`` is a traced index).  The comm-aware schedule is the
    overlapped ring: the carry destined for rank ``d`` starts at ``d+1``,
    each hop adds the local partial for the in-flight chunk, and a rank's
    own chunk is accumulated last — remote data is on the wire while local
    partials are still being computed (paper Fig. 7b).

    The oblivious schedule computes *all* partials first (natural order)
    and only then runs the pure ring reduce — communication is exposed at
    the tail exactly like the paper's communication-oblivious baseline.
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    if n == 1:
        return partial_fn(jnp.int32(0))

    if schedule == "comm_aware":
        acc = partial_fn((d - 1) % n)
        for i in range(1, n):
            acc = ring_permute(acc, axis_name, n)
            acc = acc + partial_fn((d - i - 1) % n)
        return acc

    if schedule == "oblivious":
        # All compute up front, then a bare ring reduce-scatter.
        parts = [partial_fn((d - 1 - i) % n) for i in reversed(range(n))]
        # parts[j] is the partial for chunk (d - n + j) mod n; the carry
        # schedule consumes them in reverse creation order so the own
        # chunk was produced first (local-first, the paper's baseline).
        acc = parts[-1]  # chunk (d-1)
        for i in range(1, n):
            acc = ring_permute(acc, axis_name, n)
            acc = acc + parts[-(i + 1)]
        return acc

    raise ValueError(f"unknown schedule {schedule!r}")


# ---------------------------------------------------------------------------
# all-gather fused with per-chunk consumption (AG + matmul / KV-gather core)
# ---------------------------------------------------------------------------
def ring_all_gather_compute(
    x_local,
    consume_fn: Callable,
    axis_name: str,
    *,
    combine: str = "place",
    out_init=None,
):
    """Gather ``x_local`` around the ring, applying ``consume_fn`` to each
    arriving shard immediately (while the next hop is in flight).

    consume_fn(src_index, x_src, acc) -> acc'   (src_index is traced)

    combine="place" is a convenience: consume_fn returns (y_src, position
    placer handled by caller through acc).  The local shard is consumed
    first — it is available at t=0, so its compute hides the first hop.
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    acc = consume_fn(d, x_local, out_init)
    buf = x_local
    for i in range(1, n):
        buf = ring_permute(buf, axis_name, n)
        acc = consume_fn((d - i) % n, buf, acc)
    return acc


# ---------------------------------------------------------------------------
# direct all-to-all fused with per-destination compute (GEMM/embedding + A2A)
# ---------------------------------------------------------------------------
def direct_all_to_all_compute(
    produce_fn: Callable,
    out_shape_dtype,
    axis_name: str,
    *,
    schedule: str = "comm_aware",
):
    """Fused compute + All-to-All via per-destination direct sends.

    ``produce_fn(dest)`` computes the chunk this rank owes rank ``dest``
    (traced index).  Each chunk is sent with a single offset
    collective-permute the moment it is ready — the TPU analogue of the
    paper's per-slice RDMA PUT (one logical point-to-point transaction per
    destination, data moved in final layout, no post-shuffle).

    Returns ``[n, *chunk_shape]`` stacked by *source* rank.

    comm_aware: farthest destination first, own chunk last (paper's
    remote-ahead-of-local rule).  oblivious: natural order (Fig. 14
    baseline).
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + tuple(out_shape_dtype.shape), out_shape_dtype.dtype)

    for off in ring_offsets(n, schedule):
        dest = (d + off) % n
        y = produce_fn(dest)
        if off == 0:
            recv, src = y, d
        else:
            recv = ring_permute(y, axis_name, n, shift=off)
            src = (d - off) % n
        out = lax.dynamic_update_slice_in_dim(out, recv[None], src, axis=0)
    return out


def bulk_all_to_all(x, axis_name: str):
    """Baseline: single All-to-All over leading dim [n, ...] -> [n, ...]."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


# ---------------------------------------------------------------------------
# partial-softmax merge (context-sharded decode attention)
# ---------------------------------------------------------------------------
def attention_partial_merge(o, m, l, axis_name: str):
    """Merge flash-attention partials across a KV-sharded axis.

    o: [..., d] unnormalized partial output (sum of exp(s - m) * v)
    m: [...]    local running max
    l: [...]    local sum of exp(s - m)

    One tiny psum/pmax pair replaces the paper's ``sliceRdy`` polling: the
    collective itself is the readiness signal.
    """
    m_glob = lax.pmax(lax.stop_gradient(m), axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * corr, axis_name)
    o_glob = lax.psum(o * corr[..., None], axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
