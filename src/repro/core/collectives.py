"""Decomposed compute-collective combinators (TPU-adapted paper core).

The paper's GPU kernels issue a non-blocking RDMA PUT per output slice as
soon as the slice's workgroups finish.  The XLA-level TPU equivalent is a
chunked loop in which each chunk's collective (a ``collective-permute``
ring hop or direct offset permute) is issued immediately after that
chunk's compute, while the loop body continues with the next chunk.  The
loops are *unrolled* in python so XLA's latency-hiding scheduler can hoist
``collective-permute-start`` above the following chunk's compute —
yielding the paper's fine-grained overlap without kernel-boundary sync.

All functions here execute *inside* ``jax.shard_map``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.scheduling import ring_offsets, sub_chunk_service_order
from repro.compat import axis_size, optimization_barrier


def _ring_perm(n: int, shift: int = 1):
    return [(j, (j + shift) % n) for j in range(n)]


# ---------------------------------------------------------------------------
# wire-fault injection hook (chaos engineering)
# ---------------------------------------------------------------------------
# A trace-time hook applied to every payload leaf as it goes on the wire
# (ring hops and the phase-2 all-gather).  ``None`` — the default — is a
# single Python identity check at *trace* time, so the lowered HLO of a
# clean build is bit-identical whether or not chaos is importable.  The
# chaos runtime (:mod:`repro.runtime.chaos`) installs a corruptor here to
# reproduce flipped-link / NaN-payload faults inside the real rings.
_WIRE_FAULT_HOOK = None


def set_wire_fault_hook(hook):
    """Install (or clear, with ``None``) the wire-fault hook.  Returns the
    previous hook so scoped injectors can restore it."""
    global _WIRE_FAULT_HOOK
    prev = _WIRE_FAULT_HOOK
    _WIRE_FAULT_HOOK = hook
    return prev


def _wire_fault(leaf):
    return leaf if _WIRE_FAULT_HOOK is None else _WIRE_FAULT_HOOK(leaf)


def ring_permute(x, axis_name: str, n: int, shift: int = 1):
    """ppermute with the payload dtype pinned.

    Without the barrier XLA may hoist a downstream bf16->f32 convert
    through the permute ("convert of permute == permute of convert"),
    silently doubling wire bytes; the barrier keeps the narrow dtype on
    the wire.  Accepts a pytree payload (the fp8 wire format rides a
    ``(values, scale)`` pair), barriering and permuting every leaf."""
    return jax.tree.map(
        lambda leaf: lax.ppermute(optimization_barrier(_wire_fault(leaf)),
                                  axis_name, _ring_perm(n, shift)), x)


# ---------------------------------------------------------------------------
# wire-dtype compression (CoCoNet-style fused precision conversion)
# ---------------------------------------------------------------------------
# "f32" is the uncompressed setting: the payload travels at the op's
# compute dtype, exactly as before the wire knob existed (bit-identical).
WIRE_DTYPES = ("f32", "bf16", "fp8")
WIRE_SETTINGS = WIRE_DTYPES + ("auto",)
FP8_MAX = 448.0  # float8_e4m3fn finite max


def wire_itemsize(wire: str, dtype_bytes: int) -> int:
    """Bytes per element on the wire.  The wire is never *widened*: a
    bf16 model under ``wire="bf16"`` already travels at 2 bytes."""
    if wire == "bf16":
        return min(2, int(dtype_bytes))
    if wire == "fp8":
        return min(1, int(dtype_bytes))
    return int(dtype_bytes)


def _passthrough(x, wire: str) -> bool:
    if wire in (None, "f32"):
        return True
    if not jnp.issubdtype(x.dtype, jnp.floating):
        return True  # integer payloads (routing ids, ...) stay exact
    return x.dtype.itemsize <= wire_itemsize(wire, x.dtype.itemsize)


def wire_cast(x, wire: str):
    """Compress one ring/A2A payload chunk for the wire.

    bf16: a plain narrowing cast.  fp8: float8_e4m3fn values with a
    per-chunk max-abs scale riding alongside as a ``(values, scale)``
    pair — the scale is a [1] f32 array so it permutes like any payload.
    ``wire="f32"`` (and any non-narrowing combination) is a passthrough,
    keeping the pre-wire graphs bit-identical.
    """
    if wire not in WIRE_DTYPES and wire is not None:
        raise ValueError(f"unknown wire dtype {wire!r}; expected one of "
                         f"{WIRE_DTYPES}")
    if _passthrough(x, wire):
        return x
    if wire == "bf16":
        return x.astype(jnp.bfloat16)
    amax = lax.stop_gradient(jnp.max(jnp.abs(x.astype(jnp.float32))))
    scale = jnp.maximum(amax, 1e-30) / FP8_MAX
    q = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return (q, scale.reshape((1,)))


def wire_uncast(payload, dtype):
    """Decompress a :func:`wire_cast` payload back to ``dtype`` (callers
    pass f32 where the value feeds a local accumulation)."""
    if isinstance(payload, tuple):
        q, scale = payload
        return (q.astype(jnp.float32) * scale[0]).astype(dtype)
    return payload.astype(dtype)


def all_gather_wire(x, axis_name: str, n: int, *, axis: int = 0,
                    wire: str = "f32"):
    """``lax.all_gather(..., tiled=True)`` with the payload compressed to
    the wire dtype per source chunk (the phase-2 all-gather of the fused
    AllReduce).  ``wire="f32"`` is the exact pre-wire gather."""
    if _passthrough(x, wire):
        return lax.all_gather(_wire_fault(x), axis_name, axis=axis,
                              tiled=True)
    p = wire_cast(x, wire)
    if isinstance(p, tuple):
        q, scale = p
        qg = lax.all_gather(optimization_barrier(_wire_fault(q)), axis_name,
                            axis=0,
                            tiled=False)          # [n, ...chunk]
        sg = lax.all_gather(scale, axis_name, axis=0, tiled=False)  # [n, 1]
        shape = (n,) + (1,) * q.ndim
        vals = qg.astype(jnp.float32) * sg.reshape(shape)
        parts = [lax.index_in_dim(vals, s, axis=0, keepdims=False)
                 for s in range(n)]
        return jnp.concatenate(parts, axis=axis).astype(x.dtype)
    g = lax.all_gather(optimization_barrier(_wire_fault(p)), axis_name,
                       axis=axis, tiled=True)
    return g.astype(x.dtype)


def feasible_chunks_per_rank(dim: int, n: int, q: int) -> int:
    """Largest q' <= q such that ``dim`` splits evenly into ``n * q'``
    fine chunks (sub-chunk granularity must divide the chunked dim)."""
    q = max(1, int(q))
    while q > 1 and dim % (n * q) != 0:
        q -= 1
    return q


def split_ring_payload(a, n_sub: int, axis: int = 1):
    """Split a ring payload into ``n_sub`` equal sub-chunks along ``axis``
    so each can ring (and be consumed) independently — the paper's
    Fig. 13 sub-chunk granularity.  ``n_sub`` must divide the axis
    (callers clamp via :func:`feasible_chunks_per_rank` first); an
    indivisible split raises rather than silently truncating the payload.
    """
    if n_sub == 1:
        return [a]
    if a.shape[axis] % n_sub:
        raise ValueError(
            f"sub-chunk factor {n_sub} does not divide ring-payload axis "
            f"{axis} of size {a.shape[axis]}; clamp via "
            f"feasible_chunks_per_rank first")
    sub = a.shape[axis] // n_sub
    return [lax.dynamic_slice_in_dim(a, j * sub, sub, axis=axis)
            for j in range(n_sub)]


# ---------------------------------------------------------------------------
# reduce-scatter fused with per-chunk compute (GEMV/GEMM + AllReduce core)
# ---------------------------------------------------------------------------
def ring_reduce_scatter_compute(
    partial_fn: Callable,
    axis_name: str,
    *,
    schedule: str = "comm_aware",
    chunks_per_rank: int = 1,
    sub_axis: int = 0,
    skew: int = 0,
    wire: str = "f32",
):
    """sum_over_ranks(partial_fn(chunk)) -> own rank's reduced chunk.

    ``partial_fn(f)`` returns this rank's *partial* contribution to fine
    output chunk ``f`` (``f`` is a traced index).  With the default
    ``chunks_per_rank=1`` there are exactly ``n`` fine chunks — one per
    rank — and the semantics match the historical single-chunk ring.  With
    ``chunks_per_rank=q > 1`` the output is split into ``n*q`` fine chunks
    (rank ``r`` owns fine chunks ``r*q .. r*q+q-1``, concatenated along
    ``sub_axis``): each ring step's payload is ``q`` sub-chunks, and every
    sub-chunk is put on the wire the moment it is produced, so XLA can
    hide sub-chunk ``s``'s hop behind sub-chunk ``s+1``'s compute — the
    paper's Fig. 13 granularity knob.

    The comm-aware schedule is the overlapped ring: the carry destined for
    rank ``d`` starts at ``d+1``, each hop adds the local partial for the
    in-flight chunk, and a rank's own chunk is accumulated last — remote
    data is on the wire while local partials are still being computed
    (paper Fig. 7b).

    The oblivious schedule computes *all* partials first (natural order)
    and only then runs the pure ring reduce — communication is exposed at
    the tail exactly like the paper's communication-oblivious baseline.

    ``skew`` (a measured straggler rotation, Fig. 14): the ring-carry
    structure pins which chunk each rank touches at every hop, so skew
    rotates the only free axis — the service order of the ``q``
    independent sub-chunk rings — putting the straggler-facing sub-ring
    on the wire first.  Each sub-ring's compute chain is untouched, so
    the result is bit-identical under any skew.

    ``wire`` compresses the ring *carry* (bf16, or fp8 with a per-chunk
    scale riding alongside): the carry is cast on the send side of every
    hop while all local accumulation runs in f32, so quantization error
    enters only through the wire — the fused-precision-conversion move of
    CoCoNet.  ``wire="f32"`` keeps the pre-wire graph bit-identical
    (payloads travel at the compute dtype, partials accumulate in it).
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    q = chunks_per_rank
    order = sub_chunk_service_order(q, skew)
    compress = wire not in (None, "f32")

    def merge(accs, dtype=None):
        out = accs[0] if q == 1 else jnp.concatenate(accs, axis=sub_axis)
        return out if dtype is None else out.astype(dtype)

    if n == 1:
        return merge([partial_fn(jnp.int32(s)) for s in range(q)])

    def part(f):
        p = partial_fn(f)
        return p.astype(jnp.float32) if compress else p

    def hop(acc):
        if not compress:
            return ring_permute(acc, axis_name, n)
        return wire_uncast(ring_permute(wire_cast(acc, wire), axis_name, n),
                           jnp.float32)

    if schedule == "comm_aware":
        accs: list = [None] * q
        out_dtype = None
        for s in order:
            p = partial_fn(((d - 1) % n) * q + s)
            out_dtype = p.dtype
            accs[s] = p.astype(jnp.float32) if compress else p
        for i in range(1, n):
            c = (d - i - 1) % n
            for s in order:
                accs[s] = hop(accs[s]) + part(c * q + s)
        return merge(accs, out_dtype if compress else None)

    if schedule == "oblivious":
        # All compute up front, then a bare ring reduce-scatter.
        parts = [[partial_fn(((d - 1 - i) % n) * q + s) for s in range(q)]
                 for i in reversed(range(n))]
        out_dtype = parts[0][0].dtype
        # parts[j] is the partial for chunk (d - n + j) mod n; the carry
        # schedule consumes them in reverse creation order so the own
        # chunk was produced first (local-first, the paper's baseline).
        accs = [p.astype(jnp.float32) if compress else p
                for p in parts[-1]]  # chunk (d-1)
        for i in range(1, n):
            for s in order:
                nxt = parts[-(i + 1)][s]
                accs[s] = hop(accs[s]) + (nxt.astype(jnp.float32)
                                          if compress else nxt)
        return merge(accs, out_dtype if compress else None)

    raise ValueError(f"unknown schedule {schedule!r}")


# ---------------------------------------------------------------------------
# all-gather fused with per-chunk consumption (AG + matmul / KV-gather core)
# ---------------------------------------------------------------------------
def ring_all_gather_compute(
    x_local,
    consume_fn: Callable,
    axis_name: str,
    *,
    combine: str = "place",
    out_init=None,
    wire: str = "f32",
):
    """Gather ``x_local`` around the ring, applying ``consume_fn`` to each
    arriving shard immediately (while the next hop is in flight).

    consume_fn(src_index, x_src, acc) -> acc'   (src_index is traced)

    combine="place" is a convenience: consume_fn returns (y_src, position
    placer handled by caller through acc).  The local shard is consumed
    first — it is available at t=0, so its compute hides the first hop.

    ``wire`` compresses the forwarded shard *once at its source* (the
    compressed payload then rings unchanged, so remote shards round
    exactly once regardless of hop count); the local shard is consumed
    uncompressed.  ``wire="f32"`` is the exact pre-wire path.
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    acc = consume_fn(d, x_local, out_init)
    buf = wire_cast(x_local, wire) if wire not in (None, "f32") else x_local
    for i in range(1, n):
        buf = ring_permute(buf, axis_name, n)
        acc = consume_fn((d - i) % n, wire_uncast(buf, x_local.dtype), acc)
    return acc


# ---------------------------------------------------------------------------
# direct all-to-all fused with per-destination compute (GEMM/embedding + A2A)
# ---------------------------------------------------------------------------
def direct_all_to_all_compute(
    produce_fn: Callable,
    out_shape_dtype,
    axis_name: str,
    *,
    schedule: str = "comm_aware",
    chunks_per_rank: int = 1,
    sub_axis: int = 0,
    skew: int = 0,
    wire: str = "f32",
):
    """Fused compute + All-to-All via per-destination direct sends.

    With the default ``chunks_per_rank=1``, ``produce_fn(dest)`` computes
    the full chunk this rank owes rank ``dest`` (traced index).  With
    ``chunks_per_rank=q > 1`` the payload for each destination is split
    into ``q`` sub-chunks along ``sub_axis`` and ``produce_fn(f)`` is
    called with the *fine* index ``f = dest * q + s``; each sub-chunk is
    sent the moment it is produced, so sub-chunk ``s``'s wire time hides
    behind sub-chunk ``s+1``'s compute (paper Fig. 13 granularity knob).
    ``out_shape_dtype`` always describes the full per-destination chunk.

    Each send is a single offset collective-permute — the TPU analogue of
    the paper's per-slice RDMA PUT (one logical point-to-point transaction
    per destination, data moved in final layout, no post-shuffle).

    Returns ``[n, *chunk_shape]`` stacked by *source* rank.

    comm_aware: farthest destination first, own chunk last (paper's
    remote-ahead-of-local rule).  oblivious: natural order (Fig. 14
    baseline).  ``skew`` rotates the remote portion of the destination
    order (a measured straggler rotation — Fig. 14), exactly matching the
    schedule :func:`repro.core.scheduling.sub_chunk_send_events` models;
    per-destination chunks are independent, so the output is bit-identical
    under any skew.

    ``wire`` compresses each remote send (bf16, or fp8 + per-chunk scale)
    on the producer side; the receiver uncasts into the output dtype.
    Every payload is a one-shot point-to-point transaction, so each value
    rounds exactly once.  The locally-consumed chunk never touches the
    wire and stays exact; ``wire="f32"`` is the exact pre-wire path.
    """
    n = axis_size(axis_name)
    d = lax.axis_index(axis_name)
    q = chunks_per_rank
    chunk_shape = tuple(out_shape_dtype.shape)
    out = jnp.zeros((n,) + chunk_shape, out_shape_dtype.dtype)
    if chunk_shape[sub_axis] % q:
        raise ValueError(
            f"sub-chunk factor {q} does not divide destination-chunk axis "
            f"{sub_axis} of size {chunk_shape[sub_axis]}; clamp via "
            f"feasible_chunks_per_rank first")
    sub = chunk_shape[sub_axis] // q

    def place(out, ysub, src, s):
        starts = [jnp.int32(0)] * out.ndim
        starts[0] = src
        starts[sub_axis + 1] = jnp.int32(s * sub)
        return lax.dynamic_update_slice(out, ysub[None], tuple(starts))

    for off in ring_offsets(n, schedule, skew):
        dest = (d + off) % n
        for s in range(q):
            y = produce_fn(dest * q + s) if q > 1 else produce_fn(dest)
            if off == 0:
                recv, src = y, d
            else:
                recv = wire_uncast(
                    ring_permute(wire_cast(y, wire), axis_name, n,
                                 shift=off), y.dtype)
                src = (d - off) % n
            out = place(out, recv, src, s)
    return out


def bulk_all_to_all(x, axis_name: str):
    """Baseline: single All-to-All over leading dim [n, ...] -> [n, ...]."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=False)


# ---------------------------------------------------------------------------
# partial-softmax merge (context-sharded decode attention)
# ---------------------------------------------------------------------------
def attention_partial_merge(o, m, l, axis_name: str):
    """Merge flash-attention partials across a KV-sharded axis.

    o: [..., d] unnormalized partial output (sum of exp(s - m) * v)
    m: [...]    local running max
    l: [...]    local sum of exp(s - m)

    One tiny psum/pmax pair replaces the paper's ``sliceRdy`` polling: the
    collective itself is the readiness signal.
    """
    m_glob = lax.pmax(lax.stop_gradient(m), axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * corr, axis_name)
    o_glob = lax.psum(o * corr[..., None], axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
