"""Fused embedding pooling + All-to-All (paper §III-A, Fig. 6 — DLRM).

DLRM shards embedding tables across the whole device world (table/model
parallelism) while the top-MLP runs data parallel; the switch between the
two is an All-to-All of pooled embeddings.  The paper's kernel pools a
*slice* (a batch-fragment of one table's output) and PUTs it to the
owning node the moment the slice's workgroups finish, remote slices
scheduled ahead of local ones.

TPU adaptation: the world is the flattened (dp x tp) axis set; pooling is
evaluated per-destination batch fragment and shipped with an offset
collective-permute as soon as it is pooled (direct sends — data arrives
already in the {local batch, tables x dim} layout the downstream
interaction op wants, no shuffle kernel).  The Pallas ``embedding_pool``
kernel covers the compute hot-spot; "kernel" mode routes pooling through
it inside the same fused loop.

Shapes (global):
  indices: [B, T_global, L] int32  — L lookups per bag (pooling size)
  offsets/weights omitted: fixed-L bags, mean-pooled (matches the DLRM
  data generator used by the paper's evaluation)
  tables:  [T_global, V, D]        — T sharded over the world axis
  output:  [B, T_global, D]        — B sharded over the world axis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import resolve_overlap, tune_all_to_all
from repro.core.collectives import bulk_all_to_all, direct_all_to_all_compute
from repro.core.degrade import degrade_mode
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


def _pool(table, idx, kernel: bool):
    """Mean-pool rows of one table.  idx: [b, L] -> [b, D]."""
    if kernel:
        from repro.kernels.embedding_pool.ops import embedding_pool

        return embedding_pool(table, idx)
    return jnp.take(table, idx, axis=0).mean(axis=1)


def embedding_all_to_all(
    ctx: ParallelContext,
    indices,
    tables,
    *,
    mode: str | None = None,
    schedule: str | None = None,
    chunks_per_rank: int | str | None = None,
    skew: int | None = None,
    wire: str | None = None,
):
    """Pooled embeddings exchanged table-parallel -> data-parallel.

    Every world rank holds T_local tables and the categorical indices for
    the *global* batch on its tables; it pools all of them and owes each
    peer the fragment of pooled vectors for that peer's batch shard.
    Returns [B, T_global, D] with B sharded over the world.

    ``chunks_per_rank`` splits each destination's batch fragment into
    sub-fragments along the batch rows, shipping every sub-fragment the
    moment its pooling finishes (paper Fig. 13 — the paper's slice is
    exactly such a batch-fragment of one table's output).  ``skew``
    rotates the destination order by the measured straggler bucket
    (Fig. 14).  This op rings over the flattened *world* axis, so
    ``None`` uses ``ctx.fusion.skew_world`` — a tp-ring bucket would be
    an arbitrary offset on this (larger) ring.  ``wire`` compresses each
    pooled fragment on the send side; the world ring crosses every mesh
    axis, so ``"auto"`` resolves against the *bottleneck* link class
    (a multi-pod world ring inherits the DCN constants).
    """
    mode = mode or ctx.fusion.resolve("embed_a2a")
    mode = degrade_mode("embedding_a2a", indices.shape + tables.shape, mode)
    schedule = schedule or ctx.fusion.schedule
    skew = ctx.fusion.skew_world if skew is None else int(skew)
    world_axes = tuple(ctx.dp_axes) + (ctx.tp_axis,)
    n = ctx.world
    B, T, L = indices.shape
    _, V, D = tables.shape
    use_kernel = mode == "kernel"

    t_local_g = T // n
    if mode == "bulk":
        q, wire_dt = 1, "f32"  # the single A2A does not sub-chunk
    else:
        dec = resolve_overlap(
            chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
            lambda fq, wr: tune_all_to_all(
                (B // n) * t_local_g * D,
                float((B // n) * t_local_g * L * D),
                dtype_bytes=tables.dtype.itemsize,
                n_dev=n, sub_dim=B // n, hw=ctx.hw, axis=world_axes,
                skew=skew, wire=wr, fixed_q=fq),
            dim=B // n, ring=1)
        q, wire_dt = dec.q, dec.wire

    def local_fn(idx_l, tab_l):
        # idx_l: [B, T_local, L] (full batch), tab_l: [T_local, V, D]
        t_local = tab_l.shape[0]
        b_chunk = B // n
        sub = b_chunk // q

        pool_tables = jax.vmap(
            lambda tab, ix: _pool(tab, ix, use_kernel), in_axes=(0, 1), out_axes=1
        )  # ([T_local,V,D], [b,T_local,L]) -> [b, T_local, D]

        def pool_fragment(f):
            # pooled embeddings of this rank's tables for a sub-fragment of
            # dest's batch rows (f is the fine index dest * q + s)
            rows = b_chunk if q == 1 else sub
            frag = lax.dynamic_slice_in_dim(idx_l, f * rows, rows, axis=0)
            return pool_tables(tab_l, frag)  # [rows, T_local, D]

        if mode == "bulk":
            # pool everything, then one All-to-All (RCCL-style baseline)
            full = jnp.concatenate(
                [pool_fragment(jnp.int32(c)) for c in range(n)], axis=0
            )  # [B, T_local, D]
            stacked = full.reshape((n, b_chunk, t_local, D))
            recv = bulk_all_to_all(stacked, _FLAT_AXIS)
        else:
            recv = direct_all_to_all_compute(
                pool_fragment,
                jax.ShapeDtypeStruct((b_chunk, t_local, D), tables.dtype),
                _FLAT_AXIS,
                schedule=schedule,
                chunks_per_rank=q,
                sub_axis=0,
                skew=skew,
                wire=wire_dt,
            )
        # recv: [n_src, b_chunk, T_local, D] -> [b_chunk, T_global, D]
        return jnp.moveaxis(recv, 0, 1).reshape((b_chunk, n * t_local, D))

    # Flatten the whole mesh into one logical world axis for the exchange.
    _FLAT_AXIS = world_axes
    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(None, world_axes, None), P(world_axes, None, None)),
        out_specs=P(world_axes, None, None),
        check_vma=False,
    )(indices, tables)
