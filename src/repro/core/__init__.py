from repro.core import fused  # noqa: F401
