"""Communication-aware chunk scheduling (paper §III, Fig. 6b/7b/14).

The paper schedules logical workgroups that produce *remote* slices ahead
of those producing locally-consumed slices, so remote wire time hides
behind local compute.  On TPU the unit of scheduling is the chunk-loop
iteration order inside a fused op; these helpers produce that order.

All orders are python-level (static) permutations of ring offsets, so
they are free at runtime — the schedule is baked into the lowered HLO.
"""
from __future__ import annotations


def ring_offsets(world: int, schedule: str = "comm_aware") -> list[int]:
    """Order in which a device visits destination offsets 0..world-1.

    Offset 0 is the locally-consumed chunk; offsets 1..world-1 are remote.

    comm_aware: farthest-first remote chunks, local chunk last.  Farthest
      first maximizes the time available to hide the longest wire path
      (multi-hop on a torus) and matches the paper's remote-ahead-of-local
      rule.
    oblivious: natural order starting at the local chunk (the paper's
      baseline scheduling, reproduced for the Fig. 14 skew benchmark).
    """
    if schedule == "comm_aware":
        return [w for w in range(world - 1, 0, -1)] + [0]
    if schedule == "oblivious":
        return list(range(world))
    raise ValueError(f"unknown schedule {schedule!r}")


def reduce_ring_chunk_order(world: int, schedule: str = "comm_aware") -> list[int]:
    """Chunk index (relative to own rank) computed at each ring step of a
    reduce-scatter ring.

    In the overlapped reduce-scatter ring, the carry that finally lands on
    rank ``d`` starts at rank ``d+1``; at ring step ``i`` rank ``d`` adds
    its partial for chunk ``(d - i - 1) mod world``.  That ordering is
    inherently comm-aware — the own chunk ``d`` is accumulated *last*
    (step world-1), i.e. remote contributions are computed and on the wire
    first.  The oblivious variant accumulates its own chunk first, which
    exposes the full ring latency at the end (used only as the Fig. 14
    baseline).
    """
    if schedule == "comm_aware":
        return [-(i + 1) % world for i in range(world)]
    if schedule == "oblivious":
        # own chunk first, then ring hops: strictly worse overlap.
        return [(i) % world for i in range(world)]
    raise ValueError(f"unknown schedule {schedule!r}")
