"""Communication-aware chunk scheduling (paper §III, Fig. 6b/7b/14).

The paper schedules logical workgroups that produce *remote* slices ahead
of those producing locally-consumed slices, so remote wire time hides
behind local compute.  On TPU the unit of scheduling is the chunk-loop
iteration order inside a fused op; these helpers produce that order.

All orders are python-level (static) permutations of ring offsets, so
they are free at runtime — the schedule is baked into the lowered HLO.
"""
from __future__ import annotations


def ring_offsets(world: int, schedule: str = "comm_aware",
                 skew: int = 0) -> list[int]:
    """Order in which a device visits destination offsets 0..world-1.

    Offset 0 is the locally-consumed chunk; offsets 1..world-1 are remote.

    comm_aware: farthest-first remote chunks, local chunk last.  Farthest
      first maximizes the time available to hide the longest wire path
      (multi-hop on a torus) and matches the paper's remote-ahead-of-local
      rule.
    oblivious: natural order starting at the local chunk (the paper's
      baseline scheduling, reproduced for the Fig. 14 skew benchmark).

    ``skew`` rotates the *remote* portion of the order (Fig. 14: feed a
    measured straggler offset in so the lagging peer's chunk is scheduled
    first); the local chunk keeps its position, so the remote-ahead-of-
    local rule is preserved.
    """
    if schedule == "comm_aware":
        offs = [w for w in range(world - 1, 0, -1)] + [0]
    elif schedule == "oblivious":
        offs = list(range(world))
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    if skew and world > 1:
        remote = [o for o in offs if o != 0]
        r = skew % len(remote)
        remote = remote[r:] + remote[:r]
        it = iter(remote)
        offs = [o if o == 0 else next(it) for o in offs]
    return offs


def sub_chunk_send_events(world: int, chunks_per_rank: int,
                          schedule: str = "comm_aware",
                          skew: int = 0) -> list[list[tuple[int, int]]]:
    """Per-rank (destination, fine-chunk) send events of the sub-chunked
    direct-send schedule (``direct_all_to_all_compute`` with
    ``chunks_per_rank=q``).

    Fine chunk ``f = dest * q + s`` is the ``s``-th sub-slice of the
    payload rank ``r`` owes rank ``dest``; events are listed in issue
    order.  The schedule is a *permutation*: every (rank, fine-chunk) pair
    is sent exactly once and lands at the rank owning it — the invariant
    the property suite pins down for arbitrary (world, q, skew).
    """
    q = chunks_per_rank
    events = []
    for r in range(world):
        offs = ring_offsets(world, schedule, skew)
        events.append([((r + off) % world, ((r + off) % world) * q + s)
                       for off in offs for s in range(q)])
    return events


def reduce_ring_chunk_order(world: int, schedule: str = "comm_aware") -> list[int]:
    """Chunk index (relative to own rank) computed at each ring step of a
    reduce-scatter ring.

    In the overlapped reduce-scatter ring, the carry that finally lands on
    rank ``d`` starts at rank ``d+1``; at ring step ``i`` rank ``d`` adds
    its partial for chunk ``(d - i - 1) mod world``.  That ordering is
    inherently comm-aware — the own chunk ``d`` is accumulated *last*
    (step world-1), i.e. remote contributions are computed and on the wire
    first.  The oblivious variant accumulates its own chunk first, which
    exposes the full ring latency at the end (used only as the Fig. 14
    baseline).
    """
    if schedule == "comm_aware":
        return [-(i + 1) % world for i in range(world)]
    if schedule == "oblivious":
        # own chunk first, then ring hops: strictly worse overlap.
        return [(i) % world for i in range(world)]
    raise ValueError(f"unknown schedule {schedule!r}")
