"""Communication-aware chunk scheduling (paper §III, Fig. 6b/7b/14).

The paper schedules logical workgroups that produce *remote* slices ahead
of those producing locally-consumed slices, so remote wire time hides
behind local compute.  On TPU the unit of scheduling is the chunk-loop
iteration order inside a fused op; these helpers produce that order.

All orders are python-level (static) permutations of ring offsets, so
they are free at runtime — the schedule is baked into the lowered HLO.
"""
from __future__ import annotations


def ring_offsets(world: int, schedule: str = "comm_aware",
                 skew: int = 0) -> list[int]:
    """Order in which a device visits destination offsets 0..world-1.

    Offset 0 is the locally-consumed chunk; offsets 1..world-1 are remote.

    comm_aware: farthest-first remote chunks, local chunk last.  Farthest
      first maximizes the time available to hide the longest wire path
      (multi-hop on a torus) and matches the paper's remote-ahead-of-local
      rule.
    oblivious: natural order starting at the local chunk (the paper's
      baseline scheduling, reproduced for the Fig. 14 skew benchmark).

    ``skew`` rotates the *remote* portion of the order (Fig. 14: feed a
    measured straggler offset in so the lagging peer's chunk is scheduled
    first); the local chunk keeps its position, so the remote-ahead-of-
    local rule is preserved.
    """
    if schedule == "comm_aware":
        offs = [w for w in range(world - 1, 0, -1)] + [0]
    elif schedule == "oblivious":
        offs = list(range(world))
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    if skew and world > 1:
        remote = [o for o in offs if o != 0]
        r = skew % len(remote)
        remote = remote[r:] + remote[:r]
        it = iter(remote)
        offs = [o if o == 0 else next(it) for o in offs]
    return offs


def sub_chunk_send_events(world: int, chunks_per_rank: int,
                          schedule: str = "comm_aware",
                          skew: int = 0) -> list[list[tuple[int, int]]]:
    """Per-rank (destination, fine-chunk) send events of the sub-chunked
    direct-send schedule (``direct_all_to_all_compute`` with
    ``chunks_per_rank=q``).

    Fine chunk ``f = dest * q + s`` is the ``s``-th sub-slice of the
    payload rank ``r`` owes rank ``dest``; events are listed in issue
    order.  The schedule is a *permutation*: every (rank, fine-chunk) pair
    is sent exactly once and lands at the rank owning it — the invariant
    the property suite pins down for arbitrary (world, q, skew).
    """
    q = chunks_per_rank
    events = []
    for r in range(world):
        offs = ring_offsets(world, schedule, skew)
        events.append([((r + off) % world, ((r + off) % world) * q + s)
                       for off in offs for s in range(q)])
    return events


def expected_send_cover(world: int, chunks_per_rank: int) -> set:
    """The (destination, fine-chunk) pairs every rank's send schedule must
    emit exactly once: fine chunk ``dest * q + s`` for each destination's
    ``q`` sub-slices.  This is the ground truth both the static schedule
    verifier (:mod:`repro.analysis.lint`) and the hypothesis property
    suite check :func:`sub_chunk_send_events` against — one definition, so
    the lint and the tests can never drift apart."""
    q = chunks_per_rank
    return {(d, d * q + s) for d in range(world) for s in range(q)}


def sub_chunk_service_order(n_sub: int, skew: int = 0) -> list[int]:
    """Service order of the ``n_sub`` independent sub-chunk rings inside a
    ring-carry op (reduce-scatter / KV / CE rings).

    The ring-carry structure fixes *which* chunk a rank touches at each
    hop, so the only schedule freedom a measured skew can exploit is the
    order in which the sub-chunk rings are serviced within a hop: rotating
    it by ``skew`` issues the straggler-facing sub-ring's permute first.
    Each sub-ring's compute chain is untouched, so outputs are unchanged.
    """
    if n_sub <= 1:
        return [0]
    r = skew % n_sub
    return list(range(r, n_sub)) + list(range(r))


def modeled_finish_times(world: int, schedule: str, skew: int,
                         step_times: list[float], *,
                         compute: float = 1.0,
                         wire: float = 0.3,
                         link_scale: list[float] | None = None) -> list[float]:
    """Per-rank finish times of one fused direct-A2A round (Fig. 14 model).

    ``step_times`` are measured per-rank step times (only ratios matter);
    rank ``r`` produces its ``j``-th scheduled chunk ``compute * rate[r]``
    after the previous one and the send departs when the chunk is
    produced.  Wire time is the shortest-direction ring traversal with an
    optional per-link cost multiplier ``link_scale`` (``link_scale[l]``
    scales the link from rank ``l`` to ``l+1`` — a slow DCN/pod-boundary
    link at cluster scale).  A rank finishes when its own chunks are
    produced and every incoming chunk has arrived — the spread of these
    finish times is the paper's inter-node execution skew.

    The offset order is the shared SPMD schedule, so a straggler's send
    for offset ``off`` departs at its (slowed) position of ``off`` in that
    order.  Which of the straggler's sends are wire-expensive depends on
    where it sits relative to the slow links — that coupling between the
    *measured* straggler position and the static topology is what the
    schedule rotation exploits.
    """
    offs = ring_offsets(world, schedule, skew)
    t_min = min(step_times)
    if t_min <= 0:
        raise ValueError("step times must be positive")
    rate = [t / t_min for t in step_times]
    ls = list(link_scale) if link_scale is not None else [1.0] * world
    if len(ls) != world:
        raise ValueError(f"need {world} link scales, got {len(ls)}")
    pos = {off: j for j, off in enumerate(offs)}
    # O(1) per-pair link sums: the forward path src..src+off-1 and the
    # backward path together traverse the whole ring exactly once, so
    # bwd = total - fwd; fwd comes from a doubled prefix array.
    cum = [0.0]
    for l in ls + ls:
        cum.append(cum[-1] + l)
    total = cum[world]

    def wire_cost(src: int, off: int) -> float:
        fwd = cum[src + off] - cum[src]
        return wire * min(fwd, total - fwd)

    finish = []
    for d in range(world):
        t = world * compute * rate[d]        # own chunks all produced
        for src in range(world):
            if src == d:
                continue
            off = (d - src) % world
            depart = (pos[off] + 1) * compute * rate[src]
            t = max(t, depart + wire_cost(src, off))
        finish.append(t)
    return finish


def skew_statistic(times: list[float]) -> float:
    """max/median - 1 (the Fig. 14 inter-node execution-skew metric)."""
    if len(times) < 2:
        return 0.0
    s = sorted(times)
    k = len(s)
    med = s[k // 2] if k % 2 else 0.5 * (s[k // 2 - 1] + s[k // 2])
    return s[-1] / med - 1.0 if med > 0 else 0.0


def modeled_execution_skew(world: int, schedule: str, skew: int,
                           step_times: list[float], *,
                           compute: float = 1.0, wire: float = 0.3,
                           link_scale: list[float] | None = None) -> float:
    """Schedule-induced execution skew: the max/median - 1 statistic over
    *rate-normalized* modeled finish times.  Dividing each rank's finish
    by its measured compute rate removes the injected/measured imbalance
    itself, so what remains is the skew the *schedule* creates by leaving
    wire time exposed unevenly — 0 for a perfectly hidden schedule,
    largest for the communication-oblivious baseline (Fig. 14)."""
    t_min = min(step_times)
    if t_min <= 0:
        raise ValueError("step times must be positive")
    rate = [t / t_min for t in step_times]
    fin = modeled_finish_times(world, schedule, skew, step_times,
                               compute=compute, wire=wire,
                               link_scale=link_scale)
    return skew_statistic([f / r for f, r in zip(fin, rate)])


def best_skew_rotation(world: int, step_times: list[float], *,
                       schedule: str = "comm_aware",
                       compute: float = 1.0, wire: float = 0.3,
                       link_scale: list[float] | None = None) -> int:
    """Reduce measured per-rank step times to an integer schedule rotation:
    the ``skew`` minimizing the modeled execution-skew statistic (ties go
    to the smaller rotation, so uniform times yield 0 — no re-jit churn).
    Candidates include 0, so the measured rotation can never model worse
    than the un-skewed comm-aware schedule."""
    best, best_s = 0, float("inf")
    for r in range(max(world - 1, 1)):
        s = modeled_execution_skew(world, schedule, r, step_times,
                                   compute=compute, wire=wire,
                                   link_scale=link_scale)
        if s < best_s - 1e-12:
            best, best_s = r, s
    return best


def reduce_ring_chunk_order(world: int, schedule: str = "comm_aware") -> list[int]:
    """Chunk index (relative to own rank) computed at each ring step of a
    reduce-scatter ring.

    In the overlapped reduce-scatter ring, the carry that finally lands on
    rank ``d`` starts at rank ``d+1``; at ring step ``i`` rank ``d`` adds
    its partial for chunk ``(d - i - 1) mod world``.  That ordering is
    inherently comm-aware — the own chunk ``d`` is accumulated *last*
    (step world-1), i.e. remote contributions are computed and on the wire
    first.  The oblivious variant accumulates its own chunk first, which
    exposes the full ring latency at the end (used only as the Fig. 14
    baseline).
    """
    if schedule == "comm_aware":
        return [-(i + 1) % world for i in range(world)]
    if schedule == "oblivious":
        # own chunk first, then ring hops: strictly worse overlap.
        return [(i) % world for i in range(world)]
    raise ValueError(f"unknown schedule {schedule!r}")
