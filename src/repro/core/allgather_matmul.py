"""Fused AllGather x matmul and matmul x ReduceScatter (sequence parallel).

These are the sequence-parallel counterparts of the paper's GEMM+collective
fusion: under SP the row-parallel AllReduce splits into a reduce-scatter
(fused here with the producing matmul) and the next layer's all-gather
(fused here with the consuming matmul).  Each ring hop's collective-permute
is issued as soon as the corresponding chunk is computed/consumed, giving
the paper's intra-kernel overlap at the XLA level.

allgather_matmul:  x [B, S, K] with S sharded over tp, w [K, N] col-sharded
                   -> y [B, S, N] full S, N sharded over tp.
matmul_reducescatter: x [B, S, K] full S, K sharded over tp; w [K, N]
                   -> y [B, S, N] with S sharded over tp (sum over ranks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import (resolve_overlap, tune_allgather_matmul,
                                 tune_matmul_allreduce)
from repro.core.collectives import (ring_permute,
                                    ring_reduce_scatter_compute, wire_cast,
                                    wire_uncast)
from repro.core.degrade import degrade_mode
from repro.core.scheduling import sub_chunk_service_order
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


def allgather_matmul(ctx: ParallelContext, x, w, *, mode: str | None = None,
                     chunks_per_rank: int | str | None = None,
                     skew: int | None = None, wire: str | None = None):
    """y[b, s, :] = (AG_tp(x) @ w_colshard)[b, s, :].

    Fused: the locally-held sequence chunk is multiplied first (it is
    available at t=0, hiding the first hop), then each arriving chunk is
    multiplied while the next is on the wire.  ``chunks_per_rank`` splits
    the ring payload into sub-chunks so each arriving sub-slice is
    consumed (and the next forwarded) independently — finer overlap for
    long sequence chunks (paper Fig. 13).  ``skew`` rotates the sub-ring
    service order by the measured straggler bucket (Fig. 14; ``None``
    uses ``ctx.fusion.skew``); results land in disjoint output slices, so
    the rotation is bit-exact.  ``wire`` compresses the forwarded
    sequence sub-chunks once at their source (one rounding per value no
    matter how many hops they ride; the local chunk stays exact); ``None``
    uses ``ctx.fusion.wire``.
    """
    mode = mode or ctx.fusion.resolve("ag_matmul")
    mode = degrade_mode("allgather_matmul", x.shape + w.shape, mode)
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis, n = ctx.tp_axis, ctx.tp
    b, s, k = x.shape
    nout = w.shape[1]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    # the ring payload is the local sequence chunk: only q | s_loc matters
    dec = (None if mode == "bulk" else resolve_overlap(
        chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
        lambda fq, wr: tune_allgather_matmul(
            b, s // n, k, nout // n, dtype_bytes=x.dtype.itemsize, n_dev=n,
            hw=ctx.hw, axis=axis, skew=skew, wire=wr, fixed_q=fq),
        dim=s // n, ring=1))
    q, wire_dt = (1, "f32") if dec is None else (dec.q, dec.wire)
    order = sub_chunk_service_order(q, skew)

    def local_fn(xl, wl):
        if mode == "bulk":
            xg = lax.all_gather(xl, axis, axis=1, tiled=True)
            return xg @ wl
        d = lax.axis_index(axis)
        s_loc = xl.shape[1]
        sub = s_loc // q
        out = jnp.zeros((xl.shape[0], s_loc * n, wl.shape[1]), xl.dtype)
        bufs = [lax.dynamic_slice_in_dim(xl, j * sub, sub, axis=1)
                for j in range(q)]
        for j in range(q):
            out = lax.dynamic_update_slice_in_dim(
                out, bufs[j] @ wl, d * s_loc + j * sub, axis=1)
        # the ring payload rounds once at its source; arriving sub-chunks
        # are consumed from the wire representation at every hop
        bufs = [wire_cast(bj, wire_dt) for bj in bufs]
        for i in range(1, n):
            src = (d - i) % n
            for j in order:
                bufs[j] = ring_permute(bufs[j], axis, n)
                out = lax.dynamic_update_slice_in_dim(
                    out, wire_uncast(bufs[j], xl.dtype) @ wl,
                    src * s_loc + j * sub, axis=1)
        return out

    return shard_map(
        local_fn,
        mesh=ctx.mesh,
        in_specs=(P(dp, ctx.tp_axis, None), P(None, ctx.tp_axis)),
        out_specs=P(dp, None, ctx.tp_axis),
        check_vma=False,
    )(x, w)


def matmul_reducescatter(ctx: ParallelContext, x, w, *, mode: str | None = None,
                         schedule: str | None = None,
                         chunks_per_rank: int | str | None = None,
                         skew: int | None = None, wire: str | None = None):
    """y = ReduceScatter_tp(x @ w_rowshard) scattered over the sequence dim.

    ``chunks_per_rank`` sub-chunks each ring step's payload (Fig. 13);
    ``skew`` rotates the sub-chunk service order by the measured straggler
    bucket (Fig. 14; ``None`` uses ``ctx.fusion.skew``); ``wire``
    compresses the ring carry per hop with f32 local accumulation
    (``None`` uses ``ctx.fusion.wire``)."""
    mode = mode or ctx.fusion.resolve("matmul_rs")
    mode = degrade_mode("matmul_reducescatter", x.shape + w.shape, mode)
    schedule = schedule or ctx.fusion.schedule
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis, n = ctx.tp_axis, ctx.tp
    b, s, k = x.shape
    nout = w.shape[1]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    dec = (None if mode == "bulk" else resolve_overlap(
        chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
        lambda fq, wr: tune_matmul_allreduce(
            b * s, k // n, nout, dtype_bytes=x.dtype.itemsize, n_dev=n,
            chunk_dim=s, allgather_phase=False, hw=ctx.hw, axis=axis,
            skew=skew, wire=wr, fixed_q=fq),
        dim=s, ring=n))
    q, wire_dt = (1, "f32") if dec is None else (dec.q, dec.wire)

    def local_fn(xl, wl):
        if mode == "bulk":
            y = xl @ wl
            return lax.psum_scatter(y, axis, scatter_dimension=1, tiled=True)
        s_full = xl.shape[1]
        chunk = s_full // (n * q)

        def partial(f):
            xi = lax.dynamic_slice_in_dim(xl, f * chunk, chunk, axis=1)
            return xi @ wl

        return ring_reduce_scatter_compute(partial, axis, schedule=schedule,
                                           chunks_per_rank=q, sub_axis=1,
                                           skew=skew, wire=wire_dt)

    return shard_map(
        local_fn,
        mesh=ctx.mesh,
        in_specs=(P(dp, None, ctx.tp_axis), P(ctx.tp_axis, None)),
        out_specs=P(dp, ctx.tp_axis, None),
        check_vma=False,
    )(x, w)


def allgather_seq(ctx: ParallelContext, x, *, axis_pos: int = 1):
    """Plain AG of a sequence-sharded activation (layout boundaries)."""
    b = x.shape[0]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    in_spec = [dp, None, None]
    in_spec[axis_pos] = ctx.tp_axis
    out_spec = [dp, None, None]

    def local_fn(xl):
        return lax.all_gather(xl, ctx.tp_axis, axis=axis_pos, tiled=True)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(*in_spec),), out_specs=P(*out_spec), check_vma=False,
    )(x)
