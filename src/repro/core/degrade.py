"""Graceful degradation: quarantine bad fused decisions, fall back to bulk.

A fused kernel/wire combination that keeps failing (collective timeouts,
NaN losses from a poisoned ring) should cost throughput, not the job.
This module tracks failures per ``(op, shape)`` decision key — the same
granularity the autotuner memoizes under — and after ``max_failures``
strikes *quarantines* the key: every fused-op call site consults
:func:`degrade_mode` at trace time and a quarantined key resolves to the
bulk (``psum`` / ``all_to_all``) reference path instead of the fused one.

Quarantine is not forever: after ``cooldown`` healthy steps the key is
released on probation and the fused path is re-probed; a failure while on
probation re-quarantines with the cool-down scaled by
``cooldown_backoff`` (capped), so a persistently bad combo converges to
rarely-probed bulk execution while a transient blip recovers quickly.

Mode decisions are baked into the lowered HLO, so a policy change only
takes effect at the next trace — the supervisor watches
:meth:`DegradationPolicy.consume_dirty` and re-jits (see
``TrainSupervisor.rebuild_step``).  With no policy installed the hook is
a module-level ``None`` check at trace time: zero cost, identical HLO.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Sequence

log = logging.getLogger("repro.core.degrade")

DegradeKey = tuple  # (op: str, shape: tuple[int, ...])


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    max_failures: int = 2        # strikes before quarantine
    cooldown: int = 50           # healthy steps before a re-probe
    cooldown_backoff: float = 2.0  # growth after a failed re-probe
    max_cooldown: int = 2000


class DegradationPolicy:
    """Per-(op, shape) failure ledger -> fused/bulk mode decisions."""

    def __init__(self, cfg: DegradeConfig | None = None):
        self.cfg = cfg or DegradeConfig()
        self._strikes: dict[DegradeKey, int] = {}
        self._quarantine: dict[DegradeKey, int] = {}  # key -> steps left
        self._sentences: dict[DegradeKey, int] = {}   # key -> times jailed
        self._active: set[DegradeKey] = set()  # keys in the current trace
        self.demotions = 0       # fused->bulk resolutions served
        self._dirty = False

    # -- trace-time surface (called from the fused-op call sites) --------
    def effective_mode(self, op: str, shape: Sequence[int], mode: str) -> str:
        key = (str(op), tuple(int(s) for s in shape))
        self._active.add(key)
        if mode != "bulk" and key in self._quarantine:
            self.demotions += 1
            return "bulk"
        return mode

    # -- runtime surface (called from the supervisor / chaos harness) ----
    def record_failure(self, key: DegradeKey | None = None) -> list[DegradeKey]:
        """One strike against ``key`` — or, with ``None``, against every
        key active in the current trace (a NaN loss cannot name the ring
        that poisoned it, so all fused decisions in the step are blamed).
        Returns the keys newly quarantined."""
        keys = [key] if key is not None else sorted(self._active)
        jailed = []
        for k in keys:
            if k in self._quarantine:
                continue
            self._strikes[k] = self._strikes.get(k, 0) + 1
            if self._strikes[k] < self.cfg.max_failures:
                continue
            n = self._sentences.get(k, 0)
            cd = min(self.cfg.max_cooldown,
                     int(self.cfg.cooldown * self.cfg.cooldown_backoff ** n))
            self._quarantine[k] = cd
            self._sentences[k] = n + 1
            self._strikes[k] = 0
            self._dirty = True
            jailed.append(k)
            log.warning("quarantining fused decision %s for %d healthy "
                        "steps (sentence %d); falling back to bulk", k, cd,
                        n + 1)
        return jailed

    def record_healthy(self) -> list[DegradeKey]:
        """One healthy step: cool every quarantined key down, releasing
        those whose sentence expired (re-probe on the next trace).
        Returns the released keys."""
        released = []
        for k in list(self._quarantine):
            self._quarantine[k] -= 1
            if self._quarantine[k] <= 0:
                del self._quarantine[k]
                self._dirty = True
                released.append(k)
                log.info("releasing %s from quarantine; re-probing the "
                         "fused path", k)
        return released

    def quarantined(self, op: str, shape: Sequence[int]) -> bool:
        return (str(op), tuple(int(s) for s in shape)) in self._quarantine

    def quarantined_keys(self) -> tuple[DegradeKey, ...]:
        """Snapshot of the jailed keys (the comm-graph analyzer lists
        these in its report; order is deterministic for test output)."""
        return tuple(sorted(self._quarantine))

    def consume_dirty(self) -> bool:
        """True exactly once after the quarantine set changed — the
        caller's cue to re-jit so the new mode decisions take effect."""
        d, self._dirty = self._dirty, False
        return d

    def begin_trace(self) -> None:
        """Reset the active-key ledger before a fresh trace.  The
        supervisor calls this on every rebuild path (degradation re-jit,
        skew re-jit, poisoned-step retrace, rank-loss reshard) so
        ``record_failure(None)`` blames only keys live in the current
        trace, not ops left over from retired ones."""
        self._active.clear()

    def summary(self) -> dict:
        return {
            "quarantined": {f"{op}{list(shape)}": left
                            for (op, shape), left in self._quarantine.items()},
            "strikes": {f"{op}{list(shape)}": n
                        for (op, shape), n in self._strikes.items() if n},
            "sentences": sum(self._sentences.values()),
            "demotions": self.demotions,
            "active_keys": len(self._active),
        }


# ---------------------------------------------------------------------------
# module-level installation (mirrors the wire-fault hook in collectives)
# ---------------------------------------------------------------------------
_POLICY: DegradationPolicy | None = None


def set_degradation_policy(policy: DegradationPolicy | None):
    """Install (or clear) the process-wide policy.  Returns the previous
    one so tests can scope their installs."""
    global _POLICY
    prev = _POLICY
    _POLICY = policy
    return prev


def get_degradation_policy() -> DegradationPolicy | None:
    return _POLICY


def is_quarantined(op: str, shape: Sequence[int]) -> bool:
    """Read-only quarantine probe (no active-key bookkeeping): the static
    analyzer asks this before planning a rewrite, without registering the
    key as live in the current trace the way ``degrade_mode`` does."""
    return _POLICY is not None and _POLICY.quarantined(op, shape)


def degrade_mode(op: str, shape: Sequence[int], mode: str) -> str:
    """The fused-op call-site hook: demote ``mode`` to ``"bulk"`` when the
    installed policy has quarantined this (op, shape) decision.  With no
    policy installed this is a single ``None`` check at trace time."""
    if _POLICY is None:
        return mode
    return _POLICY.effective_mode(op, shape, mode)
