"""Fused expert GEMM + All-to-All for MoE layers (paper §III, Fig. 4/10).

Expert parallelism: experts are sharded over the TP axis; tokens are
exchanged by two All-to-All collectives (dispatch, combine).  The paper
fuses the *combine* All-to-All into the expert GEMM: as soon as an expert
finishes the output tiles destined for one peer, those tiles are sent
while the remaining tiles are still being computed.

TPU adaptation: the expert FFN is evaluated per-destination-shard; each
destination chunk is shipped with a single offset collective-permute the
moment it is ready (direct per-peer sends, data lands in final layout —
the analogue of the paper's point-to-point PUTs that avoid a post-shuffle
kernel).  Comm-aware schedule computes the farthest peer's tokens first
and the locally-consumed tokens last.

The dispatch All-to-All is fused symmetrically ("pre-fusion"): the chunk
of dispatched tokens owed to a peer is sent as soon as it is sliced out,
overlapping with the routing of later chunks — a beyond-paper addition
(the paper only fuses the combine side; §EXPERIMENTS records both).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.autotune import resolve_overlap, tune_all_to_all
from repro.core.collectives import bulk_all_to_all, direct_all_to_all_compute
from repro.core.degrade import degrade_mode
from repro.core.scheduling import ring_offsets
from repro.parallel.sharding import ParallelContext
from repro.compat import shard_map


def _resolve(ctx, chunks_per_rank, wire, *, sub_dim, chunk_elems,
             flops_per_dest, dtype_bytes, skew=0, kernel=False):
    """FusionConfig/override -> feasible (chunks_per_rank, wire).
    Sub-chunks are cut along the capacity axis, so q must divide
    ``sub_dim`` (= C).  ``kernel=True`` tunes the device-initiated path
    under its own ``TuneKey`` op (fp8 clamped to bf16 in the decision)."""
    dec = resolve_overlap(
        chunks_per_rank, ctx.fusion.granularity, wire, ctx.fusion.wire,
        lambda fq, wr: tune_all_to_all(chunk_elems, flops_per_dest,
                                       dtype_bytes=dtype_bytes, n_dev=ctx.tp,
                                       sub_dim=sub_dim, hw=ctx.hw,
                                       axis=ctx.tp_axis, skew=skew, wire=wr,
                                       fixed_q=fq, kernel=kernel),
        dim=sub_dim, ring=1)
    if kernel and dec.wire == "fp8":
        # a pinned --wire fp8 bypasses the tuner sweep; record the
        # kernel-path clamp in the decision the caller sees
        from repro.kernels import clamp_kernel_wire

        dec = dec._replace(wire=clamp_kernel_wire(dec.wire, "moe_a2a_kernel"))
    return dec


def moe_dispatch_all_to_all(ctx: ParallelContext, x, *, mode: str | None = None,
                            schedule: str | None = None,
                            chunks_per_rank: int | str | None = None,
                            skew: int | None = None,
                            wire: str | None = None):
    """All-to-All of dispatch buffers over the EP axis.

    x: [B, n_ep, E_local, C, D] global — dim 1 indexes the destination EP
    shard, sharded over tp on dim 0?  No: B is the dp-sharded batch dim and
    the EP exchange happens within each dp row over the tp axis.  Input is
    produced seq-sharded, so dim 0 of the *local* view is the EP source.
    Returns same global shape with source/destination swapped.

    ``chunks_per_rank`` splits each destination's token block along the
    capacity axis; every sub-block is shipped as soon as it is sliced out
    (paper Fig. 13 granularity knob).  ``skew`` rotates the destination
    order by the measured straggler bucket (Fig. 14).  ``wire``
    compresses each remote send on the producer side (one rounding per
    token; the locally-consumed block stays exact).

    ``mode="kernel"`` runs the device-initiated Pallas dispatch A2A
    (remote DMA into the peers' by-source slots) where the backend
    supports it; falls back to fused.
    """
    mode = mode or ctx.fusion.resolve("moe_a2a")
    mode = degrade_mode("moe_dispatch_a2a", x.shape, mode)
    schedule = schedule or ctx.fusion.schedule
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis = ctx.tp_axis
    b = x.shape[0]
    _, n_ep, e_glob, cap, dmodel = x.shape
    e_loc = e_glob // ctx.tp      # expert dim is tp-sharded (in_specs)
    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    b_loc = b // (ctx.dp if dp is not None else 1)
    if mode == "kernel":
        from repro.kernels.fused_dispatch_a2a.ops import (
            fused_dispatch_a2a, fused_dispatch_a2a_kernel_available)

        if not fused_dispatch_a2a_kernel_available(ctx.mesh):
            mode = "fused"
    dec = (None if mode == "bulk" else
           _resolve(ctx, chunks_per_rank, wire, sub_dim=cap,
                    chunk_elems=b_loc * e_loc * cap * dmodel,
                    flops_per_dest=0.0, dtype_bytes=x.dtype.itemsize,
                    skew=skew, kernel=mode == "kernel"))
    q, wire_dt = (1, "f32") if dec is None else (dec.q, dec.wire)
    if mode == "kernel":
        # device-initiated path: the global kernel entry owns the
        # shard_map (it flattens multi-axis meshes under interpret mode)
        return fused_dispatch_a2a(ctx, x,
                                  comm_aware=schedule == "comm_aware",
                                  chunks_per_rank=q, skew=skew,
                                  wire=wire_dt)

    def local_fn(xl):
        # xl: [B_loc, n_ep, E_local, C, D]; exchange dim 1 across ranks.
        xt = jnp.moveaxis(xl, 1, 0)  # [n_ep, B_loc, E_local, C, D]
        if mode == "bulk":
            out = bulk_all_to_all(xt, axis)
        else:
            sub = cap // q

            def produce(f):
                dest, s = f // q, f % q
                xb = lax.dynamic_index_in_dim(xt, dest, axis=0, keepdims=False)
                if q == 1:
                    return xb
                return lax.dynamic_slice_in_dim(xb, s * sub, sub, axis=2)

            out = direct_all_to_all_compute(
                produce,
                jax.ShapeDtypeStruct(xt.shape[1:], xt.dtype),
                axis,
                schedule=schedule,
                chunks_per_rank=q,
                sub_axis=2,
                skew=skew,
                wire=wire_dt,
            )
        return jnp.moveaxis(out, 0, 1)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, ctx.tp_axis, None, None),),
        out_specs=P(dp, None, ctx.tp_axis, None, None),
        check_vma=False,
    )(x)


def fused_expert_ffn_combine(
    ctx: ParallelContext,
    x_dispatched,
    w_up,
    w_gate,
    w_down,
    *,
    act: Callable,
    mode: str | None = None,
    schedule: str | None = None,
    chunks_per_rank: int | str | None = None,
    skew: int | None = None,
    wire: str | None = None,
):
    """Expert FFN fused with the combine All-to-All (the paper's GEMM+A2A).

    x_dispatched: [B, src_ep, E_local, C, D] global — tokens already
        dispatched to this EP shard, grouped by the *source* shard that
        sent them (= the destination of the combine).  E_local sharded
        over tp.
    w_up/w_gate/w_down: [E, D, F] / [E, D, F] / [E, F, D], experts sharded
        over tp on dim 0.
    Returns [B, dest_ep, E_local, C, D]: expert outputs returned to their
        source shards.

    fused: for each combine destination (source shard) s — farthest first,
    local last — run the expert FFN over that shard's token block and ship
    it immediately; the wire time of block s hides behind the GEMMs of
    block s+1 (paper Fig. 10).  ``chunks_per_rank`` additionally splits
    each destination's block along the capacity axis, shipping every
    sub-block's FFN output the moment its GEMMs finish (Fig. 13).

    kernel: device-initiated Pallas GEMM+A2A (remote DMA into the peers'
    output buffers) where the backend supports it; falls back to fused.
    """
    mode = mode or ctx.fusion.resolve("moe_a2a")
    mode = degrade_mode("moe_combine_a2a",
                        x_dispatched.shape + w_up.shape[-1:], mode)
    schedule = schedule or ctx.fusion.schedule
    skew = ctx.fusion.skew if skew is None else int(skew)
    axis = ctx.tp_axis
    b = x_dispatched.shape[0]
    _, n_ep, e_glob, cap, dmodel = x_dispatched.shape
    e_loc = e_glob // ctx.tp      # expert dim is tp-sharded (in_specs)
    d_ff = w_up.shape[-1]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    b_loc = b // (ctx.dp if dp is not None else 1)
    if mode == "kernel":
        from repro.kernels.fused_gemm_a2a.ops import (
            fused_gemm_a2a_kernel_available)

        if not fused_gemm_a2a_kernel_available(ctx.mesh):
            mode = "fused"

    dec = (None if mode != "fused" else
           _resolve(ctx, chunks_per_rank, wire, sub_dim=cap,
                    chunk_elems=b_loc * e_loc * cap * dmodel,
                    flops_per_dest=2.0 * 3 * b_loc * e_loc * cap * dmodel
                    * d_ff,
                    dtype_bytes=x_dispatched.dtype.itemsize, skew=skew))
    q, wire_dt = (1, "f32") if dec is None else (dec.q, dec.wire)
    if mode == "kernel":
        from repro.kernels.fused_gemm_a2a.ops import fused_gemm_a2a

        # the kernel-path tune key clamps fp8 to bf16 in the Decision
        # (the PUT staging has no per-chunk-scale path)
        kdec = _resolve(ctx, 1, wire, sub_dim=cap,
                        chunk_elems=b_loc * e_loc * cap * dmodel,
                        flops_per_dest=2.0 * 3 * b_loc * e_loc * cap
                        * dmodel * d_ff,
                        dtype_bytes=x_dispatched.dtype.itemsize, skew=skew,
                        kernel=True)
        # the global kernel entry owns the shard_map (it flattens
        # multi-axis meshes under interpret mode)
        return fused_gemm_a2a(ctx, x_dispatched, w_up, w_gate, w_down,
                              act=act, comm_aware=schedule == "comm_aware",
                              skew=skew, wire=kdec.wire)

    def ffn_block(xb, wu, wg, wd):
        # xb: [B_loc, E_local, C, D] -> same shape
        h = jnp.einsum("becd,edf->becf", xb, wu)
        g = jnp.einsum("becd,edf->becf", xb, wg)
        h = act(g) * h
        return jnp.einsum("becf,efd->becd", h, wd)

    def local_fn(xl, wu, wg, wd):
        xt = jnp.moveaxis(xl, 1, 0)  # [src_ep, B_loc, E_local, C, D]
        if mode == "bulk":
            flat = xt.reshape((xt.shape[0] * xt.shape[1],) + xt.shape[2:])
            y = ffn_block(flat, wu, wg, wd).reshape(xt.shape)
            out = bulk_all_to_all(y, axis)
        else:
            sub = cap // q

            def produce(f):
                dest, s = f // q, f % q
                xb = lax.dynamic_index_in_dim(xt, dest, axis=0, keepdims=False)
                if q > 1:
                    xb = lax.dynamic_slice_in_dim(xb, s * sub, sub, axis=2)
                return ffn_block(xb, wu, wg, wd)

            out = direct_all_to_all_compute(
                produce,
                jax.ShapeDtypeStruct(xt.shape[1:], xt.dtype),
                axis,
                schedule=schedule,
                chunks_per_rank=q,
                sub_axis=2,
                skew=skew,
                wire=wire_dt,
            )
        return jnp.moveaxis(out, 0, 1)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(
            P(dp, None, ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
        ),
        out_specs=P(dp, None, ctx.tp_axis, None, None),
        check_vma=False,
    )(x_dispatched, w_up, w_gate, w_down)
