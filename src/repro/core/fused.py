"""Public API for the fused computation-collective operators.

This is the "PyTorch custom operator" integration level of the paper:
model code calls these ops and a single ``FusionConfig`` switch flips the
whole model between bulk-synchronous baseline, fused-decomposed (paper),
and Pallas device-initiated kernels — nothing else in the model changes.
"""
from repro.core.matmul_allreduce import matmul_allreduce
from repro.core.allgather_matmul import allgather_matmul, matmul_reducescatter, allgather_seq
from repro.core.moe_all_to_all import moe_dispatch_all_to_all, fused_expert_ffn_combine
from repro.kernels.fused_dispatch_a2a import fused_dispatch_a2a
from repro.kernels.fused_gemm_a2a import fused_moe_kernel
from repro.core.embedding_all_to_all import embedding_all_to_all
from repro.core.loss import sharded_cross_entropy
from repro.core.collectives import (
    ring_reduce_scatter_compute,
    ring_all_gather_compute,
    direct_all_to_all_compute,
    attention_partial_merge,
    feasible_chunks_per_rank,
    all_gather_wire,
    wire_cast,
    wire_uncast,
)
from repro.core.autotune import (
    Decision,
    choose_chunks_per_rank,
    choose_overlap,
    choose_tile_k,
    choose_tile_n,
    load_cache,
    measured_best,
    save_cache,
    tune_ce_ring,
    tune_ring_attention,
)
from repro.core.degrade import (
    DegradationPolicy,
    DegradeConfig,
    degrade_mode,
    get_degradation_policy,
    set_degradation_policy,
)
from repro.core.perfmodel import DCN, V5E, HardwareModel, MeshHardwareModel
from repro.core.calibrate import measured_calibration_pass
from repro.core.scheduling import (
    best_skew_rotation,
    modeled_execution_skew,
    modeled_finish_times,
    skew_statistic,
)
from repro.parallel.sharding import FusionConfig, ParallelContext

__all__ = [
    "FusionConfig",
    "ParallelContext",
    "matmul_allreduce",
    "allgather_matmul",
    "matmul_reducescatter",
    "allgather_seq",
    "moe_dispatch_all_to_all",
    "fused_expert_ffn_combine",
    "fused_dispatch_a2a",
    "fused_moe_kernel",
    "embedding_all_to_all",
    "sharded_cross_entropy",
    "ring_reduce_scatter_compute",
    "ring_all_gather_compute",
    "direct_all_to_all_compute",
    "attention_partial_merge",
    "feasible_chunks_per_rank",
    "all_gather_wire",
    "wire_cast",
    "wire_uncast",
    "DegradationPolicy",
    "DegradeConfig",
    "degrade_mode",
    "get_degradation_policy",
    "set_degradation_policy",
    "Decision",
    "choose_chunks_per_rank",
    "choose_overlap",
    "choose_tile_k",
    "choose_tile_n",
    "DCN",
    "V5E",
    "HardwareModel",
    "MeshHardwareModel",
    "load_cache",
    "measured_best",
    "measured_calibration_pass",
    "save_cache",
    "tune_ce_ring",
    "tune_ring_attention",
    "best_skew_rotation",
    "modeled_execution_skew",
    "modeled_finish_times",
    "skew_statistic",
]
