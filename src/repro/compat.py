"""Version tolerance for the jax API surface this repo targets.

The codebase is written against the current jax spelling (``jax.shard_map``
with ``check_vma``, ``jax.sharding.AxisType``, ``pltpu.CompilerParams``,
``lax.axis_size``).  Older jaxlibs (0.4.x) ship the same functionality
under earlier names; everything below resolves to the native symbol when
present and otherwise to the equivalent legacy one, so the rest of the
repo can import from here and stay version-agnostic.
"""
from __future__ import annotations

import jax
from jax import lax
from jax.experimental.pallas import tpu as pltpu

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
if not _HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the per-shard value-and-mesh check disabled.

    On jax >= 0.6 this is ``jax.shard_map(..., check_vma=...)``; earlier
    releases call it ``check_rep`` and live under ``jax.experimental``.
    """
    if _HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis from inside shard_map."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    # psum of a python literal is constant-folded to the axis size (int).
    return lax.psum(1, axis_name)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (renamed from ``TPUCompilerParams``)."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def tree_flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` (older: ``jax.tree_util``)."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


_BARRIER_DIFFERENTIABLE: bool | None = None


def _barrier_differentiable() -> bool:
    """Whether ``lax.optimization_barrier`` has a differentiation rule
    (absent on older jax; probed once with an abstract trace)."""
    global _BARRIER_DIFFERENTIABLE
    if _BARRIER_DIFFERENTIABLE is None:
        import jax.numpy as jnp

        try:
            jax.eval_shape(jax.grad(lambda x: lax.optimization_barrier(x)),
                           jnp.float32(0.0))
            _BARRIER_DIFFERENTIABLE = True
        except NotImplementedError:
            _BARRIER_DIFFERENTIABLE = False
    return _BARRIER_DIFFERENTIABLE


@jax.custom_vjp
def _barrier_vjp(x):
    return lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier_vjp(x), None


def _barrier_bwd(_, g):
    return (lax.optimization_barrier(g),)


_barrier_vjp.defvjp(_barrier_fwd, _barrier_bwd)


def optimization_barrier(x):
    """Differentiable ``lax.optimization_barrier``.

    New jax ships a native differentiation rule; older releases get a
    custom-vjp wrapper whose cotangent passes through its own barrier (the
    barrier is semantically the identity, so this is exact)."""
    if _barrier_differentiable():
        return lax.optimization_barrier(x)
    return _barrier_vjp(x)
