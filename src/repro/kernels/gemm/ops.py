"""Public GEMM wrapper: picks block sizes, pads ragged dims, jits."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.gemm.kernel import gemm_pallas


def _block(dim, pref):
    for b in (pref, 256, 128, 64, 32, 16, 8):
        if b <= pref and dim % b == 0:
            return b
    return dim


def gemm(x, w, *, bm=128, bn=128, bk=128):
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    return gemm_pallas(x, w, bm=bm, bn=bn, bk=bk, interpret=interpret_mode())
