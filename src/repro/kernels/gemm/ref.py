"""Pure-jnp oracle for the GEMM kernel."""
import jax.numpy as jnp


def gemm_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
