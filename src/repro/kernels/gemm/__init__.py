from repro.kernels.gemm.ops import gemm  # noqa: F401
