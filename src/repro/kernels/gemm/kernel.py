"""Tiled GEMM kernel (MXU-aligned, f32 VMEM accumulator).

The paper's GEMM+All-to-All uses a generic Triton GEMM; this is its
Pallas analogue, and the local-compute body reused by the fused kernels.
Grid is (M/bm, N/bn, K/bk) with the K dimension innermost so one (i, j)
output tile's accumulator lives in VMEM across the K loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm_pallas(x, w, *, bm=128, bn=128, bk=128, interpret=True):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bm, bn, bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
