"""Flattened-world shard_map plumbing for device-initiated kernels.

The CPU interpreter for ``pltpu.make_async_remote_copy`` can only
discharge a remote DMA when the enclosing shard_map names a *single*
mesh axis (the discharge gathers each device's target id over that one
axis).  On a real 2-D ``(data, model)`` mesh the kernels therefore run
their shard_map over a flattened 1-D view of the same devices — one
named world axis with the ring axis fastest-varying — and confine each
PUT ring to its row by logical-id arithmetic: world rank
``w = base + ring_pos`` with ``base = (w // ring) * ring``, so a PUT to
ring position ``dest`` targets logical id ``base + dest`` and never
leaves the row.  On TPU (Mosaic) none of this is needed: mesh-coordinate
device ids confine the ring to one axis natively, so the kernels keep
the multi-axis shard_map there.

The helpers below build the flattened mesh and move the MoE global
layouts (``[B, n_ep, E, C, D]`` activations, ``[E, ...]`` expert
weights) into/out of the world-major layout the single-axis in_specs
need.  They are validation-path plumbing — plain reshapes/transposes XLA
executes outside the kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

WORLD_AXIS = "kworld"


def needs_flat_world(mesh) -> bool:
    """True when the kernel path must run over the flattened 1-D view:
    interpret mode (CPU validation) on a multi-axis mesh."""
    from repro.kernels import interpret_mode

    return (interpret_mode() and mesh is not None
            and len(mesh.axis_names) > 1)


def flat_world_mesh(mesh, ring_axis: str) -> Mesh:
    """Single-named-axis view of ``mesh`` with ``ring_axis`` fastest-
    varying, so each contiguous group of ``mesh.shape[ring_axis]`` world
    ranks is one PUT-ring row."""
    names = [a for a in mesh.axis_names if a != ring_axis] + [ring_axis]
    perm = [mesh.axis_names.index(a) for a in names]
    devs = np.transpose(mesh.devices, perm).reshape(-1)
    return Mesh(devs, (WORLD_AXIS,))


def moe_to_world(x, rows: int, ring: int, *, b_sharded: bool):
    """``[B, n_ep, E, C, D]`` -> ``[W, B_loc, n_ep, E_loc, C, D]`` with
    dim 0 world-major (row-major over the ``rows`` data rows, ring
    position fastest).  ``b_sharded=False`` replicates the full batch
    into every row (the dp-indivisible case)."""
    b, n_ep, e, c, d = x.shape
    if b_sharded:
        x = x.reshape(rows, b // rows, n_ep, e, c, d)
    else:
        x = jnp.broadcast_to(x[None], (rows, b, n_ep, e, c, d))
    b_loc = x.shape[1]
    x = x.reshape(rows, b_loc, n_ep, ring, e // ring, c, d)
    x = jnp.transpose(x, (0, 3, 1, 2, 4, 5, 6))
    return x.reshape(rows * ring, b_loc, n_ep, e // ring, c, d)


def moe_from_world(y, rows: int, ring: int, *, b_sharded: bool):
    """Inverse of :func:`moe_to_world`.  In the replicated-batch case
    every row computed the same row-confined exchange, so row 0 is the
    answer."""
    w, b_loc, n_ep, e_loc, c, d = y.shape
    y = y.reshape(rows, ring, b_loc, n_ep, e_loc, c, d)
    y = jnp.transpose(y, (0, 2, 3, 1, 4, 5, 6))
    y = y.reshape(rows, b_loc, n_ep, ring * e_loc, c, d)
    if b_sharded:
        return y.reshape(rows * b_loc, n_ep, ring * e_loc, c, d)
    return y[0]


def weights_to_world(w, rows: int, ring: int):
    """``[E, ...]`` expert weights (ring-sharded on dim 0, replicated
    across rows) -> ``[W, E_loc, ...]`` world-major."""
    e = w.shape[0]
    w = w.reshape((ring, e // ring) + w.shape[1:])
    w = jnp.broadcast_to(w[None], (rows,) + w.shape)
    return w.reshape((rows * ring, e // ring) + w.shape[3:])
