from repro.kernels.fused_gemv_allreduce.ops import (  # noqa: F401
    fused_matmul_allreduce_kernel_available,
    fused_matmul_allreduce_shard,
    fused_matmul_allreduce,
)
