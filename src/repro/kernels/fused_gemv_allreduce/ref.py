"""Oracle for the fused GEMV/GEMM+AllReduce kernel.

Per-shard semantics: every ring rank holds x_r [B, K_loc], w_r [K_loc, N];
the fused kernel must return sum_r x_r @ w_r on every rank.  The oracle
computes that with plain jnp (given the gathered shards) and, under
shard_map, with lax.psum.
"""
import jax.numpy as jnp
from jax import lax


def fused_matmul_allreduce_ref_global(x_full, w_full):
    """x_full: [B, K_global]; w_full: [K_global, N] -> [B, N]."""
    return jnp.dot(x_full, w_full, preferred_element_type=jnp.float32
                   ).astype(x_full.dtype)


def fused_matmul_allreduce_ref_shard(xl, wl, axis_name):
    """Inside shard_map: bulk-synchronous baseline (matmul then psum)."""
    return lax.psum(jnp.dot(xl, wl, preferred_element_type=jnp.float32
                            ).astype(xl.dtype), axis_name)
