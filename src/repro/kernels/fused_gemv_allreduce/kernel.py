"""Device-initiated fused GEMV/GEMM + AllReduce (paper §III-B, Fig. 7).

This is the direct TPU analogue of the paper's flagship kernel:

* One Pallas kernel per chip both computes output tiles and communicates
  them — no kernel boundary between GEMM and collective.
* As soon as the tile destined for a peer is computed, it is PUT into
  that peer's reduction buffer with ``pltpu.make_async_remote_copy`` (the
  ROC_SHMEM non-blocking PUT analogue); all PUTs are in flight while the
  remaining tiles are still being computed.  DMA completion semaphores
  replace the paper's WG_Done bitmask / sliceRdy polling flags.
* Zero-copy: each remote write lands directly in the consumer's per-source
  reduction slot (phase 1) or directly in the consumer's *output ref*
  (phase 2) — no staging buffer or copy kernel on the receiver.
* Communication-aware schedule: remote tiles are computed farthest-peer-
  first; the locally-reduced tile is computed *last* (paper Fig. 7b),
  so local compute hides remote wire time.
* Two-phase direct AllReduce (the paper's choice for fully-connected
  scale-up nodes): phase 1 reduce-scatter via the PUTs above; phase 2
  each rank broadcasts its reduced tile straight into every peer's
  output.

Runs inside shard_map; ``device_id`` is the linearized mesh id, rings run
over the innermost mesh axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _fused_kernel(ids_ref, x_ref, w_ref, o_ref, tx_ref, rx_ref, acc_ref,
                  send_sem, recv_sem, bsend_sem, brecv_sem, *,
                  n_dev, comm_aware, barrier, axis_name, id_style):
    my = ids_ref[0]

    def dev_id(dest):
        if id_style == "mesh":
            return {axis_name: dest}, pltpu.DeviceIdType.MESH
        return dest, pltpu.DeviceIdType.LOGICAL
    b = x_ref.shape[0]
    n_total = w_ref.shape[1]
    bn = n_total // n_dev

    if barrier:
        # sync ring neighbours before touching symmetric buffers
        bsem = pltpu.get_barrier_semaphore()
        lid, lt = dev_id(lax.rem(my + n_dev - 1, n_dev))
        rid, rt = dev_id(lax.rem(my + 1, n_dev))
        pltpu.semaphore_signal(bsem, device_id=lid, device_id_type=lt)
        pltpu.semaphore_signal(bsem, device_id=rid, device_id_type=rt)
        pltpu.semaphore_wait(bsem, 2)

    def tile_partial(tile_idx):
        wt = w_ref[:, pl.ds(tile_idx * bn, bn)]
        return jnp.dot(x_ref[...], wt, preferred_element_type=jnp.float32)

    # ---- phase 1: compute + non-blocking PUT per remote tile -----------
    # (reduce-scatter fused into the GEMV/GEMM)
    offsets = (list(range(n_dev - 1, 0, -1)) if comm_aware
               else list(range(1, n_dev)))
    puts = []
    for off in offsets:
        dest = lax.rem(my + off, n_dev)
        did, dt = dev_id(dest)
        tx_ref[off - 1] = tile_partial(dest).astype(o_ref.dtype)
        copy = pltpu.make_async_remote_copy(
            src_ref=tx_ref.at[off - 1],
            dst_ref=rx_ref.at[my],           # per-source slot on the peer
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=did,
            device_id_type=dt,
        )
        copy.start()
        puts.append(copy)

    # own tile last: local compute hides the PUTs' wire time (Fig. 7b)
    acc_ref[...] = tile_partial(my)

    # sliceRdy analogue: the DMA recv semaphore counts peer contributions
    # (each wait_recv consumes one slot-sized arrival; slots are equal
    # sized so any descriptor of that size accounts one arrival)
    for c in puts:
        c.wait_recv()
    for s in range(n_dev):
        @pl.when(s != my)
        def _(s=s):
            acc_ref[...] += rx_ref[s].astype(jnp.float32)

    mine = acc_ref[...].astype(o_ref.dtype)
    o_ref[:, pl.ds(my * bn, bn)] = mine

    # ---- phase 2: broadcast reduced tile directly into peers' output ---
    bputs = []
    for off in range(1, n_dev):
        dest = lax.rem(my + off, n_dev)
        did, dt = dev_id(dest)
        copy = pltpu.make_async_remote_copy(
            src_ref=o_ref.at[:, pl.ds(my * bn, bn)],
            dst_ref=o_ref.at[:, pl.ds(my * bn, bn)],   # same slice on peer
            send_sem=bsend_sem,
            recv_sem=brecv_sem,
            device_id=did,
            device_id_type=dt,
        )
        copy.start()
        bputs.append(copy)
    for c in puts:
        c.wait_send()                        # phase-1 sends drained
    for c in bputs:
        c.wait_send()
        c.wait_recv()                        # all peers' tiles landed


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "comm_aware", "collective_id",
                                    "barrier", "interpret", "axis_name",
                                    "id_style"))
def fused_matmul_allreduce_pallas(x, w, my_tp, *, n_dev, axis_name,
                                  comm_aware=True, collective_id=7,
                                  barrier=False, interpret=True,
                                  id_style=None):
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    """Per-shard fused GEMV/GEMM+AllReduce.

    x: [B, K_loc]; w: [K_loc, N]; my_tp: int32 scalar (position on the
    ring axis ``axis_name``).  Returns [B, N] fully reduced.
    """
    b, k = x.shape
    n = w.shape[1]
    assert n % n_dev == 0, (n, n_dev)
    bn = n // n_dev
    kernel = functools.partial(_fused_kernel, n_dev=n_dev,
                               comm_aware=comm_aware, barrier=barrier,
                               axis_name=axis_name, id_style=id_style)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
            pl.BlockSpec((k, n), lambda i, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, n), lambda i, s: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_dev - 1, b, bn), x.dtype),  # tx staging (per PUT)
            pltpu.VMEM((n_dev, b, bn), x.dtype),      # rx slots (per source)
            pltpu.VMEM((b, bn), jnp.float32),         # reduction accumulator
            pltpu.SemaphoreType.DMA,                  # send
            pltpu.SemaphoreType.DMA,                  # recv
            pltpu.SemaphoreType.DMA,                  # bcast send
            pltpu.SemaphoreType.DMA,                  # bcast recv
        ],
    )
    ids = jnp.stack([my_tp.astype(jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(ids, x, w)
