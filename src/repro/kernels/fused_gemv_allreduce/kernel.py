"""Device-initiated fused GEMV/GEMM + AllReduce (paper §III-B, Fig. 7).

This is the direct TPU analogue of the paper's flagship kernel, rebuilt
as a **tile-granular pipeline** (T3-style track-&-trigger at output-tile
granularity):

* The kernel runs a multi-step grid over (output tile, K panel) pairs.
  ``w`` stays in HBM; each step's ``[tile_k, tile_n]`` weight panel is
  streamed into a VMEM double buffer one step ahead of its use, so VMEM
  holds two panels — not the whole operand and not even a whole
  ``[K, tile_n]`` column strip.  ``N x K`` may exceed VMEM by an
  arbitrary factor in *both* dimensions: ``tile_n`` bounds the output
  width, ``tile_k`` bounds the contraction depth.  Partial products are
  accumulated in a f32 VMEM scratch across K panels; the final K panel
  may be *ragged* (``K % tile_k != 0``) — its copy descriptor and matmul
  are sized to the remainder.
* As soon as a tile's accumulation over its last K panel completes, it
  is PUT into the owning peer's reduction buffer with
  ``pltpu.make_async_remote_copy`` (the ROC_SHMEM non-blocking PUT
  analogue); HBM DMA-in, MXU compute, and remote DMA-out of different
  tiles are all in flight simultaneously.  DMA completion semaphores
  replace the paper's WG_Done bitmask / sliceRdy polling flags.
* Zero-copy: each remote write lands directly in the consumer's
  per-source reduction slot (phase 1) or directly in the consumer's
  *output ref* (phase 2) — no staging buffer or copy kernel on the
  receiver.
* Communication-aware schedule: remote tiles are computed farthest-peer-
  first; the locally-reduced tiles are computed *last* (paper Fig. 7b),
  so local compute hides remote wire time.  The per-rank chunk is further
  split into ``tiles_per_rank`` sub-tiles — the kernel-level face of the
  ``chunks_per_rank`` granularity knob (paper Fig. 13); ``tile_n`` /
  ``tile_k`` are picked by :func:`repro.core.autotune.choose_tile_n` /
  :func:`repro.core.autotune.choose_tile_k` when not pinned.
* Two-phase direct AllReduce (the paper's choice for fully-connected
  scale-up nodes): phase 1 reduce-scatter via the PUTs above; phase 2
  each rank broadcasts its reduced chunk straight into every peer's
  output.

Runs inside shard_map; ``device_id`` is the linearized mesh id, rings run
over the innermost mesh axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.autotune import choose_tile_k, choose_tile_n, feasible_tile
from repro.kernels.tile_pipeline import (ANY, drain, neighbor_barrier,
                                         remote_tile_put, step_schedule,
                                         stream_tile_copy)


def _fused_kernel(ids_ref, x_ref, w_hbm, o_ref,
                  w_slots, w_sems, kacc_ref, tx_ref, rx_ref, acc_ref,
                  send_sem, recv_sem, bsend_sem, brecv_sem, *,
                  n_dev, tiles_per_rank, tile_n, tile_k, k_panels, k_rem,
                  barrier, axis_name, id_style):
    my = ids_ref[0]
    i = pl.program_id(0)
    num_tiles = n_dev * tiles_per_rank
    num_steps = num_tiles * k_panels
    bn = tiles_per_rank * tile_n
    ragged = k_rem != tile_k
    # schedule rides in the prefetch operand: ids = [my | offs | subs],
    # indexed by the *tile* a step belongs to
    step_off = lambda t: ids_ref[1 + t]
    step_sub = lambda t: ids_ref[1 + num_tiles + t]

    def wdma(step, last_panel: bool):
        """HBM→VMEM copy descriptor for one [tile_k, tile_n] weight panel.

        ``last_panel`` selects the statically-sized ragged descriptor for
        the final K panel; wait descriptors must rebuild the same variant
        (DMA semaphores account by bytes)."""
        t = lax.div(step, k_panels)
        p = lax.rem(step, k_panels)
        slot = lax.rem(step, 2)
        dest = lax.rem(my + step_off(t), n_dev)
        col = dest * bn + step_sub(t) * tile_n
        if last_panel and ragged:
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds((k_panels - 1) * tile_k, k_rem),
                         pl.ds(col, tile_n)],
                w_slots.at[slot, pl.ds(0, k_rem)],
                w_sems.at[slot],
            )
        return stream_tile_copy(w_hbm, w_slots, w_sems, slot,
                                col, tile_n, row_start=p * tile_k,
                                rows=tile_k)

    def start(step):
        """Start ``step``'s panel copy (ragged-aware when K is ragged)."""
        if not ragged:
            wdma(step, False).start()
            return
        p = lax.rem(step, k_panels)

        @pl.when(p == k_panels - 1)
        def _():
            wdma(step, True).start()

        @pl.when(p != k_panels - 1)
        def _():
            wdma(step, False).start()

    @pl.when(i == 0)
    def _():
        if barrier:
            # sync ring neighbours before touching symmetric buffers
            neighbor_barrier(my, n_dev, axis_name, id_style)
        # step 0 is panel 0 of the first tile — ragged only if k_panels==1,
        # which implies tile_k == K and k_rem == tile_k (never ragged)
        wdma(jnp.int32(0), False).start()

    @pl.when(i + 1 < num_steps)
    def _():
        start(i + 1)

    # ---- K-panel pipeline: wait panel in, matmul, accumulate ----------
    p = lax.rem(i, k_panels)
    slot = lax.rem(i, 2)

    def accumulate(partial):
        @pl.when(p == 0)
        def _():
            kacc_ref[...] = partial

        @pl.when(p != 0)
        def _():
            kacc_ref[...] += partial

    if not ragged:
        wdma(i, False).wait()
        accumulate(jnp.dot(x_ref[:, pl.ds(p * tile_k, tile_k)],
                           w_slots[slot],
                           preferred_element_type=jnp.float32))
    else:
        @pl.when(p == k_panels - 1)
        def _():
            wdma(i, True).wait()
            accumulate(jnp.dot(
                x_ref[:, pl.ds((k_panels - 1) * tile_k, k_rem)],
                w_slots[slot, pl.ds(0, k_rem)],
                preferred_element_type=jnp.float32))

        @pl.when(p != k_panels - 1)
        def _():
            wdma(i, False).wait()
            accumulate(jnp.dot(x_ref[:, pl.ds(p * tile_k, tile_k)],
                               w_slots[slot],
                               preferred_element_type=jnp.float32))

    # ---- last K panel of a tile: trigger PUT / place own tile ---------
    t = lax.div(i, k_panels)
    off = step_off(t)
    sub = step_sub(t)
    dest = lax.rem(my + off, n_dev)

    @pl.when((p == k_panels - 1) & (off != 0))
    def _():
        # remote tile: stage in wire dtype, PUT into the peer's per-source
        # slot the moment the accumulation finishes (phase-1 RS)
        tx_ref[t] = kacc_ref[...].astype(tx_ref.dtype)
        remote_tile_put(
            tx_ref.at[t],
            rx_ref.at[my, :, pl.ds(sub * tile_n, tile_n)],
            send_sem, recv_sem, dest, axis_name, id_style,
        ).start()

    @pl.when((p == k_panels - 1) & (off == 0))
    def _():
        # own tiles last: local compute hides the PUTs' wire time (Fig. 7b)
        acc_ref[:, pl.ds(sub * tile_n, tile_n)] = kacc_ref[...]

    # ---- final step: reduce arrivals, write own chunk, broadcast -------
    @pl.when(i == num_steps - 1)
    def _():
        n_remote = (n_dev - 1) * tiles_per_rank
        # sliceRdy analogue: the DMA recv semaphore counts tile arrivals
        # (uniform tile size, so any descriptor of that size accounts one)
        drain(lambda: remote_tile_put(
            tx_ref.at[0], rx_ref.at[0, :, pl.ds(0, tile_n)],
            send_sem, recv_sem, my, axis_name, id_style),
            n_remote, recv=True)
        for s in range(n_dev):
            @pl.when(s != my)
            def _(s=s):
                acc_ref[...] += rx_ref[s].astype(jnp.float32)
        o_ref[:, pl.ds(my * bn, bn)] = acc_ref[...].astype(o_ref.dtype)

        # phase 2: broadcast reduced chunk directly into peers' output
        def bput(dst):
            return remote_tile_put(
                o_ref.at[:, pl.ds(my * bn, bn)],
                o_ref.at[:, pl.ds(my * bn, bn)],   # same slice on peer
                bsend_sem, brecv_sem, dst, axis_name, id_style)

        for off2 in range(1, n_dev):
            bput(lax.rem(my + off2, n_dev)).start()
        drain(lambda: remote_tile_put(
            tx_ref.at[0], rx_ref.at[0, :, pl.ds(0, tile_n)],
            send_sem, recv_sem, my, axis_name, id_style),
            n_remote, recv=False)              # phase-1 sends drained
        drain(lambda: bput(my), n_dev - 1, recv=False)
        drain(lambda: bput(my), n_dev - 1, recv=True)  # peers' chunks in


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "comm_aware", "collective_id",
                                    "barrier", "interpret", "axis_name",
                                    "id_style", "tile_n", "tile_k",
                                    "vmem_budget_bytes", "wire"))
def fused_matmul_allreduce_pallas(x, w, my_tp, *, n_dev, axis_name,
                                  comm_aware=True, collective_id=7,
                                  barrier=False, interpret=True,
                                  id_style=None, tile_n=None, tile_k=None,
                                  vmem_budget_bytes=8 << 20, wire="f32"):
    """Per-shard tile-pipelined fused GEMV/GEMM+AllReduce.

    x: [B, K_loc]; w: [K_loc, N]; my_tp: int32 scalar (position on the
    ring axis ``axis_name``).  Returns [B, N] fully reduced.

    ``tile_n`` is the output-tile width of the pipeline (the granularity
    knob): ``None`` lets the autotuner size it against the VMEM budget;
    any requested value is clamped to the largest divisor of the per-rank
    chunk ``N // n_dev`` so tiles stay uniform.  ``tile_k`` is the
    contraction-panel depth: ``None`` sizes it so two ``[tile_k, tile_n]``
    panels plus the fixed buffers fit ``vmem_budget_bytes``; it need not
    divide ``K`` — the final panel is ragged.

    ``wire`` is the phase-1 PUT payload dtype: ``"bf16"`` stages the
    finished tiles (already f32-accumulated in the K-panel scratch) in
    bf16 tx/rx buffers so the remote DMA moves half the bytes; the
    receive-side reduction still runs in f32.  The kernel path supports
    ``{"f32", "bf16"}`` — the fp8 per-chunk-scale format is an XLA-path
    feature (callers clamp).  The phase-2 broadcast ships final outputs
    and stays at the output dtype.
    """
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    if wire not in ("f32", "bf16"):
        raise ValueError(f"kernel wire dtype must be 'f32' or 'bf16', "
                         f"got {wire!r}")
    b, k = x.shape
    n = w.shape[1]
    assert n % n_dev == 0, (n, n_dev)
    bn = n // n_dev
    # "f32" = uncompressed: the PUT payload travels at the compute dtype
    wire_dt = (jnp.bfloat16 if wire == "bf16"
               and x.dtype.itemsize > 2 else x.dtype)
    if tile_n is None:
        tile_n = choose_tile_n(b, k, n, n_dev=n_dev,
                               dtype_bytes=x.dtype.itemsize,
                               vmem_budget_bytes=vmem_budget_bytes)
    tile_n = feasible_tile(bn, tile_n)
    if tile_k is None:
        tile_k = choose_tile_k(b, k, n, tile_n, n_dev=n_dev,
                               dtype_bytes=x.dtype.itemsize,
                               vmem_budget_bytes=vmem_budget_bytes)
    tile_k = max(1, min(int(tile_k), k))
    k_panels = -(-k // tile_k)
    k_rem = k - (k_panels - 1) * tile_k
    tiles_per_rank = bn // tile_n
    num_tiles = n_dev * tiles_per_rank

    # the schedule itself rides in the prefetched ids (step_schedule below);
    # the kernel body is schedule-agnostic
    kernel = functools.partial(_fused_kernel, n_dev=n_dev,
                               tiles_per_rank=tiles_per_rank, tile_n=tile_n,
                               tile_k=tile_k, k_panels=k_panels, k_rem=k_rem,
                               barrier=barrier,
                               axis_name=axis_name, id_style=id_style)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_tiles * k_panels,),
        in_specs=[
            pl.BlockSpec((b, k), lambda i, s: (0, 0)),
            pl.BlockSpec(memory_space=ANY),           # w stays in HBM
        ],
        out_specs=pl.BlockSpec((b, n), lambda i, s: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, tile_k, tile_n), w.dtype),  # streamed w panels
            pltpu.SemaphoreType.DMA((2,)),            # panel double buffer
            pltpu.VMEM((b, tile_n), jnp.float32),     # K-panel accumulator
            # tx staging: remote tiles only — the schedule puts the own
            # (non-staged) tiles last, so remote tiles are t < n_remote.
            # Staged (and received) in the wire dtype: the PUT moves
            # wire-width bytes, the reduction upcasts to f32
            pltpu.VMEM((max((n_dev - 1) * tiles_per_rank, 1), b, tile_n),
                       wire_dt),
            pltpu.VMEM((n_dev, b, bn), wire_dt),      # rx slots (per source)
            pltpu.VMEM((b, bn), jnp.float32),         # reduction accumulator
            pltpu.SemaphoreType.DMA,                  # send
            pltpu.SemaphoreType.DMA,                  # recv
            pltpu.SemaphoreType.DMA,                  # bcast send
            pltpu.SemaphoreType.DMA,                  # bcast recv
        ],
    )
    step_off, step_sub = step_schedule(n_dev, tiles_per_rank, comm_aware)
    ids = jnp.concatenate([
        my_tp.astype(jnp.int32)[None],
        jnp.asarray(step_off + step_sub, jnp.int32),
    ])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(ids, x, w)
