"""Wrappers for the device-initiated fused GEMV/GEMM+AllReduce kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels import interpret_mode
from repro.kernels.fused_gemv_allreduce.kernel import fused_matmul_allreduce_pallas
from repro.parallel.sharding import ParallelContext
from repro.compat import axis_size, shard_map


def fused_matmul_allreduce_kernel_available(mesh=None) -> bool:
    """Mosaic on TPU supports any mesh; the CPU *interpreter* can only
    discharge remote DMAs under a single-named-axis mesh (validation runs
    use a 1D mesh; the production path on CPU falls back to the XLA
    decomposed fusion)."""
    if not interpret_mode():
        return True
    return mesh is not None and len(mesh.axis_names) == 1


def fused_matmul_allreduce_shard(xl, wl, axis, *, comm_aware=True,
                                 tile_n=None, tile_k=None,
                                 vmem_budget_bytes=8 << 20, wire="f32"):
    """Call inside shard_map.  xl: [rows_loc, K_loc]; wl: [K_loc, N].
    The PUT ring runs over mesh axis ``axis``.  ``tile_n`` pins the
    pipeline's output-tile width and ``tile_k`` its contraction-panel
    depth (None = autotuned from the VMEM budget; ``tile_k`` may leave a
    ragged final K panel).  ``wire`` compresses the phase-1 PUT payload
    (kernel path supports f32/bf16; fp8 is clamped to bf16 — the
    per-chunk-scale format is an XLA-path feature)."""
    n_dev = axis_size(axis)
    my = lax.axis_index(axis)
    wire = "bf16" if wire == "fp8" else wire
    return fused_matmul_allreduce_pallas(
        xl, wl, my, n_dev=n_dev, axis_name=axis, comm_aware=comm_aware,
        interpret=interpret_mode(), tile_n=tile_n, tile_k=tile_k,
        vmem_budget_bytes=vmem_budget_bytes, wire=wire)


def fused_matmul_allreduce(ctx: ParallelContext, x, w, *, comm_aware=True,
                           tile_n=None, tile_k=None,
                           vmem_budget_bytes=8 << 20, wire="f32"):
    """Standalone global-array entry (tests/benchmarks).

    x: [..., K] K sharded over tp; w: [K, N] row-sharded -> [..., N]."""
    lead = x.shape[:-1]
    xf = x.reshape((-1, x.shape[-1]))
    rows = xf.shape[0]
    dp = ctx.batch_axes if rows % ctx.dp == 0 else None

    def local_fn(xl, wl):
        return fused_matmul_allreduce_shard(
            xl, wl, ctx.tp_axis, comm_aware=comm_aware, tile_n=tile_n,
            tile_k=tile_k, vmem_budget_bytes=vmem_budget_bytes, wire=wire)

    yf = shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, ctx.tp_axis), P(ctx.tp_axis, None)),
        out_specs=P(dp, None),
        check_vma=False,
    )(xf, w)
    return yf.reshape(lead + (w.shape[1],))
