"""Pure-jnp oracle for the embedding-pool kernel."""
import jax.numpy as jnp


def embedding_pool_ref(table, idx):
    return jnp.take(table, idx, axis=0).mean(axis=1).astype(table.dtype)
