"""Embedding-bag pooling kernel (DLRM EmbeddingBag sum/mean, paper §III-A).

The table stays in HBM; the categorical indices are scalar-prefetched and
drive the table BlockSpec's index_map, so each grid step DMAs exactly the
embedding row it needs into VMEM — the TPU idiom for gather.  Grid is
(batch, L); the bag accumulator for one output row lives in VMEM across
the L loop and is scaled to the mean on the last lookup.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _pool_kernel(idx_ref, row_ref, o_ref, acc_ref):
    ll = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(ll == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(ll == n_l - 1)
    def _():
        o_ref[...] = (acc_ref[...] / n_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_pool_pallas(table, idx, *, interpret=True):
    """table: [V, D]; idx: [B, L] int32 -> mean-pooled [B, D]."""
    v, d = table.shape
    b, L = idx.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, L),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, l, idx_ref: (idx_ref[i, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, l, idx_ref: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        _pool_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(idx, table)
