from repro.kernels.embedding_pool.ops import embedding_pool  # noqa: F401
