"""Public embedding-pool wrapper."""
from __future__ import annotations

from repro.kernels import interpret_mode
from repro.kernels.embedding_pool.kernel import embedding_pool_pallas


def embedding_pool(table, idx):
    """table: [V, D]; idx: [B, L] -> [B, D] mean-pooled bags."""
    return embedding_pool_pallas(table, idx, interpret=interpret_mode())
