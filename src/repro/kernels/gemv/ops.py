"""Public GEMV wrapper."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.gemv.kernel import gemv_pallas


def _block(dim, pref):
    for b in (pref, 512, 256, 128, 64, 32, 16, 8):
        if b <= pref and dim % b == 0:
            return b
    return dim


def gemv(x, w, *, bn=256, bk=512):
    """x: [K] or [B, K] small-batch; w: [K, N]."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    k, n = w.shape
    out = gemv_pallas(x, w, bn=_block(n, bn), bk=_block(k, bk),
                      interpret=interpret_mode())
    return out[0] if squeeze else out
