from repro.kernels.gemv.ops import gemv  # noqa: F401
