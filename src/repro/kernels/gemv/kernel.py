"""Tiled GEMV kernel (token-phase inference matvec, paper §III-B).

y[n] = sum_k x[k] W[k, n].  Grid is (N/bn, K/bk); each output tile's f32
partial accumulates in VMEM across the K loop — the workgroup-per-output-
tile decomposition the paper's fused GEMV+AllReduce builds on.  x is kept
2D [1, K] (TPU lanes want >= 2D operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _gemv_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gemv_pallas(x, w, *, bn=256, bk=512, interpret=True):
    (b, k), (k2, n) = x.shape, w.shape
    assert k == k2 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, bn, bk)
    return pl.pallas_call(
        _gemv_kernel,
        grid=(n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((b, bk), lambda j, l: (0, l)),
            pl.BlockSpec((bk, bn), lambda j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j, l: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((b, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
