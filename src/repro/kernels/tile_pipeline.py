"""Tile-granular pipeline building blocks for device-initiated kernels.

Shared by the pipelined fused GEMV/GEMM+AllReduce and GEMM+All-to-All
kernels (and reusable by future fused ops).  Three concerns:

* **Weight/activation streaming** — double-buffered HBM→VMEM copies so a
  multi-step grid never stages more than two tiles of a large operand in
  VMEM (removes the whole-operand VMEM capacity cliff of single-shot
  kernels).
* **Remote tile PUTs** — ``pltpu.make_async_remote_copy`` wrappers that
  ship one output tile to a peer the moment its accumulation completes
  (the paper's per-slice RDMA PUT; T3's track-&-trigger unit is likewise
  the output tile).
* **Semaphore bookkeeping** — DMA waits are issued by *descriptor*, so a
  later grid step can drain copies started by earlier steps (grid steps
  share one traced body; python copy objects do not persist across steps,
  matching sizes do).

All helpers are shape-polymorphic over the tile layout; the comm-aware
offset order comes from :mod:`repro.core.scheduling` so XLA-level and
device-initiated paths share one schedule definition.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ANY = getattr(pltpu, "ANY", None)
if ANY is None:  # older spelling
    ANY = pltpu.TPUMemorySpace.ANY


def device_id_pair(dest, axis_name: str, id_style: str):
    """(device_id, device_id_type) for a remote copy to ring position
    ``dest`` — mesh-coordinate style on real meshes, logical in the
    single-axis interpreter."""
    if id_style == "mesh":
        return {axis_name: dest}, pltpu.DeviceIdType.MESH
    return dest, pltpu.DeviceIdType.LOGICAL


def neighbor_barrier(my, n_dev: int, axis_name: str, id_style: str):
    """Sync both ring neighbours before touching symmetric buffers."""
    bsem = pltpu.get_barrier_semaphore()
    for nb in (lax.rem(my + n_dev - 1, n_dev), lax.rem(my + 1, n_dev)):
        did, dt = device_id_pair(nb, axis_name, id_style)
        pltpu.semaphore_signal(bsem, device_id=did, device_id_type=dt)
    pltpu.semaphore_wait(bsem, 2)


def stream_tile_copy(hbm_ref, vmem_slots, sems, slot, col_start, tile_n,
                     row_start=None, rows=None):
    """Descriptor for one HBM→VMEM panel copy into a double-buffer slot.

    With ``row_start``/``rows`` unset the panel spans every row (the
    ``[K, tile_n]`` column strip); setting them streams a
    ``[rows, tile_n]`` sub-panel — the K-dim streaming used by the
    contraction-tiled kernels.  Start it one step ahead; wait with an
    identical descriptor."""
    if row_start is None:
        src = hbm_ref.at[:, pl.ds(col_start, tile_n)]
    else:
        src = hbm_ref.at[pl.ds(row_start, rows), pl.ds(col_start, tile_n)]
    return pltpu.make_async_copy(
        src,
        vmem_slots.at[slot],
        sems.at[slot],
    )


def stream_block_copy(hbm_ref, vmem_slots, sems, slot, index):
    """Descriptor for one HBM→VMEM leading-dim block copy into a double
    buffer slot (the A2A kernels stream per-destination token blocks)."""
    return pltpu.make_async_copy(
        hbm_ref.at[index],
        vmem_slots.at[slot],
        sems.at[slot],
    )


def remote_tile_put(src_ref, dst_ref, send_sem, recv_sem, dest,
                    axis_name: str, id_style: str):
    """Non-blocking PUT of one finished output tile into a peer buffer."""
    did, dt = device_id_pair(dest, axis_name, id_style)
    return pltpu.make_async_remote_copy(
        src_ref=src_ref,
        dst_ref=dst_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=did,
        device_id_type=dt,
    )


def drain(descriptor_fn, count: int, *, recv: bool):
    """Wait for ``count`` same-sized remote-copy completions.

    ``descriptor_fn()`` must rebuild a copy descriptor whose src/dst size
    matches the in-flight transfers; DMA semaphores account by bytes, so
    any descriptor of that size retires one arrival/send."""
    for _ in range(count):
        c = descriptor_fn()
        if recv:
            c.wait_recv()
        else:
            c.wait_send()


def step_schedule(n_dev: int, tiles_per_rank: int, comm_aware: bool,
                  skew: int = 0):
    """Static per-grid-step (offset, sub-tile) lists.

    Remote tiles first — farthest peer first under comm-aware scheduling
    (paper Fig. 7b), natural order otherwise — and the locally-reduced
    tiles always last, so local compute hides remote wire time.  ``skew``
    rotates the remote portion of the offset order by the measured
    straggler bucket (Fig. 14), mirroring
    :func:`repro.core.scheduling.ring_offsets`; the local tiles keep
    their final position so the remote-ahead-of-local rule (and the
    kernels' tx-slot indexing, which relies on remote steps preceding the
    local one) is preserved.  The lists are meant to ride in the
    scalar-prefetch operand (a Pallas kernel body cannot capture array
    constants), indexed by the traced ``program_id``.
    """
    offs = (list(range(n_dev - 1, 0, -1)) if comm_aware
            else list(range(1, n_dev))) + [0]
    if skew and n_dev > 1:
        remote = offs[:-1]
        r = skew % len(remote)
        offs = remote[r:] + remote[:r] + [0]
    step_off = []
    step_sub = []
    for off in offs:
        for sub in range(tiles_per_rank):
            step_off.append(off)
            step_sub.append(sub)
    return step_off, step_sub
