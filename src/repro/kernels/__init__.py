"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel package ships three modules:
  kernel.py - pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    - jit'd public wrapper (shape checks, dtype policy, vmap rules)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

``interpret=True`` (CPU) is used for validation; on TPU the same calls
lower to Mosaic.  The fused_* kernels use device-initiated remote DMA
(pltpu.make_async_remote_copy) — the TPU analogue of the paper's
GPU-initiated RDMA PUTs.

The fused kernels are *tile-granular pipelines* built on
``repro.kernels.tile_pipeline``: a multi-step grid streams operand
panels HBM→VMEM through a double buffer and PUTs each output tile to its
peer the moment the tile's accumulation completes, so DMA-in, MXU
compute, and remote DMA-out overlap.  Tile width (and the XLA-level
``chunks_per_rank`` sibling knob, see ``FusionConfig.granularity``) is
picked by the shape-keyed autotuner in ``repro.core.autotune``.
"""


def interpret_mode() -> bool:
    import jax

    return jax.default_backend() != "tpu"
