"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel package ships three modules:
  kernel.py - pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    - jit'd public wrapper (shape checks, dtype policy, vmap rules)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

``interpret=True`` (CPU) is used for validation; on TPU the same calls
lower to Mosaic.  The fused_* kernels use device-initiated remote DMA
(pltpu.make_async_remote_copy) — the TPU analogue of the paper's
GPU-initiated RDMA PUTs.

The fused kernels are *tile-granular pipelines* built on
``repro.kernels.tile_pipeline``: a multi-step grid streams operand
panels HBM→VMEM through a double buffer and PUTs each output tile to its
peer the moment the tile's accumulation completes, so DMA-in, MXU
compute, and remote DMA-out overlap.  Tile width (and the XLA-level
``chunks_per_rank`` sibling knob, see ``FusionConfig.granularity``) is
picked by the shape-keyed autotuner in ``repro.core.autotune``.
"""


def interpret_mode() -> bool:
    import jax

    return jax.default_backend() != "tpu"


_FP8_CLAMP_WARNED: set = set()


def clamp_kernel_wire(wire: str, op: str) -> str:
    """Device-initiated kernels stage PUT payloads at the wire dtype but
    have no per-chunk-scale path, so ``"fp8"`` is clamped to ``"bf16"``.
    Warns once per op family so ``--wire fp8`` users see the clamp instead
    of silently reading bf16 decisions out of the tune cache."""
    if wire != "fp8":
        return wire
    if op not in _FP8_CLAMP_WARNED:
        _FP8_CLAMP_WARNED.add(op)
        import warnings

        warnings.warn(
            f"{op}: wire='fp8' is an XLA-path feature (per-chunk scale); "
            f"the device-initiated kernel clamps the PUT payload to bf16",
            stacklevel=3)
    return "bf16"
