"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each kernel package ships three modules:
  kernel.py - pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    - jit'd public wrapper (shape checks, dtype policy, vmap rules)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

``interpret=True`` (CPU) is used for validation; on TPU the same calls
lower to Mosaic.  The fused_* kernels use device-initiated remote DMA
(pltpu.make_async_remote_copy) — the TPU analogue of the paper's
GPU-initiated RDMA PUTs.
"""


def interpret_mode() -> bool:
    import jax

    return jax.default_backend() != "tpu"
