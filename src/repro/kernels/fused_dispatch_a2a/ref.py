"""Oracle for the device-initiated dispatch All-to-All kernel.

Per-shard semantics: every EP rank holds routed token blocks
``xt [n, B, E, C, D]`` stacked by *destination* rank; the kernel must
return the blocks *sent to this rank by every source* — a pure bulk
All-to-All over the leading dim (the dispatch moves data only; the
expert FFN happens on the receiving side).
"""
from __future__ import annotations

from jax import lax


def fused_dispatch_a2a_ref_shard(xt, axis_name):
    """Inside shard_map: bulk-synchronous dispatch exchange."""
    return lax.all_to_all(xt, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)
