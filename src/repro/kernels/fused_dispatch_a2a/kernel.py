"""Device-initiated dispatch-side All-to-All (paper §III + CommFuse).

The dispatch A2A ships each destination rank's capacity chunk of routed
tokens; the XLA combinator path (``moe_dispatch_all_to_all``) decomposes
it into per-peer collective-permutes, but the payload still round-trips
through HBM before the expert FFN can start.  This kernel is the
device-initiated sibling: per-destination token blocks are streamed from
HBM through a VMEM double buffer and every ``chunks_per_rank`` sub-chunk
of the capacity axis is PUT to its peer the moment it is resolved —
CommFuse's sub-collective decomposition of the routing tail, with T3's
producer-tile trigger replaced by DMA completion semaphores.

* Multi-step grid over ``(destination, sub-chunk)`` pairs in comm-aware
  order (farthest peer first, locally-consumed block last; ``skew``
  rotates the remote order by the measured straggler bucket).
* PUTs land directly in the peer's output slot for this source rank —
  the ``[n_dev, B, E_loc, C, D]`` by-source slot layout the FFN+combine
  kernel (:mod:`repro.kernels.fused_gemm_a2a`) streams its input from,
  so the chained form never re-materializes the exchange through XLA.
* ``wire="bf16"`` stages each sub-chunk in a bf16 tx buffer and receives
  into a bf16 rx staging ref upcast at the end (half the remote bytes at
  the cost of the receive-side zero-copy), like the other two kernels.
* Ring confinement on a flattened multi-axis mesh is by logical-id
  arithmetic: peer id = ``ring_base + dest`` (see
  :mod:`repro.kernels.flatmesh`).

Runs inside shard_map over the expert-parallel axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.tile_pipeline import (ANY, drain, remote_tile_put,
                                         step_schedule, stream_block_copy)


def _dispatch_a2a_kernel(ids_ref, x_hbm, o_ref, x_slots, x_sems, tx_ref,
                         rx_ref, send_sem, recv_sem, *, n_dev, q, sub,
                         axis_name, id_style, use_rx):
    my = ids_ref[0]
    base = ids_ref[1]
    i = pl.program_id(0)
    n_steps = n_dev * q
    step_off = lambda s: ids_ref[2 + s]
    blk = i // q                       # dest-block counter (q subs per dest)
    s_i = lax.rem(i, q)

    def xdma(block, slot):
        dest = lax.rem(my + step_off(block * q), n_dev)
        return stream_block_copy(x_hbm, x_slots, x_sems, slot, dest)

    @pl.when(i == 0)
    def _():
        xdma(0, 0).start()

    @pl.when((s_i == 0) & (i + q < n_steps))
    def _():
        # prefetch the next destination's block while this one drains
        xdma(blk + 1, lax.rem(blk + 1, 2)).start()

    @pl.when(s_i == 0)
    def _():
        xdma(blk, lax.rem(blk, 2)).wait()

    off = step_off(i)
    dest = lax.rem(my + off, n_dev)
    c0 = s_i * sub
    xs = x_slots[lax.rem(blk, 2)]                     # [B, E, C, D]
    chunk = lax.dynamic_slice_in_dim(xs, c0, sub, axis=2)

    # receive target: the output ref itself (zero-copy) at the exact wire,
    # a wire-dtype rx staging ref otherwise (upcast at the end)
    recv_ref = rx_ref if use_rx else o_ref

    @pl.when(off != 0)
    def _():
        # resolved sub-chunk: PUT straight into the peer's slot for this
        # source rank (data lands in the combine kernel's by-source slot
        # layout; no receive-side shuffle).  Remote steps precede the
        # local block, so i indexes the tx staging directly.
        tx_ref[i] = chunk.astype(tx_ref.dtype)
        remote_tile_put(tx_ref.at[i],
                        recv_ref.at[my, :, :, pl.ds(c0, sub)],
                        send_sem, recv_sem, base + dest, axis_name,
                        id_style).start()

    @pl.when(off == 0)
    def _():
        o_ref[my, :, :, pl.ds(c0, sub)] = chunk

    @pl.when(i == n_steps - 1)
    def _():
        def desc():
            return remote_tile_put(tx_ref.at[0],
                                   recv_ref.at[0, :, :, pl.ds(0, sub)],
                                   send_sem, recv_sem, base + my, axis_name,
                                   id_style)

        drain(desc, (n_dev - 1) * q, recv=True)   # peers' chunks landed
        drain(desc, (n_dev - 1) * q, recv=False)  # our PUTs drained
        if use_rx:
            for src in range(n_dev):
                @pl.when(src != my)
                def _(src=src):
                    o_ref[src] = rx_ref[src].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "comm_aware", "chunks_per_rank",
                                    "skew", "collective_id", "interpret",
                                    "axis_name", "id_style", "wire"))
def fused_dispatch_a2a_pallas(xt, my_ep, ring_base, *, n_dev, axis_name,
                              comm_aware=True, chunks_per_rank=1, skew=0,
                              collective_id=10, interpret=True,
                              id_style=None, wire="f32"):
    """Per-shard device-initiated dispatch All-to-All.

    xt: [n_dev, B, E_loc, C, D] routed token blocks stacked by
    destination rank; returns the same shape stacked by *source* rank —
    the slot layout ``fused_gemm_a2a_pallas`` consumes directly.
    ``my_ep`` is the int32 ring position, ``ring_base`` the logical id of
    ring position 0 (0 on a 1-D mesh; the row base on a flattened
    multi-axis world, where peer logical id = ``ring_base + dest``).

    ``chunks_per_rank`` must divide the capacity axis C; every
    ``C/chunks_per_rank`` sub-chunk is PUT as soon as it is sliced out
    (Fig. 13 granularity).  ``skew`` rotates the remote destination
    order (Fig. 14).  ``wire`` is the PUT payload dtype — supported
    ``{"f32", "bf16"}`` (fp8 per-chunk scaling is an XLA-path feature;
    callers clamp).
    """
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    if wire not in ("f32", "bf16"):
        raise ValueError(f"kernel wire dtype must be 'f32' or 'bf16', "
                         f"got {wire!r}")
    nd, b, e, c, d = xt.shape
    assert nd == n_dev, (nd, n_dev)
    q = int(chunks_per_rank)
    if q < 1 or c % q:
        raise ValueError(f"chunks_per_rank {q} must divide capacity {c}")
    sub = c // q
    n_steps = n_dev * q
    wire_dt = (jnp.bfloat16 if wire == "bf16" and xt.dtype.itemsize > 2
               else xt.dtype)
    use_rx = wire_dt != xt.dtype
    kernel = functools.partial(_dispatch_a2a_kernel, n_dev=n_dev, q=q,
                               sub=sub, axis_name=axis_name,
                               id_style=id_style, use_rx=use_rx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec(memory_space=ANY),           # token blocks in HBM
        ],
        out_specs=pl.BlockSpec((nd, b, e, c, d), lambda i, s: (0,) * 5),
        scratch_shapes=[
            pltpu.VMEM((2, b, e, c, d), xt.dtype),    # streamed dest blocks
            pltpu.SemaphoreType.DMA((2,)),            # block double buffer
            # tx staging: one slot per remote (dest, sub) step, at the
            # wire dtype (the local block is stored to the output
            # directly and scheduled last)
            pltpu.VMEM((max((n_dev - 1) * q, 1), b, e, sub, d), wire_dt),
            # rx staging for a narrowed wire (dummy otherwise — PUTs then
            # land zero-copy in the output ref)
            pltpu.VMEM((nd, b, e, c, d) if use_rx else (1,) * 5, wire_dt),
            pltpu.SemaphoreType.DMA,                  # send
            pltpu.SemaphoreType.DMA,                  # recv
        ],
    )
    step_off, _ = step_schedule(n_dev, q, comm_aware, skew)
    ids = jnp.concatenate([my_ep.astype(jnp.int32)[None],
                           ring_base.astype(jnp.int32)[None],
                           jnp.asarray(step_off, jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, b, e, c, d), xt.dtype),
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(ids, xt)
