"""Wrappers for the device-initiated dispatch All-to-All kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.core.collectives import feasible_chunks_per_rank
from repro.kernels import clamp_kernel_wire, interpret_mode
from repro.kernels.flatmesh import (WORLD_AXIS, flat_world_mesh,
                                    moe_from_world, moe_to_world,
                                    needs_flat_world)
from repro.kernels.fused_dispatch_a2a.kernel import fused_dispatch_a2a_pallas
from repro.parallel.sharding import ParallelContext


def fused_dispatch_a2a_kernel_available(mesh=None) -> bool:
    """Mosaic on TPU supports any mesh.  The CPU *interpreter* needs a
    known mesh: multi-axis meshes run the kernel's shard_map over a
    flattened single-named-axis view with row-confined logical ids (see
    :mod:`repro.kernels.flatmesh`), so only a missing mesh gates it."""
    if not interpret_mode():
        return True
    return mesh is not None


def fused_dispatch_a2a_shard(xt, axis, *, comm_aware=True, chunks_per_rank=1,
                             skew=0, wire="f32", ring_size=None):
    """Call inside shard_map.  xt: [n, B_loc, E_loc, C, D] stacked by
    destination rank; the PUT ring runs over mesh axis ``axis``.
    ``ring_size`` confines the ring to contiguous groups of that many
    ranks of a larger (flattened) axis — ``None`` means the whole axis.
    ``chunks_per_rank`` is clamped to the largest feasible divisor of the
    capacity axis; ``wire="fp8"`` is clamped to bf16 (one-time warning).

    Differentiable: the dispatch permutation is self-adjoint on this slot
    layout (swapping (source, destination) is an involution), so the VJP
    is the same exchange applied to the cotangent.
    """
    wire = clamp_kernel_wire(wire, "fused_dispatch_a2a")
    world = axis_size(axis)
    n_dev = world if ring_size is None else int(ring_size)
    q = feasible_chunks_per_rank(xt.shape[3], 1, chunks_per_rank)

    def call(v):
        # recompute the ring position per trace: the VJP re-enters this
        # under a fresh trace, and closure-captured index tracers from the
        # forward trace would leak into it
        my_world = lax.axis_index(axis)
        my = lax.rem(my_world, n_dev)
        base = my_world - my
        return fused_dispatch_a2a_pallas(
            v, my, base, n_dev=n_dev, axis_name=axis, comm_aware=comm_aware,
            chunks_per_rank=q, skew=skew, interpret=interpret_mode(),
            wire=wire)

    @jax.custom_vjp
    def a2a(v):
        return call(v)

    def fwd(v):
        return call(v), None

    def bwd(_, g):
        return (call(g),)

    a2a.defvjp(fwd, bwd)
    return a2a(xt)


def _flat_specs(n: int):
    return tuple(P(WORLD_AXIS) for _ in range(n))


def fused_dispatch_a2a(ctx: ParallelContext, x, *, comm_aware=True,
                       chunks_per_rank=1, skew=0, wire="f32"):
    """Standalone global-array entry (tests/benchmarks).

    x: [B, n_ep, E, C, D] global, dim 1 indexing the destination EP
    shard, E sharded over tp — same layout as
    ``moe_dispatch_all_to_all``.  Returns the same global shape with
    source/destination swapped (the FFN+combine kernel's input layout).
    """
    b = x.shape[0]

    def local_fn(xl):
        xt = jnp.moveaxis(xl, 1, 0)  # [n_ep, B_loc, E_loc, C, D]
        out = fused_dispatch_a2a_shard(
            xt, ctx.tp_axis, comm_aware=comm_aware,
            chunks_per_rank=chunks_per_rank, skew=skew, wire=wire)
        return jnp.moveaxis(out, 0, 1)

    if needs_flat_world(ctx.mesh):
        rows, ring = ctx.dp, ctx.tp
        b_sharded = b % rows == 0
        xw = moe_to_world(x, rows, ring, b_sharded=b_sharded)

        def flat_fn(xl):
            xt = jnp.moveaxis(xl[0], 1, 0)
            out = fused_dispatch_a2a_shard(
                xt, WORLD_AXIS, comm_aware=comm_aware,
                chunks_per_rank=chunks_per_rank, skew=skew, wire=wire,
                ring_size=ring)
            return jnp.moveaxis(out, 0, 1)[None]

        yw = shard_map(flat_fn, mesh=flat_world_mesh(ctx.mesh, ctx.tp_axis),
                       in_specs=_flat_specs(1), out_specs=P(WORLD_AXIS),
                       check_vma=False)(xw)
        return moe_from_world(yw, rows, ring, b_sharded=b_sharded)

    dp = ctx.batch_axes if b % ctx.dp == 0 else None
    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, ctx.tp_axis, None, None),),
        out_specs=P(dp, None, ctx.tp_axis, None, None),
        check_vma=False,
    )(x)
