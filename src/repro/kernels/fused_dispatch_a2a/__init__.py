from repro.kernels.fused_dispatch_a2a.ops import (
    fused_dispatch_a2a,
    fused_dispatch_a2a_kernel_available,
    fused_dispatch_a2a_shard,
)

__all__ = [
    "fused_dispatch_a2a",
    "fused_dispatch_a2a_kernel_available",
    "fused_dispatch_a2a_shard",
]
