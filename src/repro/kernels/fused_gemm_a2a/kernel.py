"""Device-initiated fused expert GEMM + All-to-All (paper §III, Fig. 10).

The paper's third operator (MoE combine): as soon as an expert shard
finishes the output block destined for one peer, that block is PUT to the
peer while the remaining blocks are still being computed.  This kernel is
the device-initiated sibling of the XLA-level ``fused_expert_ffn_combine``
and shares the tile-pipeline helpers with the rewritten fused
GEMV/GEMM+AllReduce kernel:

* Multi-step grid over combine destinations (comm-aware: farthest peer
  first, locally-consumed block last — paper Fig. 7b's rule applied to
  the A2A).
* The dispatched token blocks stay in HBM; each destination's
  ``[B, E, C, D]`` block is streamed into a VMEM double buffer one step
  ahead, so VMEM holds two blocks — not the whole dispatch buffer.
* The expert weights stay in HBM too: the gated FFN's GEMMs are
  contraction-tiled, streaming ``[tile_k, F]`` up/gate panels and
  ``[tile_f, D]`` down panels through per-stream double buffers and
  accumulating partials in f32 — so VMEM holds two panels per stream
  instead of all ``E_loc`` experts' ``[D, F]`` slabs, and ``D x F``
  scales past VMEM in both dims (the K-panel treatment of the
  GEMV+AllReduce kernel applied to both chained GEMMs).  Panels may be
  ragged in the final step of either contraction.
* The finished block is PUT straight into the peer's *output ref* slot
  for this source rank (zero-copy: the combine A2A needs no receive-side
  shuffle), wire time hidden behind the next block's GEMMs.
* DMA completion semaphores replace the paper's sliceRdy polling.

Runs inside shard_map over the expert-parallel axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.tile_pipeline import (ANY, drain, remote_tile_put,
                                         step_schedule, stream_block_copy)


def _panel_copy(hbm, slots, sems, slot, ei, row0, rows, full_rows):
    """Descriptor for one ``[rows, cols]`` weight panel of expert ``ei``
    (``rows < full_rows`` on a ragged final panel).  All indices are
    python-static — the (expert, panel) loops are unrolled."""
    if rows == full_rows:
        dst = slots.at[slot]
    else:
        dst = slots.at[slot, pl.ds(0, rows)]
    return pltpu.make_async_copy(hbm.at[ei, pl.ds(row0, rows)], dst,
                                 sems.at[slot])


def _weight_schedule(e_loc, kp_d, kp_f):
    """Static (stream, expert, panel) order the FFN consumes panels in."""
    items = []
    for ei in range(e_loc):
        items += [("ug", ei, p) for p in range(kp_d)]
        items += [("d", ei, p) for p in range(kp_f)]
    return items


def _gemm_a2a_kernel(ids_ref, x_hbm, wu_hbm, wg_hbm, wd_hbm, o_ref,
                     x_slots, x_sems, wu_slots, wu_sems, wg_slots, wg_sems,
                     wd_slots, wd_sems, tx_ref, rx_ref, send_sem, recv_sem, *,
                     n_dev, e_loc, tile_k, tile_f, dm, f, act,
                     axis_name, id_style, use_rx):
    my = ids_ref[0]
    base = ids_ref[1]
    i = pl.program_id(0)
    step_off = lambda s: ids_ref[2 + s]
    kp_d = -(-dm // tile_k)
    kp_f = -(-f // tile_f)
    items = _weight_schedule(e_loc, kp_d, kp_f)

    def xdma(step, slot):
        dest = lax.rem(my + step_off(step), n_dev)
        return stream_block_copy(x_hbm, x_slots, x_sems, slot, dest)

    def wcopy(item, occ):
        stream, ei, p = item
        if stream == "ug":
            k0 = p * tile_k
            ksz = min(tile_k, dm - k0)
            return [_panel_copy(wu_hbm, wu_slots, wu_sems, occ % 2, ei,
                                k0, ksz, tile_k),
                    _panel_copy(wg_hbm, wg_slots, wg_sems, occ % 2, ei,
                                k0, ksz, tile_k)]
        f0 = p * tile_f
        fsz = min(tile_f, f - f0)
        return [_panel_copy(wd_hbm, wd_slots, wd_sems, occ % 2, ei,
                            f0, fsz, tile_f)]

    # per-stream double-buffer slot = occurrence count % 2 (python-static)
    occs = []
    counts = {"ug": 0, "d": 0}
    for it in items:
        occs.append(counts[it[0]])
        counts[it[0]] += 1

    @pl.when(i == 0)
    def _():
        xdma(0, 0).start()

    @pl.when(i + 1 < n_dev)
    def _():
        xdma(i + 1, (i + 1) % 2).start()

    for c in wcopy(items[0], occs[0]):
        c.start()
    xdma(i, i % 2).wait()
    off = step_off(i)
    dest = lax.rem(my + off, n_dev)
    xs = x_slots[i % 2]                               # [B, E, C, D]
    b, _, cc, _ = xs.shape

    # ---- contraction-tiled gated FFN, weights streamed from HBM -------
    ys = []
    h = g = u = y = None
    for j, (item, occ) in enumerate(zip(items, occs)):
        for c in wcopy(item, occ):
            c.wait()
        if j + 1 < len(items):
            for c in wcopy(items[j + 1], occs[j + 1]):
                c.start()
        stream, ei, p = item
        slot = occ % 2
        xe = xs[:, ei].reshape(b * cc, dm)
        if stream == "ug":
            k0 = p * tile_k
            ksz = min(tile_k, dm - k0)
            xp = xe[:, k0:k0 + ksz]
            hp = jnp.dot(xp, wu_slots[slot, :ksz],
                         preferred_element_type=jnp.float32)
            gp = jnp.dot(xp, wg_slots[slot, :ksz],
                         preferred_element_type=jnp.float32)
            h = hp if p == 0 else h + hp
            g = gp if p == 0 else g + gp
        else:
            if p == 0:
                u = (act(g) * h).astype(xs.dtype)
            f0 = p * tile_f
            fsz = min(tile_f, f - f0)
            yp = jnp.dot(u[:, f0:f0 + fsz], wd_slots[slot, :fsz],
                         preferred_element_type=jnp.float32)
            y = yp if p == 0 else y + yp
            if p == kp_f - 1:
                ys.append(y.reshape(b, 1, cc, dm).astype(o_ref.dtype))
    block = jnp.concatenate(ys, axis=1)               # [B, E, C, D]

    # receive target: the output ref itself (zero-copy) when the wire
    # dtype matches the output, a wire-dtype rx staging ref otherwise
    # (the narrow payload is upcast into the output at the end)
    recv_ref = rx_ref if use_rx else o_ref

    @pl.when(off != 0)
    def _():
        # finished block: PUT straight into the peer's slot for this
        # source rank, staged at the wire dtype (data lands in final
        # layout; no receive-side shuffle)
        tx_ref[i] = block.astype(tx_ref.dtype)
        remote_tile_put(tx_ref.at[i], recv_ref.at[my], send_sem, recv_sem,
                        base + dest, axis_name, id_style).start()

    @pl.when(off == 0)
    def _():
        o_ref[my] = block

    @pl.when(i == n_dev - 1)
    def _():
        def desc():
            return remote_tile_put(tx_ref.at[0], recv_ref.at[0], send_sem,
                                   recv_sem, base + my, axis_name, id_style)

        drain(desc, n_dev - 1, recv=True)   # peers' blocks landed
        drain(desc, n_dev - 1, recv=False)  # our PUTs drained
        if use_rx:
            # upcast the wire-dtype arrivals into the output slots
            for s in range(n_dev):
                @pl.when(s != my)
                def _(s=s):
                    o_ref[s] = rx_ref[s].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "act", "comm_aware", "skew",
                                    "collective_id", "interpret",
                                    "axis_name", "id_style", "tile_k",
                                    "tile_f", "wire"))
def fused_gemm_a2a_pallas(xt, w_up, w_gate, w_down, my_ep, ring_base, *,
                          n_dev, axis_name, act, comm_aware=True, skew=0,
                          collective_id=8, interpret=True, id_style=None,
                          tile_k=None, tile_f=None, wire="f32"):
    """Per-shard fused expert FFN + combine All-to-All.

    xt: [n_dev, B, E_loc, C, D] dispatched tokens stacked by combine
    destination; w_up/w_gate: [E_loc, D, F]; w_down: [E_loc, F, D];
    my_ep: int32 ring position; ring_base: logical id of ring position 0
    (0 on a 1-D mesh; on a flattened multi-axis world the row base, so a
    PUT to ring position ``dest`` targets logical id ``ring_base + dest``
    and stays row-confined).  ``skew`` rotates the remote send order by
    the measured straggler bucket.  Returns [n_dev, B, E_loc, C, D]
    stacked by *source* rank (the bulk All-to-All's layout).

    ``tile_k`` / ``tile_f`` bound the contraction panels of the up/gate
    and down GEMMs (``None`` = whole depth; values need not divide D or F
    — the final panel of either contraction is ragged).  The weights are
    streamed per (expert, panel) from HBM, so per-expert ``D x F`` and
    the ``E_loc`` multiplier never hit VMEM at once.

    ``wire`` is the combine-PUT payload dtype: ``"bf16"`` stages finished
    blocks (f32-accumulated in the GEMM pipeline) in bf16 tx buffers and
    receives them in a bf16 staging ref upcast into the output at the end
    — the remote DMA moves half the bytes at the cost of the receive-side
    zero-copy.  Supported: ``{"f32", "bf16"}`` (fp8 per-chunk scaling is
    an XLA-path feature; callers clamp).
    """
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    if wire not in ("f32", "bf16"):
        raise ValueError(f"kernel wire dtype must be 'f32' or 'bf16', "
                         f"got {wire!r}")
    nd, b, e, c, d = xt.shape
    f = w_up.shape[2]
    assert nd == n_dev, (nd, n_dev)
    tile_k = d if tile_k is None else max(1, min(int(tile_k), d))
    tile_f = f if tile_f is None else max(1, min(int(tile_f), f))
    wire_dt = (jnp.bfloat16 if wire == "bf16" and xt.dtype.itemsize > 2
               else xt.dtype)
    use_rx = wire_dt != xt.dtype
    kernel = functools.partial(_gemm_a2a_kernel, n_dev=n_dev, e_loc=e,
                               tile_k=tile_k, tile_f=tile_f, dm=d, f=f,
                               act=act, axis_name=axis_name,
                               id_style=id_style, use_rx=use_rx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_dev,),
        in_specs=[
            pl.BlockSpec(memory_space=ANY),           # token blocks in HBM
            pl.BlockSpec(memory_space=ANY),           # w_up in HBM
            pl.BlockSpec(memory_space=ANY),           # w_gate in HBM
            pl.BlockSpec(memory_space=ANY),           # w_down in HBM
        ],
        out_specs=pl.BlockSpec((nd, b, e, c, d), lambda i, s: (0,) * 5),
        scratch_shapes=[
            pltpu.VMEM((2, b, e, c, d), xt.dtype),    # streamed x blocks
            pltpu.SemaphoreType.DMA((2,)),            # block double buffer
            pltpu.VMEM((2, tile_k, f), w_up.dtype),   # streamed up panels
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((2, tile_k, f), w_gate.dtype),  # streamed gate panels
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.VMEM((2, tile_f, d), w_down.dtype),  # streamed down panels
            pltpu.SemaphoreType.DMA((2,)),
            # tx staging: remote blocks only (own block is written to the
            # output directly and scheduled last, so remote steps are
            # i < n_dev - 1); staged at the wire dtype
            pltpu.VMEM((max(n_dev - 1, 1), b, e, c, d), wire_dt),
            # rx staging for a narrowed wire (a dummy slot otherwise — the
            # PUTs then land zero-copy in the output ref)
            pltpu.VMEM((n_dev, b, e, c, d) if use_rx else (1,) * 5, wire_dt),
            pltpu.SemaphoreType.DMA,                  # send
            pltpu.SemaphoreType.DMA,                  # recv
        ],
    )
    step_off, _ = step_schedule(n_dev, 1, comm_aware, skew)
    ids = jnp.concatenate([my_ep.astype(jnp.int32)[None],
                           ring_base.astype(jnp.int32)[None],
                           jnp.asarray(step_off, jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, b, e, c, d), xt.dtype),
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(ids, xt, w_up, w_gate, w_down)
