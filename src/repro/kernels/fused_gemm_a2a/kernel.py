"""Device-initiated fused expert GEMM + All-to-All (paper §III, Fig. 10).

The paper's third operator (MoE combine): as soon as an expert shard
finishes the output block destined for one peer, that block is PUT to the
peer while the remaining blocks are still being computed.  This kernel is
the device-initiated sibling of the XLA-level ``fused_expert_ffn_combine``
and shares the tile-pipeline helpers with the rewritten fused
GEMV/GEMM+AllReduce kernel:

* Multi-step grid over combine destinations (comm-aware: farthest peer
  first, locally-consumed block last — paper Fig. 7b's rule applied to
  the A2A).
* The dispatched token blocks stay in HBM; each destination's
  ``[B, E, C, D]`` block is streamed into a VMEM double buffer one step
  ahead, so VMEM holds two blocks — not the whole dispatch buffer.
* The gated expert FFN (up/gate GEMMs, activation, down GEMM) runs per
  destination block; the finished block is PUT straight into the peer's
  *output ref* slot for this source rank (zero-copy: the combine A2A
  needs no receive-side shuffle), wire time hidden behind the next
  block's GEMMs.
* DMA completion semaphores replace the paper's sliceRdy polling.

Runs inside shard_map over the expert-parallel axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.tile_pipeline import (ANY, drain, remote_tile_put,
                                         step_schedule, stream_block_copy)


def _ffn_block(xs, wu_ref, wg_ref, wd_ref, act, out_dtype):
    """Gated FFN over one destination block.  xs: [B, E, C, D] value."""
    b, e, c, d = xs.shape
    outs = []
    for ei in range(e):
        xe = xs[:, ei].reshape(b * c, d)
        h = jnp.dot(xe, wu_ref[ei], preferred_element_type=jnp.float32)
        g = jnp.dot(xe, wg_ref[ei], preferred_element_type=jnp.float32)
        y = jnp.dot((act(g) * h).astype(xs.dtype), wd_ref[ei],
                    preferred_element_type=jnp.float32)
        outs.append(y.reshape(b, 1, c, d))
    return jnp.concatenate(outs, axis=1).astype(out_dtype)


def _gemm_a2a_kernel(ids_ref, x_hbm, wu_ref, wg_ref, wd_ref, o_ref,
                     x_slots, x_sems, tx_ref, send_sem, recv_sem, *,
                     n_dev, act, axis_name, id_style):
    my = ids_ref[0]
    i = pl.program_id(0)
    step_off = lambda s: ids_ref[1 + s]

    def xdma(step, slot):
        dest = lax.rem(my + step_off(step), n_dev)
        return stream_block_copy(x_hbm, x_slots, x_sems, slot, dest)

    @pl.when(i == 0)
    def _():
        xdma(0, 0).start()

    @pl.when(i + 1 < n_dev)
    def _():
        xdma(i + 1, (i + 1) % 2).start()

    xdma(i, i % 2).wait()
    off = step_off(i)
    dest = lax.rem(my + off, n_dev)
    y = _ffn_block(x_slots[i % 2], wu_ref, wg_ref, wd_ref, act, o_ref.dtype)

    @pl.when(off != 0)
    def _():
        # finished block: PUT straight into the peer's output slot for
        # this source rank (zero-copy combine; data lands in final layout)
        tx_ref[i] = y
        remote_tile_put(tx_ref.at[i], o_ref.at[my], send_sem, recv_sem,
                        dest, axis_name, id_style).start()

    @pl.when(off == 0)
    def _():
        o_ref[my] = y

    @pl.when(i == n_dev - 1)
    def _():
        def desc():
            return remote_tile_put(tx_ref.at[0], o_ref.at[0], send_sem,
                                   recv_sem, my, axis_name, id_style)

        drain(desc, n_dev - 1, recv=True)   # peers' blocks landed
        drain(desc, n_dev - 1, recv=False)  # our PUTs drained


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "act", "comm_aware",
                                    "collective_id", "interpret",
                                    "axis_name", "id_style"))
def fused_gemm_a2a_pallas(xt, w_up, w_gate, w_down, my_ep, *, n_dev,
                          axis_name, act, comm_aware=True, collective_id=8,
                          interpret=True, id_style=None):
    """Per-shard fused expert FFN + combine All-to-All.

    xt: [n_dev, B, E_loc, C, D] dispatched tokens stacked by combine
    destination; w_up/w_gate: [E_loc, D, F]; w_down: [E_loc, F, D];
    my_ep: int32 ring position.  Returns [n_dev, B, E_loc, C, D] stacked
    by *source* rank (the bulk All-to-All's layout).
    """
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    nd, b, e, c, d = xt.shape
    assert nd == n_dev, (nd, n_dev)
    kernel = functools.partial(_gemm_a2a_kernel, n_dev=n_dev, act=act,
                               axis_name=axis_name, id_style=id_style)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_dev,),
        in_specs=[
            pl.BlockSpec(memory_space=ANY),           # token blocks in HBM
            pl.BlockSpec((e,) + w_up.shape[1:], lambda i, s: (0, 0, 0)),
            pl.BlockSpec((e,) + w_gate.shape[1:], lambda i, s: (0, 0, 0)),
            pl.BlockSpec((e,) + w_down.shape[1:], lambda i, s: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((nd, b, e, c, d), lambda i, s: (0,) * 5),
        scratch_shapes=[
            pltpu.VMEM((2, b, e, c, d), xt.dtype),    # streamed x blocks
            pltpu.SemaphoreType.DMA((2,)),            # block double buffer
            # tx staging: remote blocks only (own block is written to the
            # output directly and scheduled last, so remote steps are
            # i < n_dev - 1)
            pltpu.VMEM((max(n_dev - 1, 1), b, e, c, d), xt.dtype),
            pltpu.SemaphoreType.DMA,                  # send
            pltpu.SemaphoreType.DMA,                  # recv
        ],
    )
    step_off, _ = step_schedule(n_dev, 1, comm_aware)
    ids = jnp.concatenate([my_ep.astype(jnp.int32)[None],
                           jnp.asarray(step_off, jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nd, b, e, c, d), xt.dtype),
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(ids, xt, w_up, w_gate, w_down)
