from repro.kernels.fused_gemm_a2a.ops import (
    fused_gemm_a2a,
    fused_gemm_a2a_kernel_available,
    fused_gemm_a2a_shard,
    fused_moe_chain_shard,
    fused_moe_kernel,
)

__all__ = [
    "fused_gemm_a2a",
    "fused_gemm_a2a_kernel_available",
    "fused_gemm_a2a_shard",
    "fused_moe_chain_shard",
    "fused_moe_kernel",
]
