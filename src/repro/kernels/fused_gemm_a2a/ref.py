"""Oracle for the fused expert GEMM + All-to-All kernel.

Per-shard semantics: every EP rank holds dispatched token blocks
``xt [n, B, E, C, D]`` stacked by combine destination plus its local
expert weights; the fused kernel must return the blocks *computed for
this rank by every source*, i.e. the gated expert FFN applied per block
followed by a bulk All-to-All over the leading dim.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def expert_ffn_ref(xb, w_up, w_gate, w_down, act):
    """Gated FFN over one block.  xb: [..., E, C, D] with per-expert
    weights [E, D, F]/[E, F, D]."""
    h = jnp.einsum("...ecd,edf->...ecf", xb, w_up)
    g = jnp.einsum("...ecd,edf->...ecf", xb, w_gate)
    return jnp.einsum("...ecf,efd->...ecd", act(g) * h, w_down)


def fused_gemm_a2a_ref_shard(xt, w_up, w_gate, w_down, axis_name, act):
    """Inside shard_map: bulk-synchronous baseline (FFN, then one A2A)."""
    y = expert_ffn_ref(xt, w_up, w_gate, w_down, act)
    return lax.all_to_all(y, axis_name, split_axis=0, concat_axis=0,
                          tiled=False)
