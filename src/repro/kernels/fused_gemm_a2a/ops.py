"""Wrappers for the device-initiated fused expert GEMM + All-to-All kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels import interpret_mode
from repro.kernels.fused_gemm_a2a.kernel import fused_gemm_a2a_pallas
from repro.parallel.sharding import ParallelContext
from repro.compat import axis_size, shard_map


def fused_gemm_a2a_kernel_available(mesh=None) -> bool:
    """Mosaic on TPU supports any mesh; the CPU *interpreter* can only
    discharge remote DMAs under a single-named-axis mesh (validation runs
    use a 1D mesh; the production path on CPU falls back to the XLA
    decomposed fusion)."""
    if not interpret_mode():
        return True
    return mesh is not None and len(mesh.axis_names) == 1


def fused_gemm_a2a_shard(xt, w_up, w_gate, w_down, axis, *, act,
                         comm_aware=True, tile_k=None, tile_f=None,
                         wire="f32"):
    """Call inside shard_map.  xt: [n, B_loc, E_loc, C, D] stacked by
    combine destination; the PUT ring runs over mesh axis ``axis``.
    ``tile_k`` / ``tile_f`` bound the streamed weight panels of the
    up/gate and down GEMM contractions (None = whole depth).  ``wire``
    compresses the combine-PUT payload (kernel path supports f32/bf16;
    fp8 is clamped to bf16 — the per-chunk-scale format is an XLA-path
    feature)."""
    n_dev = axis_size(axis)
    my = lax.axis_index(axis)
    wire = "bf16" if wire == "fp8" else wire
    return fused_gemm_a2a_pallas(
        xt, w_up, w_gate, w_down, my, n_dev=n_dev, axis_name=axis, act=act,
        comm_aware=comm_aware, interpret=interpret_mode(), tile_k=tile_k,
        tile_f=tile_f, wire=wire)


def fused_gemm_a2a(ctx: ParallelContext, x_dispatched, w_up, w_gate, w_down,
                   *, act, comm_aware=True, tile_k=None, tile_f=None,
                   wire="f32"):
    """Standalone global-array entry (tests/benchmarks).

    x_dispatched: [B, n_ep, E, C, D] global, E sharded over tp — same
    layout as ``fused_expert_ffn_combine``.  Returns [B, n_ep, E, C, D]
    with the expert outputs returned to their source shards.
    """
    b = x_dispatched.shape[0]
    dp = ctx.batch_axes if b % ctx.dp == 0 else None

    def local_fn(xl, wu, wg, wd):
        xt = jnp.moveaxis(xl, 1, 0)  # [n_ep, B_loc, E_loc, C, D]
        out = fused_gemm_a2a_shard(xt, wu, wg, wd, ctx.tp_axis, act=act,
                                   comm_aware=comm_aware, tile_k=tile_k,
                                   tile_f=tile_f, wire=wire)
        return jnp.moveaxis(out, 0, 1)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(
            P(dp, None, ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
        ),
        out_specs=P(dp, None, ctx.tp_axis, None, None),
        check_vma=False,
    )(x_dispatched, w_up, w_gate, w_down)
