"""Wrappers for the device-initiated fused expert GEMM + All-to-All kernel.

Also home of the chained MoE entry: the dispatch-side A2A kernel
(:mod:`repro.kernels.fused_dispatch_a2a`) lands tokens in exactly the
by-source slot layout the FFN+combine kernel streams its input from, so
``fused_moe_kernel`` runs dispatch → expert FFN → combine with no XLA
round-trip between the two exchanges.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map
from repro.kernels import clamp_kernel_wire, interpret_mode
from repro.kernels.flatmesh import (WORLD_AXIS, flat_world_mesh,
                                    moe_from_world, moe_to_world,
                                    needs_flat_world, weights_to_world)
from repro.kernels.fused_dispatch_a2a.ops import fused_dispatch_a2a_shard
from repro.kernels.fused_gemm_a2a.kernel import fused_gemm_a2a_pallas
from repro.kernels.fused_gemm_a2a.ref import expert_ffn_ref
from repro.parallel.sharding import ParallelContext


def fused_gemm_a2a_kernel_available(mesh=None) -> bool:
    """Mosaic on TPU supports any mesh.  The CPU *interpreter* needs a
    known mesh: multi-axis meshes run the kernel's shard_map over a
    flattened single-named-axis view with row-confined logical ids (see
    :mod:`repro.kernels.flatmesh`), so only a missing mesh gates it."""
    if not interpret_mode():
        return True
    return mesh is not None


def _ring_position(axis, ring_size):
    """(n_dev, my, base) for a PUT ring over ``axis`` — the whole axis by
    default, or contiguous ``ring_size`` groups of a flattened world."""
    world = axis_size(axis)
    n_dev = world if ring_size is None else int(ring_size)
    my_world = lax.axis_index(axis)
    my = lax.rem(my_world, n_dev)
    return n_dev, my, my_world - my


def fused_gemm_a2a_shard(xt, w_up, w_gate, w_down, axis, *, act,
                         comm_aware=True, skew=0, tile_k=None, tile_f=None,
                         wire="f32", ring_size=None):
    """Call inside shard_map.  xt: [n, B_loc, E_loc, C, D] stacked by
    combine destination; the PUT ring runs over mesh axis ``axis``
    (``ring_size`` confines it to contiguous groups of a flattened world
    axis).  ``tile_k`` / ``tile_f`` bound the streamed weight panels of
    the up/gate and down GEMM contractions (None = whole depth).
    ``wire`` compresses the combine-PUT payload (kernel path supports
    f32/bf16; fp8 is clamped to bf16 with a one-time warning — the
    per-chunk-scale format is an XLA-path feature).

    Differentiable: ``pallas_call`` has no JVP rule, so the VJP
    differentiates the pure reference of the same math — the gated
    expert FFN followed by the (self-adjoint, kernel-backed) ring A2A
    — rematerialized from the saved operands.  The forward kernel is
    bit-identical to that reference at ``wire="f32"``, so the grads are
    the exact grads of what was computed."""
    wire = clamp_kernel_wire(wire, "fused_gemm_a2a")

    def kernel_call(v, wu, wg, wd):
        n_dev, my, base = _ring_position(axis, ring_size)
        return fused_gemm_a2a_pallas(
            v, wu, wg, wd, my, base, n_dev=n_dev, axis_name=axis, act=act,
            comm_aware=comm_aware, skew=skew, interpret=interpret_mode(),
            tile_k=tile_k, tile_f=tile_f, wire=wire)

    def ref_call(v, wu, wg, wd):
        y = expert_ffn_ref(v, wu, wg, wd, act)
        return fused_dispatch_a2a_shard(y, axis, comm_aware=comm_aware,
                                        skew=skew, ring_size=ring_size)

    @jax.custom_vjp
    def gemm_a2a(v, wu, wg, wd):
        return kernel_call(v, wu, wg, wd)

    def fwd(v, wu, wg, wd):
        return kernel_call(v, wu, wg, wd), (v, wu, wg, wd)

    def bwd(res, g):
        _, vjp = jax.vjp(ref_call, *res)
        return vjp(g)

    gemm_a2a.defvjp(fwd, bwd)
    return gemm_a2a(xt, w_up, w_gate, w_down)


def fused_moe_chain_shard(xt, w_up, w_gate, w_down, axis, *, act,
                          comm_aware=True, chunks_per_rank=1, skew=0,
                          tile_k=None, tile_f=None, wire="f32",
                          ring_size=None):
    """Chained dispatch → FFN → combine inside shard_map.

    xt: [n, B_loc, E_loc, C, D] stacked by *dispatch destination*.  The
    dispatch kernel's rx buffer (tokens stacked by source) is consumed
    directly as the FFN+combine kernel's input — the two kernels share
    the by-source slot layout, so nothing round-trips through an XLA
    shuffle between the A2As.  Returns blocks stacked by combine
    destination (= dispatch source): each rank's tokens come home.
    """
    xr = fused_dispatch_a2a_shard(xt, axis, comm_aware=comm_aware,
                                  chunks_per_rank=chunks_per_rank, skew=skew,
                                  wire=wire, ring_size=ring_size)
    return fused_gemm_a2a_shard(xr, w_up, w_gate, w_down, axis, act=act,
                                comm_aware=comm_aware, skew=skew,
                                tile_k=tile_k, tile_f=tile_f, wire=wire,
                                ring_size=ring_size)


def _global_entry(ctx, x, w_up, w_gate, w_down, shard_fn):
    """Shared shard_map plumbing for the global kernel entries: direct
    multi-axis mapping where the backend discharges it, the flattened
    single-named-axis world otherwise (interpret mode on a 2-D mesh)."""
    b = x.shape[0]

    if needs_flat_world(ctx.mesh):
        rows, ring = ctx.dp, ctx.tp
        b_sharded = b % rows == 0
        xw = moe_to_world(x, rows, ring, b_sharded=b_sharded)
        ws = [weights_to_world(w, rows, ring)
              for w in (w_up, w_gate, w_down)]

        def flat_fn(xl, wul, wgl, wdl):
            xt = jnp.moveaxis(xl[0], 1, 0)  # [n_ep, B_loc, E_loc, C, D]
            out = shard_fn(xt, wul[0], wgl[0], wdl[0], WORLD_AXIS, ring)
            return jnp.moveaxis(out, 0, 1)[None]

        yw = shard_map(flat_fn, mesh=flat_world_mesh(ctx.mesh, ctx.tp_axis),
                       in_specs=tuple(P(WORLD_AXIS) for _ in range(4)),
                       out_specs=P(WORLD_AXIS), check_vma=False,
                       )(xw, *ws)
        return moe_from_world(yw, rows, ring, b_sharded=b_sharded)

    dp = ctx.batch_axes if b % ctx.dp == 0 else None

    def local_fn(xl, wu, wg, wd):
        xt = jnp.moveaxis(xl, 1, 0)  # [n_ep, B_loc, E_loc, C, D]
        out = shard_fn(xt, wu, wg, wd, ctx.tp_axis, None)
        return jnp.moveaxis(out, 0, 1)

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(
            P(dp, None, ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
            P(ctx.tp_axis, None, None),
        ),
        out_specs=P(dp, None, ctx.tp_axis, None, None),
        check_vma=False,
    )(x, w_up, w_gate, w_down)


def fused_gemm_a2a(ctx: ParallelContext, x_dispatched, w_up, w_gate, w_down,
                   *, act, comm_aware=True, skew=0, tile_k=None, tile_f=None,
                   wire="f32"):
    """Standalone global-array entry (tests/benchmarks).

    x_dispatched: [B, n_ep, E, C, D] global, E sharded over tp — same
    layout as ``fused_expert_ffn_combine``.  Returns [B, n_ep, E, C, D]
    with the expert outputs returned to their source shards.
    """
    def shard_fn(xt, wu, wg, wd, axis, ring_size):
        return fused_gemm_a2a_shard(xt, wu, wg, wd, axis, act=act,
                                    comm_aware=comm_aware, skew=skew,
                                    tile_k=tile_k, tile_f=tile_f, wire=wire,
                                    ring_size=ring_size)

    return _global_entry(ctx, x_dispatched, w_up, w_gate, w_down, shard_fn)


def fused_moe_kernel(ctx: ParallelContext, x, w_up, w_gate, w_down, *, act,
                     comm_aware=True, chunks_per_rank=1, skew=0, tile_k=None,
                     tile_f=None, wire="f32"):
    """Full device-initiated MoE: dispatch A2A kernel chained with the
    FFN+combine kernel (global-array entry).

    x: [B, n_ep, E, C, D] global, dim 1 indexing the *destination* EP
    shard (``moe_dispatch_all_to_all``'s input layout), E sharded over
    tp.  Equivalent to ``fused_expert_ffn_combine(ctx,
    moe_dispatch_all_to_all(ctx, x), ...)`` with both exchanges device-
    initiated and no HBM round-trip between them.
    """
    def shard_fn(xt, wu, wg, wd, axis, ring_size):
        return fused_moe_chain_shard(xt, wu, wg, wd, axis, act=act,
                                     comm_aware=comm_aware,
                                     chunks_per_rank=chunks_per_rank,
                                     skew=skew, tile_k=tile_k, tile_f=tile_f,
                                     wire=wire, ring_size=ring_size)

    return _global_entry(ctx, x, w_up, w_gate, w_down, shard_fn)
