"""Causal flash-attention kernel (prefill hot-spot).

Grid (batch*heads, q_blocks, kv_blocks), kv innermost; running max /
denominator / output accumulator live in VMEM across the kv loop.  Causal
blocks above the diagonal are skipped by masking (TPU grids are
sequential per core, so `pl.when` on block indices skips the matmuls
entirely for fully-masked blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, bq, bkv):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    should_run = True
    if causal:
        # block fully above the diagonal -> nothing to do
        should_run = qi * bq + bq - 1 >= ki * bkv

    @pl.when(should_run)
    def _():
        q = q_ref[0]                                  # [bq, d]
        k = k_ref[0]                                  # [bkv, d]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "bq", "bkv",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, scale, causal=True, bq=128, bkv=128,
                           interpret=True):
    """q,k,v: [BH, S, d] (heads pre-folded into batch) -> [BH, S, d]."""
    bh, s, d = q.shape
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bkv=bkv)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq, s // bkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
