"""Public flash-attention wrapper: folds [B, S, H, d] to [BH, S, d]."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _block(dim, pref):
    for b in (pref, 128, 64, 32, 16, 8):
        if b <= pref and dim % b == 0:
            return b
    return dim


def flash_attention(q, k, v, *, scale=None, causal=True, bq=128, bkv=128):
    """q,k,v: [B, S, H, d] (equal head counts; GQA expansion upstream)."""
    b, s, h, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out = flash_attention_pallas(
        fold(q), fold(k), fold(v), scale=scale, causal=causal,
        bq=_block(s, bq), bkv=_block(s, bkv), interpret=interpret_mode())
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
