"""Pure-jnp oracle for the flash-attention kernel."""
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale, causal=True):
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq = q.shape[1]
        mask = jnp.tril(jnp.ones((sq, sq), bool))
        s = jnp.where(mask[None], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / e.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
