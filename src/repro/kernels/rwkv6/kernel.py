"""Chunked WKV6 recurrence kernel (RWKV-6 time-mix hot spot).

Grid (B*H, T/chunk) with the chunk dimension sequential; the [N, N]
recurrent state lives in VMEM scratch across chunk steps (the TPU
analogue of a persistent workgroup carrying state).  Within a chunk the
pairwise decay form is used: ratios exp(lc_t - lc_s), s <= t, are
bounded in (0, 1] so any chunk length is numerically safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0]            # [c, N]
    k = k_ref[0]
    v = v_ref[0]
    lw = lw_ref[0]          # [c, N] log-decay (<= 0)
    u = u_ref[0]            # [1, N]
    c = r.shape[0]

    lc = jnp.cumsum(lw, axis=0)
    lc_tm1 = lc - lw
    # pairwise per-channel decay exp(lc_{t-1} - lc_s), s < t: bounded (0,1]
    dec = jnp.exp(jnp.clip(lc_tm1[:, None] - lc[None, :], -60.0, 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.einsum("tn,tsn,sn->ts", r, dec * mask[..., None], k)
    o = jnp.dot(att, v, preferred_element_type=jnp.float32)
    # bonus diagonal term
    o = o + (r * u * k).sum(axis=-1, keepdims=True) * v
    # contribution of carried state
    rdec = r * jnp.exp(jnp.clip(lc_tm1, -60.0, 0.0))
    o = o + jnp.dot(rdec, state_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)
    # state update
    lc_end = lc[-1]
    kdec = k * jnp.exp(jnp.clip(lc_end[None, :] - lc, -60.0, 0.0))
    state_ref[...] = jnp.exp(jnp.clip(lc_end, -60.0, 0.0))[:, None] * \
        state_ref[...] + jnp.dot(kdec.T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, lw, u, *, chunk=32, interpret=True):
    """r,k,v,lw: [BH, T, N] (heads folded into batch; lw = log decay);
    u: [BH, 1, N] bonus.  Returns o: [BH, T, N] f32."""
    bh, t, n = r.shape
    assert t % chunk == 0, (t, chunk)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u)
