"""Pure-jnp oracle for the WKV6 kernel: plain per-step recurrence."""
import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u):
    """r,k,v,lw: [BH, T, N]; u: [BH, 1, N] -> o [BH, T, N] f32."""
    w = jnp.exp(lw.astype(jnp.float32))
    bh, t, n = r.shape

    def step(S, xs):
        rr, kk, vv, ww = xs
        kv = jnp.einsum("bn,bm->bnm", kk, vv)
        o = jnp.einsum("bn,bnm->bm", rr, S + u[:, 0][..., None] * kv)
        return ww[..., None] * S + kv, o

    S0 = jnp.zeros((bh, n, n), jnp.float32)
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    _, o = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(o, 0, 1)
