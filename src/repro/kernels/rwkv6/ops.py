"""Public WKV6 wrapper: folds [B, T, H, N] heads into the grid batch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.rwkv6.kernel import wkv6_pallas


def wkv6(r, k, v, w, u, *, chunk=32):
    """r,k,v,w: [B, T, H, N] (w = decay in (0,1)); u: [H, N] -> [B,T,H,N]."""
    b, t, h, n = r.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, n)
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0))
    uu = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, 1, n)
    o = wkv6_pallas(fold(r).astype(jnp.float32), fold(k).astype(jnp.float32),
                    fold(v).astype(jnp.float32), fold(lw), uu,
                    chunk=min(chunk, t), interpret=interpret_mode())
    return o.reshape(b, h, t, n).transpose(0, 2, 1, 3)
