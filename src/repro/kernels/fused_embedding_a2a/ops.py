"""Wrappers for the device-initiated fused embedding+All-to-All kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels import interpret_mode
from repro.kernels.fused_embedding_a2a.kernel import fused_embedding_a2a_pallas
from repro.parallel.sharding import ParallelContext
from repro.compat import axis_size, shard_map


def fused_embedding_a2a_kernel_available(mesh=None) -> bool:
    if not interpret_mode():
        return True
    return mesh is not None and len(mesh.axis_names) == 1


def fused_embedding_a2a(ctx: ParallelContext, indices, tables, *,
                        comm_aware=True):
    """Global entry.  indices: [B, T_global, L]; tables: [T_global, V, D]
    sharded over the (1D) mesh axis -> pooled [B, T_global, D], batch
    sharded."""
    axis = ctx.tp_axis
    B, T, L = indices.shape

    def local_fn(idx_l, tab_l):
        my = lax.axis_index(axis)
        n = axis_size(axis)
        return fused_embedding_a2a_pallas(
            tab_l, idx_l, my, n_dev=n, L=L, axis_name=axis,
            comm_aware=comm_aware, interpret=interpret_mode())

    return shard_map(
        local_fn, mesh=ctx.mesh,
        in_specs=(P(None, axis, None), P(axis, None, None)),
        out_specs=P(axis, None, None),
        check_vma=False,
    )(indices, tables)
