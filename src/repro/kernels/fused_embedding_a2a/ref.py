"""Oracle: pool all local tables for the global batch, exchange fragments."""
import jax.numpy as jnp


def fused_embedding_a2a_ref(all_tables, idx):
    """Global semantics given every device's shards.

    all_tables: [n, T_loc, V, D]; idx: [n, B, T_loc, L] (per source device)
    -> [n, B_loc, n*T_loc, D] per destination device."""
    n, t_loc, v, d = all_tables.shape
    B = idx.shape[1]
    b_loc = B // n
    pooled = jnp.stack([
        jnp.take(all_tables[s].reshape(t_loc * v, d),
                 (idx[s] + (jnp.arange(t_loc) * v)[None, :, None]
                  ).reshape(B, t_loc, -1),
                 axis=0).reshape(B, t_loc, -1, d).mean(axis=2)
        for s in range(n)
    ])  # [n_src, B, T_loc, D]
    outs = []
    for dst in range(n):
        frag = pooled[:, dst * b_loc:(dst + 1) * b_loc]   # [n_src, b_loc, T_loc, D]
        outs.append(jnp.moveaxis(frag, 0, 1).reshape(b_loc, n * t_loc, d))
    return jnp.stack(outs)
