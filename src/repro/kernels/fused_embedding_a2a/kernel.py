"""Device-initiated fused embedding pooling + All-to-All (paper §III-A,
Fig. 6 — the scale-out flagship).

One Pallas kernel per chip pools its local tables' bags AND communicates
each destination's batch fragment the moment the fragment's last bag
completes — the TPU analogue of the paper's persistent HIP kernel with
ROC_SHMEM PUTs:

* grid = (destination, batch row, table); the destination axis iterates
  in communication-aware order (farthest peer first, the local fragment
  last — paper Fig. 6b);
* embedding rows are fetched by scalar-prefetched indices driving the
  table BlockSpec (one row DMA per lookup — the TPU gather idiom);
* a fragment accumulates in VMEM; on its last bag it is PUT directly
  into the *destination's output buffer* at this source's table columns
  (zero-copy: the data lands in the layout the interaction op consumes,
  no shuffle kernel — the paper's "no explicit rearrangement" property);
* DMA completion semaphores replace WG_Done/sliceRdy flags; the kernel
  exits after its n-1 inbound fragments have landed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from repro.compat import tpu_compiler_params


def _kernel(ids_ref, idx_ref, row_ref, out_ref, frag_ref, send_sem,
            recv_sem, *, n_dev, b_loc, t_loc, L, comm_aware, id_style,
            axis_name):
    my = ids_ref[0]
    i, b, t, l = (pl.program_id(k) for k in range(4))
    # comm-aware destination order = [n-1, ..., 1, 0] (farthest first,
    # local last) -- pure arithmetic in the grid step index
    off = (n_dev - 1 - i) if comm_aware else i
    dest = lax.rem(my + off, n_dev)

    def dev_id(d):
        if id_style == "mesh":
            return {axis_name: d}, pltpu.DeviceIdType.MESH
        return d, pltpu.DeviceIdType.LOGICAL

    @pl.when(l == 0)
    def _():
        frag_ref[b, t] = jnp.zeros_like(frag_ref[b, t])

    frag_ref[b, t] += row_ref[0, 0].astype(jnp.float32)

    last_bag = (l == L - 1)

    @pl.when(last_bag)
    def _():
        frag_ref[b, t] = frag_ref[b, t] / L

    frag_done = last_bag & (b == b_loc - 1) & (t == t_loc - 1)

    @pl.when(frag_done & (dest != my))
    def _():
        # PUT the fragment straight into dest's output at MY table columns
        did, dt = dev_id(dest)
        pltpu.make_async_remote_copy(
            src_ref=frag_ref,
            dst_ref=out_ref.at[:, pl.ds(my * t_loc, t_loc)],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=did,
            device_id_type=dt,
        ).start()

    @pl.when(frag_done & (dest == my))
    def _():
        # local fragment: plain copy into our own output slice
        out_ref[:, pl.ds(my * t_loc, t_loc)] = frag_ref[...].astype(out_ref.dtype)

    # final grid step: drain sends, wait for all inbound fragments
    is_last_step = (i == n_dev - 1) & frag_done

    @pl.when(is_last_step)
    def _():
        wait = pltpu.make_async_remote_copy(
            src_ref=frag_ref,
            dst_ref=out_ref.at[:, pl.ds(my * t_loc, t_loc)],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=dev_id(my)[0],
            device_id_type=dev_id(my)[1],
        )
        for _ in range(n_dev - 1):
            wait.wait_send()
            wait.wait_recv()


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "L", "comm_aware",
                                    "collective_id", "interpret",
                                    "id_style", "axis_name"))
def fused_embedding_a2a_pallas(tables, idx, my, *, n_dev, L, axis_name,
                               comm_aware=True, collective_id=9,
                               interpret=True, id_style=None):
    """tables: [T_loc, V, D]; idx: [B_global, T_loc, L] int32.

    Returns [B_loc, n_dev * T_loc, D]: this device's batch fragment of
    the pooled embeddings of ALL devices' tables, fully exchanged.
    """
    if id_style is None:
        id_style = "logical" if interpret else "mesh"
    t_loc, v, d = tables.shape
    B, _, _ = idx.shape
    b_loc = B // n_dev
    kernel = functools.partial(_kernel, n_dev=n_dev, b_loc=b_loc,
                               t_loc=t_loc, L=L, comm_aware=comm_aware,
                               id_style=id_style, axis_name=axis_name)

    def table_index(i, b, t, l, ids_ref, idx_ref):
        off = (n_dev - 1 - i) if comm_aware else i
        dest = (ids_ref[0] + off) % n_dev
        gb = dest * b_loc + b
        return (t, idx_ref[gb, t, l], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_dev, b_loc, t_loc, L),
        in_specs=[pl.BlockSpec((1, 1, d), table_index)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((b_loc, t_loc, d), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    ids = jnp.stack([my.astype(jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_loc, n_dev * t_loc, d),
                                       tables.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",) * 4,
            collective_id=collective_id),
        interpret=interpret,
    )(ids, idx, tables)
