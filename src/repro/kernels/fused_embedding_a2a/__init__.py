from repro.kernels.fused_embedding_a2a.ops import (  # noqa: F401
    fused_embedding_a2a,
    fused_embedding_a2a_kernel_available,
)
