"""Batched decode engine: continuous batching over a shared KV cache.

Serving substrate for the inference-shaped cells (decode_32k, long_500k):
a slot-based scheduler admits requests into a fixed decode batch, runs
the jitted ``decode_step`` (whose FFN is the paper's fused
GEMV+AllReduce), samples greedily via the vocab-sharded argmax, and
retires finished sequences.  Token-level continuous batching — a slot is
re-admitted the step after its sequence finishes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(self, decode_fn: Callable, init_cache_fn: Callable,
                 batch_size: int, eos_id: int = -1):
        """decode_fn(tokens [B,1], cache, pos) -> (logits [B,1,V], cache)."""
        self.decode_fn = decode_fn
        self.batch = batch_size
        self.eos = eos_id
        self.cache = init_cache_fn(batch_size)
        self.slots: list[Request | None] = [None] * batch_size
        self.queue: list[Request] = []
        self.cur_tok = np.zeros((batch_size, 1), np.int32)
        self.pos = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # prompt is consumed token-by-token (prefill via decode);
                # production would run a separate prefill graph.
                self.cur_tok[i, 0] = req.prompt[0]
                req._consumed = 1

    def step(self):
        self._admit()
        logits, self.cache = self.decode_fn(
            jnp.asarray(self.cur_tok), self.cache, jnp.int32(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.pos += 1
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req._consumed < len(req.prompt):
                self.cur_tok[i, 0] = req.prompt[req._consumed]
                req._consumed += 1
                continue
            tok = int(nxt[i])
            req.tokens.append(tok)
            self.cur_tok[i, 0] = tok
            if tok == self.eos or len(req.tokens) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return nxt, finished

    def run_until_drained(self, max_steps: int = 10_000):
        finished = []
        steps = 0
        while (any(s is not None for s in self.slots) or self.queue) \
                and steps < max_steps:
            _, fin = self.step()
            finished.extend(fin)
            steps += 1
        return finished
