"""Batched decode engine: continuous batching over a shared KV cache.

Serving substrate for the inference-shaped cells (decode_32k, long_500k):
a slot-based scheduler admits requests into a fixed decode batch, runs
the jitted ``decode_step`` (whose FFN is the paper's fused
GEMV+AllReduce), samples greedily via the vocab-sharded argmax, and
retires finished sequences.  Token-level continuous batching — a slot is
re-admitted the step after its sequence finishes.

Elastic serving: :meth:`DecodeEngine.reshard` swaps the decode function /
cache for a different mesh mid-flight.  In-flight requests go back to the
queue front with their generated tokens intact; on re-admission the
engine replays prompt + generated tokens through the new cache (the
token-by-token prefill path) and generation resumes where it stopped —
requests survive a mesh shrink, they just pay a replay delay.
:func:`serve_with_chaos` drives the engine under a
:class:`~repro.runtime.chaos.FaultPlan`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.chaos import RankLost


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # engine-managed: tokens to replay through the cache before sampling
    # resumes (prompt, plus already-generated tokens after a reshard),
    # and how many of them have been fed so far.
    prefix: list = dataclasses.field(default_factory=list)
    consumed: int = 0


class DecodeEngine:
    def __init__(self, decode_fn: Callable, init_cache_fn: Callable,
                 batch_size: int, eos_id: int = -1, bos_id: int = 0):
        """decode_fn(tokens [B,1], cache, pos) -> (logits [B,1,V], cache).

        ``bos_id`` seeds the first decode step for empty-prompt requests
        (unconditional generation)."""
        self.decode_fn = decode_fn
        self.init_cache_fn = init_cache_fn
        self.batch = batch_size
        self.eos = eos_id
        self.bos = bos_id
        self.cache = init_cache_fn(batch_size)
        self.slots: list[Request | None] = [None] * batch_size
        self.queue: collections.deque[Request] = collections.deque()
        self.cur_tok = np.zeros((batch_size, 1), np.int32)
        self.pos = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # prompt (and, after a reshard, the already-generated
                # tokens) is consumed token-by-token — prefill via decode;
                # production would run a separate prefill graph.
                req.prefix = list(req.prompt) + list(req.tokens)
                if req.prefix:
                    self.cur_tok[i, 0] = req.prefix[0]
                    req.consumed = 1
                else:  # empty prompt: unconditional generation from BOS
                    self.cur_tok[i, 0] = self.bos
                    req.consumed = 0

    def step(self):
        self._admit()
        logits, self.cache = self.decode_fn(
            jnp.asarray(self.cur_tok), self.cache, jnp.int32(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        self.pos += 1
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.consumed < len(req.prefix):
                self.cur_tok[i, 0] = req.prefix[req.consumed]
                req.consumed += 1
                continue
            tok = int(nxt[i])
            req.tokens.append(tok)
            self.cur_tok[i, 0] = tok
            if tok == self.eos or len(req.tokens) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[i] = None
        return nxt, finished

    def reshard(self, decode_fn: Callable, init_cache_fn: Callable,
                batch_size: int | None = None) -> int:
        """Swap in a decode function/cache for a new (smaller) mesh.

        In-flight requests are pushed back to the queue *front* in slot
        order — they were admitted first, they re-admit first — keeping
        their generated tokens; re-admission replays them through the
        fresh cache.  Returns how many requests were re-queued."""
        inflight = [r for r in self.slots if r is not None]
        for r in reversed(inflight):
            self.queue.appendleft(r)
        if batch_size is not None:
            self.batch = batch_size
        self.decode_fn = decode_fn
        self.init_cache_fn = init_cache_fn
        self.cache = init_cache_fn(self.batch)
        self.slots = [None] * self.batch
        self.cur_tok = np.zeros((self.batch, 1), np.int32)
        self.pos = 0
        return len(inflight)

    def run_until_drained(self, max_steps: int = 10_000):
        finished = []
        steps = 0
        while (any(s is not None for s in self.slots) or self.queue) \
                and steps < max_steps:
            _, fin = self.step()
            finished.extend(fin)
            steps += 1
        return finished


def serve_with_chaos(engine: DecodeEngine, plan, *,
                     reshard_fn: Callable | None = None,
                     sleep_fn: Callable[[float], None] = time.sleep,
                     max_steps: int = 10_000):
    """Drain the engine under a :class:`~repro.runtime.chaos.FaultPlan`.

    Per tick: ``slow_link`` sleeps its delay before stepping; ``timeout``
    / ``rank_fail`` / ``nan_wire`` drop the tick entirely (the collective
    failed, nothing was committed — the same decode step retries next
    tick); ``rank_loss`` calls ``reshard_fn(engine)`` — the drain-reshard-
    resume path — or raises :class:`RankLost` if no handler is wired.

    Returns ``(finished, stats)`` where stats counts ticks, dropped
    ticks, and reshards.
    """
    finished = []
    stats = {"ticks": 0, "dropped": 0, "reshards": 0}
    tick = 0
    while (any(s is not None for s in engine.slots) or engine.queue) \
            and tick < max_steps:
        events = plan.at(tick) if plan is not None else ()
        tick += 1
        stats["ticks"] += 1
        dropped = False
        for ev in events:
            if ev.kind == "slow_link":
                sleep_fn(ev.delay_s)
            elif ev.kind == "rank_loss":
                if reshard_fn is None:
                    raise RankLost(ev.rank)
                reshard_fn(engine)
                stats["reshards"] += 1
            else:  # timeout / rank_fail / nan_wire: the tick is lost
                dropped = True
        if dropped:
            stats["dropped"] += 1
            continue
        _, fin = engine.step()
        finished.extend(fin)
    return finished, stats
