"""Batched decode engine: continuous batching with per-slot positions.

Serving substrate for the inference-shaped cells (decode_32k, long_500k):
a slot-based scheduler admits requests into a fixed decode batch, runs
the jitted step function (whose FFN is the paper's fused GEMV+AllReduce),
samples greedily via the vocab-sharded argmax, and retires finished
sequences.  Token-level continuous batching — a slot is re-admitted the
step after its sequence finishes.

Every slot carries its *own* position: the engine feeds a ``pos [B]``
vector to the model so a request admitted into a freed slot starts at
position 0 (fresh RoPE phases, fresh causal mask) while its neighbors
keep counting.  The old shared scalar position made slot reuse read the
previous occupant's stale KV rows — the cross-request contamination bug.

Two backends:

:class:`DecodeEngine`
    Dense ``[L, B, S_max]`` cache, one token per slot per step.  Prompt
    replay happens through the decode path token-by-token.
:class:`PagedDecodeEngine`
    Paged/block KV (:mod:`repro.serve.kv_cache` host side,
    :func:`repro.models.attention.paged_attention` device side) with
    *chunked prefill*: prompts are fed ``chunk`` tokens per step through
    the same jitted ``serve_step`` that decodes, so a step mixes prefill
    chunks and decode slots in one schedule (``n_new`` per slot: 0 idle,
    1 decode, >1 prefill).  Exactly two graphs are traced per engine —
    C=chunk and the C=1 decode fast path.  Blocks are freed the moment a
    request retires; pool exhaustion preempts the newest-admitted
    request back to the queue instead of corrupting a neighbor.

Elastic serving: :meth:`reshard` swaps the step function / cache (or
block pool) for a different mesh mid-flight.  In-flight requests go back
to the queue front with their generated tokens intact; on re-admission
the engine replays prompt + generated tokens through the new cache and
generation resumes where it stopped — requests survive a mesh shrink,
they just pay a replay delay.  :func:`serve_with_chaos` drives the
engine under a :class:`~repro.runtime.chaos.FaultPlan`.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.chaos import RankLost
from repro.serve.kv_cache import OutOfBlocks, PagedKVCache

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False   # retired at the cache bound, not eos/max_new
    # engine-managed: tokens to replay through the cache before sampling
    # resumes (prompt, plus already-generated tokens after a reshard),
    # and how many of them have been fed so far.
    prefix: list = dataclasses.field(default_factory=list)
    consumed: int = 0
    # SLO timestamps (engine clock): submission, first generated token,
    # retirement.  bench_serve derives TTFT / per-token latency from these.
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


class DrainResult(list):
    """Finished requests, plus whether the engine actually drained.

    ``drained`` is False when :meth:`run_until_drained` stopped at
    ``max_steps`` with work still queued or in flight — previously
    indistinguishable from a clean drain."""

    drained: bool = True


class _EngineBase:
    """Queue/slot bookkeeping shared by the dense and paged engines."""

    batch: int
    slots: list[Request | None]
    queue: collections.deque

    def __init__(self, batch_size: int, eos_id: int, bos_id: int,
                 time_fn: Callable[[], float]):
        self.batch = batch_size
        self.eos = eos_id
        self.bos = bos_id
        self.time_fn = time_fn
        self.slots = [None] * batch_size
        self.queue = collections.deque()

    def submit(self, req: Request):
        if req.t_submit is None:
            req.t_submit = self.time_fn()
        self.queue.append(req)

    def _pending(self) -> bool:
        return any(s is not None for s in self.slots) or bool(self.queue)

    def _retire(self, i: int, req: Request, finished: list):
        req.done = True
        req.t_done = self.time_fn()
        self.slots[i] = None
        finished.append(req)

    def _pop_admittable(self, finished: list) -> Request | None:
        """Next queued request, retiring zero-budget ones on the spot.

        A ``max_new=0`` request must finish with *zero* generated tokens
        — it never touches a slot or the cache (the old engine decoded
        one token before checking the budget)."""
        while self.queue:
            req = self.queue.popleft()
            if req.max_new <= 0:
                req.done = True
                req.t_done = self.time_fn()
                finished.append(req)
                continue
            return req
        return None

    def run_until_drained(self, max_steps: int = 10_000,
                          liveness=None) -> DrainResult:
        """Drain the queue; with ``liveness`` (a :class:`~repro.runtime.
        watchdog.LivenessMonitor`), every tick first checks peer
        heartbeats and the engine step runs guarded — a peer process
        dying mid-decode raises :class:`~repro.runtime.chaos.RankLost`
        from *real* liveness instead of hanging the fleet.  The raise
        leaves host-side bookkeeping at the last completed tick, so
        :func:`request_journal` still snapshots a consistent in-flight
        set for the respawned engine."""
        finished = DrainResult()
        steps = 0
        while self._pending() and steps < max_steps:
            if liveness is not None:
                liveness.check()
                _, fin = liveness.guarded(self.step)
            else:
                _, fin = self.step()
            finished.extend(fin)
            steps += 1
        finished.drained = not self._pending()
        if not finished.drained:
            log.warning(
                "run_until_drained stopped at max_steps=%d with %d queued "
                "and %d in-flight requests — results are TRUNCATED",
                max_steps, len(self.queue),
                sum(s is not None for s in self.slots))
        return finished

    def step(self):  # pragma: no cover - abstract
        raise NotImplementedError


class DecodeEngine(_EngineBase):
    """Dense-cache engine (one token per slot per step, per-slot pos)."""

    def __init__(self, decode_fn: Callable, init_cache_fn: Callable,
                 batch_size: int, eos_id: int = -1, bos_id: int = 0,
                 max_seq: int | None = None,
                 time_fn: Callable[[], float] = time.monotonic):
        """decode_fn(tokens [B,1], cache, pos [B]) -> (logits [B,1,V], cache).

        ``bos_id`` seeds the first decode step for empty-prompt requests
        (unconditional generation).  ``max_seq`` is the cache bound: a
        slot reaching it retires its request with ``truncated=True``
        instead of silently overwriting the last cache row (pass the
        model's ``cfg.max_seq``; ``None`` disables the check for
        cacheless fakes)."""
        super().__init__(batch_size, eos_id, bos_id, time_fn)
        self.decode_fn = decode_fn
        self.init_cache_fn = init_cache_fn
        self.max_seq = max_seq
        self.cache = init_cache_fn(batch_size)
        self.cur_tok = np.zeros((batch_size, 1), np.int32)
        self.pos = np.zeros(batch_size, np.int32)   # per-slot, not shared

    def _admit(self, finished: list):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self._pop_admittable(finished)
                if req is None:
                    return
                self.slots[i] = req
                self.pos[i] = 0
                # prompt (and, after a reshard, the already-generated
                # tokens) is consumed token-by-token — prefill via decode;
                # the paged engine runs the chunked-prefill graph instead.
                req.prefix = list(req.prompt) + list(req.tokens)
                if req.prefix:
                    self.cur_tok[i, 0] = req.prefix[0]
                    req.consumed = 1
                else:  # empty prompt: unconditional generation from BOS
                    self.cur_tok[i, 0] = self.bos
                    req.consumed = 0

    def _retire_at_bound(self, finished: list):
        """The cache holds ``max_seq`` positions; a slot about to write
        past the end retires truncated (the write would be dropped and
        attention would walk garbage) instead of silently clobbering."""
        if self.max_seq is None:
            return
        for i, req in enumerate(self.slots):
            if req is not None and self.pos[i] >= self.max_seq:
                log.warning("request %d hit cache bound max_seq=%d after "
                            "%d generated tokens — retiring truncated",
                            req.uid, self.max_seq, len(req.tokens))
                req.truncated = True
                self._retire(i, req, finished)

    def step(self):
        finished: list[Request] = []
        self._retire_at_bound(finished)
        self._admit(finished)
        logits, self.cache = self.decode_fn(
            jnp.asarray(self.cur_tok), self.cache, jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            if req.consumed < len(req.prefix):
                self.cur_tok[i, 0] = req.prefix[req.consumed]
                req.consumed += 1
                continue
            tok = int(nxt[i])
            if req.t_first is None:
                req.t_first = self.time_fn()
            req.tokens.append(tok)
            self.cur_tok[i, 0] = tok
            if tok == self.eos or len(req.tokens) >= req.max_new:
                self._retire(i, req, finished)
        return nxt, finished

    def reshard(self, decode_fn: Callable, init_cache_fn: Callable,
                batch_size: int | None = None) -> int:
        """Swap in a decode function/cache for a new (smaller) mesh.

        In-flight requests are pushed back to the queue *front* in slot
        order — they were admitted first, they re-admit first — keeping
        their generated tokens; re-admission replays them through the
        fresh cache.  Returns how many requests were re-queued."""
        inflight = [r for r in self.slots if r is not None]
        for r in reversed(inflight):
            self.queue.appendleft(r)
        if batch_size is not None:
            self.batch = batch_size
        self.decode_fn = decode_fn
        self.init_cache_fn = init_cache_fn
        self.cache = init_cache_fn(self.batch)
        self.slots = [None] * self.batch
        self.cur_tok = np.zeros((self.batch, 1), np.int32)
        self.pos = np.zeros(self.batch, np.int32)
        return len(inflight)


class PagedDecodeEngine(_EngineBase):
    """Paged-KV engine with chunked prefill in a mixed schedule."""

    def __init__(self, serve_fn: Callable, init_pool_fn: Callable,
                 batch_size: int, *, num_blocks: int, block_size: int,
                 max_seq: int, chunk: int = 8, eos_id: int = -1,
                 bos_id: int = 0, n_stripes: int = 1,
                 time_fn: Callable[[], float] = time.monotonic):
        """serve_fn(tokens [B,C], pool, tables [B,MB], pos [B], n_new [B])
        -> (logits [B,V], pool); init_pool_fn(num_blocks, block_size) ->
        pool pytree.  ``chunk`` is the prefill chunk width C (the second
        traced graph; decode steps use C=1).  ``max_seq`` bounds each
        request's block table; ``n_stripes`` should be the tp size so
        allocation balances across rank stripes."""
        super().__init__(batch_size, eos_id, bos_id, time_fn)
        self.serve_fn = serve_fn
        self.init_pool_fn = init_pool_fn
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_seq = max_seq
        self.chunk = max(1, chunk)
        self.n_stripes = n_stripes
        self.pool = init_pool_fn(num_blocks, block_size)
        self.kv = PagedKVCache(num_blocks, block_size,
                               max_blocks_per_request=-(-max_seq // block_size),
                               n_stripes=n_stripes)
        self.cur_tok = np.zeros(batch_size, np.int32)
        self.pos = np.zeros(batch_size, np.int32)
        # feed list per slot: prefix (or [bos] for empty prompts) still to
        # be pushed through the prefill path; consumed indexes into it.
        self._feed: list[list] = [[] for _ in range(batch_size)]

    # -- admission / preemption -------------------------------------------
    def _admit(self, finished: list):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self._pop_admittable(finished)
                if req is None:
                    return
                req.prefix = list(req.prompt) + list(req.tokens)
                feed = list(req.prefix) or [self.bos]
                try:
                    self.kv.register(req.uid)
                    self.kv.ensure(req.uid, min(len(feed), self.max_seq))
                except OutOfBlocks:
                    # pool full: defer admission, keep FIFO order
                    self.kv.release(req.uid)
                    self.queue.appendleft(req)
                    return
                self.slots[i] = req
                self.pos[i] = 0
                req.consumed = 0
                self._feed[i] = feed

    def _preempt(self, i: int, req: Request):
        """Pool exhausted mid-flight: push the request back to the queue
        (front — it keeps its admission-order priority) and free its
        blocks.  Re-admission replays prompt + generated tokens through
        the chunked-prefill path."""
        log.warning("preempting request %d (pool exhausted): %d tokens "
                    "generated, will replay on re-admission",
                    req.uid, len(req.tokens))
        self.kv.release(req.uid)
        self.slots[i] = None
        self._feed[i] = []
        self.queue.appendleft(req)

    def _retire_at_bound(self, finished: list):
        for i, req in enumerate(self.slots):
            if req is not None and self.pos[i] >= self.max_seq:
                log.warning("request %d hit cache bound max_seq=%d after "
                            "%d generated tokens — retiring truncated",
                            req.uid, self.max_seq, len(req.tokens))
                req.truncated = True
                self.kv.release(req.uid)
                self._retire(i, req, finished)

    # -- the mixed prefill/decode step ------------------------------------
    def step(self):
        finished: list[Request] = []
        self._retire_at_bound(finished)
        self._admit(finished)
        # chunk width: the wide graph only when some slot is mid-prefill
        remaining = [0 if r is None else len(self._feed[i]) - r.consumed
                     for i, r in enumerate(self.slots)]
        C = self.chunk if any(rem > 1 for rem in remaining) else 1

        tokens = np.zeros((self.batch, C), np.int32)
        n_new = np.zeros(self.batch, np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            rem = remaining[i]
            if rem > 0:
                n = min(rem, C, self.max_seq - int(self.pos[i]))
                tokens[i, :n] = self._feed[i][req.consumed:req.consumed + n]
            else:
                n = 1
                tokens[i, 0] = self.cur_tok[i]
            try:
                self.kv.ensure(req.uid, int(self.pos[i]) + n)
            except OutOfBlocks:
                self._preempt(i, req)
                continue
            n_new[i] = n
        tables = self.kv.tables_for(
            [r.uid if r is not None and n_new[i] > 0 else None
             for i, r in enumerate(self.slots)])

        if not n_new.any():
            return np.zeros(self.batch, np.int32), finished

        logits, self.pool = self.serve_fn(
            jnp.asarray(tokens), self.pool, jnp.asarray(tables),
            jnp.asarray(self.pos), jnp.asarray(n_new))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        for i, req in enumerate(self.slots):
            if req is None or n_new[i] == 0:
                continue
            n = int(n_new[i])
            rem = remaining[i]
            self.pos[i] += n
            if rem > 0:
                req.consumed += n
                if req.consumed < len(self._feed[i]):
                    continue   # still prefilling: logits discarded
            # prefill just finished (its last-valid logits predict the
            # first new token) or plain decode: sample greedily
            tok = int(nxt[i])
            if req.t_first is None:
                req.t_first = self.time_fn()
            req.tokens.append(tok)
            self.cur_tok[i] = tok
            if tok == self.eos or len(req.tokens) >= req.max_new:
                self.kv.release(req.uid)
                self._retire(i, req, finished)
        return nxt, finished

    # -- elasticity --------------------------------------------------------
    def reshard(self, serve_fn: Callable, init_pool_fn: Callable,
                batch_size: int | None = None,
                num_blocks: int | None = None,
                block_size: int | None = None,
                n_stripes: int | None = None) -> int:
        """Swap the serve function/pool for a new mesh, migrating requests.

        Block tables are host-side state, but the pool *contents* live on
        the lost mesh — so migration re-queues in-flight requests (tokens
        intact) and rebuilds their KV through the chunked-prefill path on
        the new pool, exactly like the dense engine's replay.  Returns
        how many requests were re-queued."""
        inflight = [r for r in self.slots if r is not None]
        for r in reversed(inflight):
            self.queue.appendleft(r)
        if batch_size is not None:
            self.batch = batch_size
        self.num_blocks = num_blocks or self.num_blocks
        self.block_size = block_size or self.block_size
        self.n_stripes = n_stripes or self.n_stripes
        self.serve_fn = serve_fn
        self.init_pool_fn = init_pool_fn
        self.pool = init_pool_fn(self.num_blocks, self.block_size)
        self.kv = PagedKVCache(
            self.num_blocks, self.block_size,
            max_blocks_per_request=-(-self.max_seq // self.block_size),
            n_stripes=self.n_stripes)
        self.slots = [None] * self.batch
        self.cur_tok = np.zeros(self.batch, np.int32)
        self.pos = np.zeros(self.batch, np.int32)
        self._feed = [[] for _ in range(self.batch)]
        return len(inflight)


def request_journal(engine) -> list[dict]:
    """JSON-serializable snapshot of every *unfinished* request.

    In-flight slots first (admission order), then the queue — the order
    re-admission should honor.  Generated tokens ride along, so a
    respawned engine (cross-process elastic recovery) resubmits through
    :func:`resubmit_journal` and each request resumes exactly where it
    stopped: the replay path rebuilds its cache from prompt + tokens,
    the same mechanism :meth:`DecodeEngine.reshard` uses in-process."""
    live = [r for r in engine.slots if r is not None] + list(engine.queue)
    return [{"uid": r.uid, "prompt": list(r.prompt), "max_new": r.max_new,
             "tokens": list(r.tokens)} for r in live]


def resubmit_journal(engine, journal: list[dict]) -> int:
    """Re-admit journaled requests (tokens intact) into a fresh engine."""
    for e in journal:
        engine.submit(Request(uid=e["uid"], prompt=list(e["prompt"]),
                              max_new=e["max_new"],
                              tokens=list(e["tokens"])))
    return len(journal)


def serve_with_chaos(engine, plan, *,
                     reshard_fn: Callable | None = None,
                     sleep_fn: Callable[[float], None] = time.sleep,
                     max_steps: int = 10_000):
    """Drain the engine under a :class:`~repro.runtime.chaos.FaultPlan`.

    Per tick: ``slow_link`` sleeps its delay before stepping; ``timeout``
    / ``rank_fail`` / ``nan_wire`` drop the tick entirely (the collective
    failed, nothing was committed — the same decode step retries next
    tick); ``rank_loss`` calls ``reshard_fn(engine)`` — the drain-reshard-
    resume path — or raises :class:`RankLost` if no handler is wired.

    Returns ``(finished, stats)`` where stats counts ticks, dropped
    ticks, and reshards, and carries ``drained`` — False when the loop
    stopped at ``max_steps`` with requests still queued or in flight
    (previously indistinguishable from a clean drain).
    """
    finished = []
    stats = {"ticks": 0, "dropped": 0, "reshards": 0, "drained": True}
    tick = 0
    while engine._pending() and tick < max_steps:
        events = plan.at(tick) if plan is not None else ()
        tick += 1
        stats["ticks"] += 1
        dropped = False
        for ev in events:
            if ev.kind == "slow_link":
                sleep_fn(ev.delay_s)
            elif ev.kind == "rank_loss":
                if reshard_fn is None:
                    raise RankLost(ev.rank)
                reshard_fn(engine)
                stats["reshards"] += 1
            else:  # timeout / rank_fail / nan_wire: the tick is lost
                dropped = True
        if dropped:
            stats["dropped"] += 1
            continue
        _, fin = engine.step()
        finished.extend(fin)
    stats["drained"] = not engine._pending()
    if not stats["drained"]:
        log.warning(
            "serve_with_chaos stopped at max_steps=%d with %d queued and "
            "%d in-flight requests — results are TRUNCATED",
            max_steps, len(engine.queue),
            sum(s is not None for s in engine.slots))
    return finished, stats
