"""Host-side paged KV cache: a block allocator + per-request block tables.

The device side is a shared pool of fixed-size KV blocks
(``[NB, block, Hkv, hd]`` per layer, blocks sharded contiguously over the
tp axis — see :func:`repro.models.attention.paged_attention`).  This
module owns the *mapping*: which pool blocks hold which request's
sequence.  Ragged sequences then cost HBM proportional to the tokens
they actually hold instead of the dense ``B x S_max`` worst case, and a
retired request's blocks return to the free list immediately.

Allocation stripes round-robin across the tp *rank stripes* (rank d owns
global blocks ``[d*NB/n, (d+1)*NB/n)``), so KV writes and attention
reads stay balanced across ranks instead of piling onto whichever rank's
stripe the free list happened to drain first.

Block tables are padded with ``FREE_BLOCK`` (-1): a sentinel no rank
owns, so device-side scatter/gather drops those rows instead of
corrupting block 0.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FREE_BLOCK = -1


class OutOfBlocks(RuntimeError):
    """The pool has no free block for a required allocation."""


@dataclasses.dataclass
class PagedStats:
    num_blocks: int
    block_size: int
    used_blocks: int
    peak_blocks: int
    requests: int

    @property
    def used_tokens_capacity(self) -> int:
        return self.used_blocks * self.block_size


class PagedKVCache:
    """Block allocator + per-request block tables (host side, numpy).

    Parameters
    ----------
    num_blocks:      total pool blocks (must divide evenly by n_stripes).
    block_size:      tokens per block.
    max_blocks_per_request:
                     table width MB; a request holds at most
                     ``MB * block_size`` tokens (the serving cache bound).
    n_stripes:       tp size — allocation round-robins across the per-rank
                     block stripes to balance HBM and attention load.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 max_blocks_per_request: int, n_stripes: int = 1):
        if num_blocks % n_stripes:
            raise ValueError(
                f"num_blocks={num_blocks} not divisible by n_stripes={n_stripes}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_blocks = max_blocks_per_request
        self.n_stripes = n_stripes
        per = num_blocks // n_stripes
        # LIFO per stripe: recently freed blocks are re-handed first
        self._free: list[list[int]] = [
            list(range(s * per + per - 1, s * per - 1, -1))
            for s in range(n_stripes)]
        self._rr = 0
        self._tables: dict[int, list[int]] = {}
        self.peak_blocks = 0

    # -- introspection ----------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    def stats(self) -> PagedStats:
        return PagedStats(self.num_blocks, self.block_size,
                          self.used_blocks, self.peak_blocks,
                          len(self._tables))

    def blocks_for(self, uid: int) -> list[int]:
        return list(self._tables.get(uid, ()))

    # -- allocation -------------------------------------------------------
    def _alloc_one(self) -> int:
        for _ in range(self.n_stripes):
            stripe = self._free[self._rr]
            self._rr = (self._rr + 1) % self.n_stripes
            if stripe:
                return stripe.pop()
        raise OutOfBlocks(
            f"pool exhausted: {self.num_blocks} blocks all in use")

    def register(self, uid: int) -> None:
        if uid not in self._tables:
            self._tables[uid] = []

    def ensure(self, uid: int, length: int) -> None:
        """Grow ``uid``'s table to cover ``length`` tokens.

        Raises :class:`OutOfBlocks` when the pool is exhausted (caller
        decides: defer admission, or preempt) — partial growth is rolled
        back so a failed ensure leaves the table unchanged.  Raises
        ``ValueError`` past the table bound ``MB * block_size`` (the
        engine retires at the bound before this can trigger).
        """
        need = -(-length // self.block_size)          # ceil
        if need > self.max_blocks:
            raise ValueError(
                f"request {uid}: {length} tokens exceeds table bound "
                f"{self.max_blocks * self.block_size}")
        table = self._tables.setdefault(uid, [])
        grown: list[int] = []
        try:
            while len(table) < need:
                table.append(self._alloc_one())
                grown.append(table[-1])
        except OutOfBlocks:
            for b in grown:
                table.remove(b)
            self._release_blocks(grown)
            raise
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)

    def capacity(self, uid: int) -> int:
        """Tokens the request's current blocks can hold."""
        return len(self._tables.get(uid, ())) * self.block_size

    # -- release ----------------------------------------------------------
    def _release_blocks(self, blocks: list[int]) -> None:
        per = self.num_blocks // self.n_stripes
        for b in blocks:
            self._free[b // per].append(b)

    def release(self, uid: int) -> None:
        """Free all of a retired request's blocks back to their stripes."""
        self._release_blocks(self._tables.pop(uid, []))

    def reset(self) -> None:
        for uid in list(self._tables):
            self.release(uid)

    # -- device-facing views ----------------------------------------------
    def table(self, uid: int) -> np.ndarray:
        t = np.full(self.max_blocks, FREE_BLOCK, np.int32)
        blocks = self._tables.get(uid, ())
        t[: len(blocks)] = blocks
        return t

    def tables_for(self, uids) -> np.ndarray:
        """Stack tables for a slot list ([B] of uid or None) -> [B, MB]."""
        out = np.full((len(uids), self.max_blocks), FREE_BLOCK, np.int32)
        for i, uid in enumerate(uids):
            if uid is not None:
                out[i] = self.table(uid)
        return out


def pool_hbm_bytes(pool) -> int:
    """Total device bytes of a paged pool pytree (all layers, K and V)."""
    import jax

    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(pool))


def dense_cache_hbm_bytes(cache) -> int:
    """Total device bytes of a dense ``[L, B, S_max, ...]`` cache tree."""
    import jax

    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
