"""Sharded checkpointing: atomic, async-capable, mesh-agnostic restore.

Format: one directory per step containing
  manifest.json          - tree structure, shapes, dtypes, logical specs
  arr_<i>.npy            - one file per leaf (host-gathered)

Writes go to ``<dir>.tmp`` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint (restart-safe, the fault-tolerance
contract).  ``async_save`` runs serialization on a worker thread so the
training loop only blocks on device->host transfer of the *previous*
checkpoint (standard large-cluster practice).

Restore is mesh-agnostic: leaves are placed with the *target* mesh's
NamedShardings, so a checkpoint taken on N hosts restores onto M hosts
(elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np
from repro.compat import tree_flatten_with_path


def _flatten_with_paths(tree):
    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous sharded save with atomic rename.  Returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"path": p, "file": f"arr_{i}.npy",
             "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self._error: Exception | None = None

    def save(self, directory: str, step: int, tree: Any):
        self.wait()
        # device_get on the main thread (orders against in-flight steps),
        # file IO on the worker thread.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self.last_path = save_checkpoint(directory, step, host_tree)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def restore_checkpoint(path: str, target_tree: Any, shardings: Any | None = None):
    """Restore into the structure of ``target_tree``; place with
    ``shardings`` (a matching tree of NamedShardings) when given —
    this is the elastic/cross-mesh path."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        entry = by_path.get(p)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]
