"""Sharded checkpointing: atomic, async-capable, mesh-agnostic restore.

Format: one directory per step containing
  manifest.json          - tree structure, shapes, dtypes, logical specs
  arr_<i>.npy            - one file per leaf (host-gathered)

Writes go to ``<dir>.tmp`` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint (restart-safe, the fault-tolerance
contract).  ``async_save`` runs serialization on a worker thread so the
training loop only blocks on device->host transfer of the *previous*
checkpoint (standard large-cluster practice).

Restore is mesh-agnostic: leaves are placed with the *target* mesh's
NamedShardings, so a checkpoint taken on N hosts restores onto M hosts
(elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np
from repro.compat import tree_flatten_with_path


def _flatten_with_paths(tree):
    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def leaf_to_host(leaf) -> np.ndarray:
    """Full host value of one leaf, multi-process safe.

    A leaf sharded across processes is not fully addressable —
    ``device_get`` would throw — so its shards are gathered through
    ``process_allgather`` (a *collective*: on a multi-process mesh every
    process must reach the save point, and every process receives the
    full value).  Fully-addressable leaves take the direct path."""
    if getattr(leaf, "is_fully_addressable", True):
        return np.asarray(jax.device_get(leaf))
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(leaf, tiled=True))


def tree_to_host(tree) -> Any:
    """Host values for a whole tree, multi-process safe.

    Per-leaf :func:`leaf_to_host` is *not* safe for a multi-leaf tree on
    the gloo CPU transport: ``process_allgather`` forces only the first
    addressable shard of each gathered leaf, so the executable's
    all-gathers for the remaining local devices can still be in flight
    when the next leaf's gather dispatches — and interleaved collectives
    from different executables crash gloo.  Here every cross-process
    leaf is gathered by ONE jitted replicated-output computation (XLA
    orders collectives within a single executable) and the whole result
    is blocked on before any host read."""
    leaves, treedef = jax.tree.flatten(tree)
    gathered = [i for i, leaf in enumerate(leaves)
                if not getattr(leaf, "is_fully_addressable", True)]
    if gathered:
        from jax.sharding import NamedSharding, PartitionSpec

        sub = [leaves[i] for i in gathered]
        reps = [NamedSharding(x.sharding.mesh, PartitionSpec())
                for x in sub]
        out = jax.jit(lambda xs: xs, out_shardings=reps)(sub)
        out = jax.block_until_ready(out)
        for i, o in zip(gathered, out):
            leaves[i] = np.asarray(o.addressable_data(0))
    leaves = [np.asarray(jax.device_get(leaf))
              for leaf in jax.block_until_ready(leaves)]
    return jax.tree.unflatten(treedef, leaves)


def host_to_device(arr, sharding=None):
    """Collective-free placement of a host value (the inverse of
    :func:`leaf_to_host`).

    ``device_put`` onto a non-fully-addressable sharding runs jax's
    cross-process equal-value check — a per-leaf broadcast *collective*
    whose gloo messages can interleave with neighbouring puts and crash
    the transport.  ``make_array_from_callback`` builds the same global
    array purely locally: each process materializes only the shards it
    addresses from the host value."""
    if sharding is None:
        return jax.device_put(arr)
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(arr, sharding)
    arr = np.asarray(arr)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous sharded save with atomic rename.  Returns final path.

    Multi-process: every process participates in the host gather (it is
    collective), but only process 0 touches the filesystem — the
    standard single-writer checkpoint layout."""
    final = os.path.join(directory, f"step_{step:08d}")
    paths, leaves, _ = _flatten_with_paths(tree)
    # Serialize behind in-flight step work: the gather below issues its
    # own cross-process collectives, and on the gloo CPU transport they
    # must not interleave with a still-executing step's collectives.
    leaves = jax.block_until_ready(leaves)
    host = tree_to_host(leaves)
    if jax.process_index() != 0:
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": []}
    for i, (p, arr) in enumerate(zip(paths, host)):
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"path": p, "file": f"arr_{i}.npy",
             "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute."""

    def __init__(self):
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None
        self._error: Exception | None = None

    def save(self, directory: str, step: int, tree: Any):
        self.wait()
        # device_get / cross-process gather on the main thread (orders
        # against in-flight steps and keeps the collective out of the
        # worker thread), file IO on the worker thread.  Block first so
        # the gather's collectives cannot interleave with a
        # still-executing step's (fatal on the gloo transport).
        tree = jax.block_until_ready(tree)
        host_tree = tree_to_host(tree)

        def work():
            try:
                self.last_path = save_checkpoint(directory, step, host_tree)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def restore_checkpoint(path: str, target_tree: Any, shardings: Any | None = None):
    """Restore into the structure of ``target_tree``; place with
    ``shardings`` (a matching tree of NamedShardings) when given —
    this is the elastic/cross-mesh path."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        entry = by_path.get(p)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {leaf.shape}")
        out.append(host_to_device(arr, sh))
    return jax.tree.unflatten(treedef, out), manifest["step"]
