"""Checkpoint lifecycle: keep-k GC, latest discovery, resume."""
from __future__ import annotations

import os
import re
import shutil

from repro.checkpoint.checkpointer import AsyncCheckpointer, restore_checkpoint

_STEP_RE = re.compile(r"step_(\d{8})$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async = AsyncCheckpointer() if async_save else None

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_path(self):
        steps = self.all_steps()
        if not steps:
            return None
        return os.path.join(self.directory, f"step_{steps[-1]:08d}")

    def save(self, step: int, tree):
        if self._async is not None:
            self._async.save(self.directory, step, tree)
        else:
            from repro.checkpoint.checkpointer import save_checkpoint

            save_checkpoint(self.directory, step, tree)
        self._gc()

    def wait(self):
        if self._async is not None:
            self._async.wait()

    def restore_latest(self, target_tree, shardings=None):
        self.wait()
        path = self.latest_path()
        if path is None:
            return None
        return restore_checkpoint(path, target_tree, shardings)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
