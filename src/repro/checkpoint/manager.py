"""Checkpoint lifecycle: keep-k GC, latest discovery, resume."""
from __future__ import annotations

import logging
import os
import re
import shutil

from repro.checkpoint.checkpointer import AsyncCheckpointer, restore_checkpoint

log = logging.getLogger("repro.checkpoint")

_STEP_RE = re.compile(r"step_(\d{8})$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async = AsyncCheckpointer() if async_save else None

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_path(self):
        steps = self.all_steps()
        if not steps:
            return None
        return os.path.join(self.directory, f"step_{steps[-1]:08d}")

    def save(self, step: int, tree):
        if self._async is not None:
            self._async.save(self.directory, step, tree)
        else:
            from repro.checkpoint.checkpointer import save_checkpoint

            save_checkpoint(self.directory, step, tree)
        self._gc()

    def wait(self):
        if self._async is not None:
            self._async.wait()

    def restore_latest(self, target_tree, shardings=None):
        """Restore the newest readable checkpoint.

        A crash mid-write leaves only a ``.tmp`` dir (the atomic rename
        never happened), but a finalized checkpoint can still rot on disk
        (truncated manifest, missing/garbled array file).  Walk newest to
        oldest and fall back past any step that fails to load, so one bad
        entry does not brick the run."""
        self.wait()
        for step in reversed(self.all_steps()):
            path = os.path.join(self.directory, f"step_{step:08d}")
            try:
                return restore_checkpoint(path, target_tree, shardings)
            except Exception as e:
                log.warning("checkpoint %s unreadable (%s); trying previous",
                            path, e)
        return None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
