from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
