"""DLRM — the paper's own architecture (Table II parameters: embedding
dim 92, avg MLP size 682, pooling 70).  Tables are world-sharded; the
embedding+All-to-All fused operator is the training hot path."""
from repro.models.dlrm import DLRMConfig

CONFIG = DLRMConfig(
    name="dlrm",
    n_tables=512, table_vocab=1_000_000, embed_dim=92,
    n_dense=13, bottom_mlp=(512, 256, 92),
    top_mlp=(682, 682, 682, 1), pooling=70,
    param_dtype="float32", compute_dtype="float32",
)

FAMILY = "dlrm"
