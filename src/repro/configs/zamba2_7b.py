"""zamba2-7b [hybrid] — 81 Mamba2 blocks d3584, shared attention block
(32H on 2*d_model, kv=32) every 6 blocks with per-invocation LoRA,
d_ff=14336, vocab=32000, ssm_state=64 [arXiv:2411.15242]."""
from repro.models.zamba2 import Zamba2Config

CONFIG = Zamba2Config(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, d_state=64, attn_every=6, lora_r=16,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "zamba2"
