"""chatglm3-6b [dense] — 28L d4096 32H (GQA kv=2) d_ff=13696 vocab=65024,
2D RoPE (rotary on half the head dim, GLM convention) [arXiv:2406.12793]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="chatglm3-6b",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128, rope_style="2d", act="silu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"
