"""rwkv6-7b [ssm] — 32L d4096 attention-free d_ff=14336 vocab=65536,
Finch data-dependent decay [arXiv:2404.05892]."""
from repro.models.rwkv6 import RWKV6Config

CONFIG = RWKV6Config(
    name="rwkv6-7b",
    n_layers=32, d_model=4096, d_ff=14336, vocab=65536,
    head_size=64, lora_r=64, chunk=64,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "rwkv6"
