"""phi3-medium-14b [dense] — 40L d5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE + SwiGLU [arXiv:2404.14219]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352, head_dim=128, act="silu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"

MICROBATCHES = 2  # gradient accumulation (fits v5e HBM)
