from repro.configs.registry import ARCHS, get_arch, SHAPES  # noqa: F401
