"""dbrx-132b [moe] — 40L d6144 48H (GQA kv=8), fine-grained MoE 16 experts
top-4 (d_ff 10752), vocab=100352 [hf:databricks/dbrx-base]."""
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128, act="silu",
    moe=MoEConfig(n_experts=16, top_k=4, d_model=6144, d_ff=10752,
                  capacity_factor=1.25, norm_topk_prob=True),
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"
OPTIMIZER = "adafactor"

MICROBATCHES = 2  # gradient accumulation (fits v5e HBM)
