"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) d_ff=36864 vocab=256000,
local(4096)/global alternating, logit softcap 30 / attn softcap 50,
post-norms, (1+w) RMSNorm, query_pre_attn_scalar=144 [arXiv:2408.00118]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma2-27b",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab=256000, head_dim=128, act="gelu_tanh",
    window=4096, local_global_period=2,
    attn_softcap=50.0, logit_softcap=30.0,
    post_norms=True, norm_plus_one=True, embed_scale=True,
    query_scale=144.0 ** -0.5,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"

MICROBATCHES = 2  # gradient accumulation (fits v5e HBM)
