"""deepseek-67b [dense] — 95L d8192 64H (GQA kv=8) d_ff=22016 vocab=102400,
llama architecture [arXiv:2401.02954]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-67b",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400, head_dim=128, act="silu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"

MICROBATCHES = 4  # gradient accumulation (fits v5e HBM)
