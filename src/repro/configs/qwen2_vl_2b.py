"""qwen2-vl-2b [vlm] — 28L d1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE (t/h/w sections 16/24/24 of head_dim 128), dynamic-resolution ViT
frontend stubbed to precomputed patch embeddings [arXiv:2409.12191]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-vl-2b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, head_dim=128, act="silu",
    rope_style="mrope", mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
    frontend="vision",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"
