"""Architecture registry: uniform bundle interface over the model zoo.

Every assigned architecture (+ the paper's own DLRM) is exposed as an
``ArchBundle`` with: init, loss (train_step body), prefill, decode,
cache construction + logical sharding specs, per-shape input specs
(ShapeDtypeStruct stand-ins, no allocation), and a reduced smoke config.

Shapes (assignment):
  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill_step
  decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288 global_batch 1     -> serve_step; SSM/hybrid only
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import split_params
from repro.parallel.sharding import ParallelContext

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

DLRM_SHAPES = {
    "train_8k": {"batch": 8192, "kind": "dlrm_train"},
}

_MODULES = {
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "dlrm": "repro.configs.dlrm",
}

ARCHS = list(_MODULES)


@dataclasses.dataclass
class ArchBundle:
    name: str
    family: str
    config: Any
    optimizer: str = "adamw"
    microbatches: int = 1   # train-time gradient accumulation (memory knob)

    # ---- model fns -------------------------------------------------------
    def init_params(self, key):
        if self.family == "transformer":
            from repro.models.transformer import transformer_init

            return transformer_init(key, self.config)
        if self.family == "rwkv6":
            from repro.models.rwkv6 import rwkv6_init

            return rwkv6_init(key, self.config)
        if self.family == "zamba2":
            from repro.models.zamba2 import zamba2_init

            return zamba2_init(key, self.config)
        if self.family == "dlrm":
            from repro.models.dlrm import dlrm_init

            return dlrm_init(key, self.config)
        raise ValueError(self.family)

    def loss_fn(self, ctx: ParallelContext) -> Callable:
        fam = self.family
        cfg = self.config
        if fam == "transformer":
            from repro.models.transformer import train_forward

            return lambda p, b: train_forward(ctx, p, cfg, b)
        if fam == "rwkv6":
            from repro.models.rwkv6 import train_forward

            return lambda p, b: train_forward(ctx, p, cfg, b)
        if fam == "zamba2":
            from repro.models.zamba2 import train_forward

            return lambda p, b: train_forward(ctx, p, cfg, b)
        if fam == "dlrm":
            from repro.models.dlrm import dlrm_loss

            return lambda p, b: dlrm_loss(ctx, p, cfg, b)
        raise ValueError(fam)

    def prefill_fn(self, ctx: ParallelContext) -> Callable:
        mod = {"transformer": "repro.models.transformer",
               "rwkv6": "repro.models.rwkv6",
               "zamba2": "repro.models.zamba2"}[self.family]
        fn = importlib.import_module(mod).prefill_forward
        cfg = self.config
        return lambda p, b: fn(ctx, p, cfg, b)

    def decode_fn(self, ctx: ParallelContext) -> Callable:
        mod = {"transformer": "repro.models.transformer",
               "rwkv6": "repro.models.rwkv6",
               "zamba2": "repro.models.zamba2"}[self.family]
        fn = importlib.import_module(mod).decode_step
        cfg = self.config
        return lambda p, t, c, pos: fn(ctx, p, cfg, t, c, pos)

    # ---- paged serving (continuous batching) -----------------------------
    @property
    def supports_paged(self) -> bool:
        """Paged/block KV is implemented for GQA transformers; MLA and the
        recurrent families keep their dense caches/states."""
        return (self.family == "transformer"
                and getattr(self.config, "attn_type", None) == "gqa")

    def serve_step_fn(self, ctx: ParallelContext) -> Callable:
        """Mixed prefill-chunk/decode step over the paged pool:
        (params, tokens [B,C], pool, tables [B,MB], pos [B], n_new [B])
        -> (last-valid logits [B,V], new pool)."""
        from repro.models.transformer import serve_step

        cfg = self.config
        return lambda p, t, pool, tbl, pos, nn: serve_step(
            ctx, p, cfg, t, pool, tbl, pos, nn)

    def init_paged_pool(self, num_blocks: int, block_size: int):
        from repro.models.transformer import init_paged_pool

        return init_paged_pool(self.config, num_blocks, block_size)

    def pool_specs(self, pool):
        from repro.models.transformer import pool_logical_specs

        return pool_logical_specs(self.config, pool)

    # ---- caches ----------------------------------------------------------
    def with_max_seq(self, max_seq: int) -> "ArchBundle":
        if self.family in ("transformer", "zamba2"):
            return dataclasses.replace(
                self, config=dataclasses.replace(self.config, max_seq=max_seq))
        return self

    def init_cache(self, batch_size: int):
        if self.family == "transformer":
            from repro.models.transformer import init_cache

            return init_cache(self.config, batch_size)
        if self.family == "rwkv6":
            from repro.models.rwkv6 import init_state

            return init_state(self.config, batch_size)
        if self.family == "zamba2":
            from repro.models.zamba2 import init_cache

            return init_cache(self.config, batch_size)
        raise ValueError(self.family)

    def cache_specs(self, cache):
        if self.family == "transformer":
            from repro.models.transformer import cache_logical_specs

            return cache_logical_specs(self.config, cache)
        if self.family == "rwkv6":
            from repro.models.rwkv6 import state_logical_specs

            return state_logical_specs(self.config, cache)
        if self.family == "zamba2":
            from repro.models.zamba2 import cache_logical_specs

            return cache_logical_specs(self.config, cache)
        raise ValueError(self.family)

    def decode_param_specs(self, specs, params_struct=None):
        """Serve-time placement (weight-stationary decode):
        - expert weights shard over the (data x model) EP world;
        - large dense weights swap their FSDP dim for the EP world where
          the dim divides it -- XLA then emits partial-matmul + psum
          (activation-sized) instead of per-layer weight all-gathers."""
        if self.family != "transformer":
            return specs
        ep_world = 256
        is_spec = lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)
        expert_remap = {
            (None, "tp", "fsdp", None): (None, "ep", None, None),
            (None, "tp", None, "fsdp"): (None, "ep", None, None),
            ("tp", "fsdp", None): ("ep", None, None),
            ("tp", None, "fsdp"): ("ep", None, None),
        }

        moe = getattr(self.config, "moe", None)
        ep_ok = moe is not None and moe.n_experts % ep_world == 0

        def remap(spec, leaf):
            if ep_ok and spec in expert_remap:
                return expert_remap[spec]
            # large dense [in, out] weights: row-parallel serve placement
            # (contraction dim over model) -> partial matmul + small AR,
            # the paper's GEMV+AllReduce pattern, instead of FSDP gathers
            if "fsdp" in spec and "tp" not in spec and "ep" not in spec \
                    and leaf is not None and leaf.size >= 2 ** 22 \
                    and len(spec) >= 2:
                i = len(spec) - 2  # contraction dim of x @ w
                if leaf.shape[i] % 16 == 0:
                    return tuple(
                        "tp" if j == i else None for j in range(len(spec)))
            return spec

        if params_struct is None:
            return jax.tree.map(lambda s: expert_remap.get(s, s), specs,
                                is_leaf=is_spec)
        flat_s, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
        flat_p = jax.tree.leaves(params_struct)
        return jax.tree.unflatten(
            treedef, [remap(s, p) for s, p in zip(flat_s, flat_p)])

    @property
    def sub_quadratic(self) -> bool:
        return bool(getattr(self.config, "sub_quadratic", False))

    def shapes(self):
        if self.family == "dlrm":
            return dict(DLRM_SHAPES)
        out = {}
        for name, sh in SHAPES.items():
            if name == "long_500k" and not self.sub_quadratic:
                continue  # quadratic attention: skipped per DESIGN.md
            out[name] = sh
        return out

    # ---- per-shape input specs (ShapeDtypeStruct, no allocation) ---------
    def batch_struct(self, shape_name: str, ctx: ParallelContext):
        """Returns (batch_tree of ShapeDtypeStruct, logical spec tree)."""
        cfg = self.config
        if self.family == "dlrm":
            sh = DLRM_SHAPES[shape_name]
            B, T, L = sh["batch"], cfg.n_tables, cfg.pooling
            batch = {
                "dense": jax.ShapeDtypeStruct((B, cfg.n_dense), jnp.float32),
                "indices": jax.ShapeDtypeStruct((B, T, L), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B,), jnp.float32),
            }
            specs = {"dense": ("world", None), "indices": (None, "world", None),
                     "labels": ("world",)}
            return batch, specs
        sh = SHAPES[shape_name]
        S, B = sh["seq"], sh["batch"]
        kind = sh["kind"]
        bspec = "batch" if B % ctx.dp == 0 else None
        if kind == "decode":
            batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
            specs = {"tokens": (bspec, None)}
            return batch, specs
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"tokens": (bspec, None), "labels": (bspec, None)}
        fe = getattr(cfg, "frontend", None)
        if fe == "audio":
            batch["frame_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                         jnp.bfloat16)
            specs["frame_embeds"] = (bspec, "seq", None)
        if fe == "vision":
            batch["vision_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                          jnp.bfloat16)
            batch["vision_mask"] = jax.ShapeDtypeStruct((S,), jnp.bool_)
            batch["positions_thw"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            specs["vision_embeds"] = (bspec, "seq", None)
            specs["vision_mask"] = (None,)
            specs["positions_thw"] = (None, bspec, None)
        return batch, specs

    # ---- reduced smoke config --------------------------------------------
    def reduced(self) -> "ArchBundle":
        c = self.config
        if self.family == "transformer":
            over = dict(n_layers=2 + c.dense_prefix if c.dense_prefix else
                        2 * c.pattern_len, d_model=64,
                        d_ff=128, vocab=512, head_dim=None, max_seq=64,
                        param_dtype="float32", compute_dtype="float32")
            # keep head structure but tiny
            hd = 16
            over["head_dim"] = hd
            over["n_heads"] = max(4, min(c.n_heads, 4))
            kv = min(c.n_kv_heads, over["n_heads"])
            over["n_kv_heads"] = kv if over["n_heads"] % kv == 0 else over["n_heads"]
            if c.window:
                over["window"] = 16
            if c.mla is not None:
                over["n_heads"] = 4
                over["n_kv_heads"] = 4
                over["mla"] = dataclasses.replace(
                    c.mla, d_model=64, n_heads=4, q_lora_rank=32,
                    kv_lora_rank=16, qk_nope_dim=hd, qk_rope_dim=8,
                    v_head_dim=hd)
            if c.moe is not None:
                over["moe"] = dataclasses.replace(
                    c.moe, n_experts=8, top_k=min(c.moe.top_k, 2), d_model=64,
                    d_ff=32)
            if c.rope_style == "mrope":
                over["mrope_sections"] = (4, 6, 6)
                over["head_dim"] = 32
            if c.dense_prefix:
                over["dense_prefix"] = 1
                over["n_layers"] = 3
            return dataclasses.replace(
                self, config=dataclasses.replace(c, **over))
        if self.family == "rwkv6":
            return dataclasses.replace(self, config=dataclasses.replace(
                c, n_layers=2, d_model=64, d_ff=128, vocab=512, head_size=16,
                lora_r=8, chunk=8, param_dtype="float32",
                compute_dtype="float32"))
        if self.family == "zamba2":
            return dataclasses.replace(self, config=dataclasses.replace(
                c, n_layers=5, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                vocab=256, d_state=8, attn_every=2, lora_r=4, max_seq=64,
                param_dtype="float32", compute_dtype="float32"))
        if self.family == "dlrm":
            return dataclasses.replace(self, config=dataclasses.replace(
                c, n_tables=8, table_vocab=128, embed_dim=16, n_dense=4,
                bottom_mlp=(32, 16), top_mlp=(32, 1), pooling=5))
        raise ValueError(self.family)


def get_arch(name: str) -> ArchBundle:
    mod = importlib.import_module(_MODULES[name])
    return ArchBundle(name=name, family=mod.FAMILY, config=mod.CONFIG,
                      optimizer=getattr(mod, "OPTIMIZER", "adamw"),
                      microbatches=getattr(mod, "MICROBATCHES", 1))
