"""deepseek-v3-671b [moe] — 61L d7168, MLA (128H, q_lora 1536, kv_lora 512,
nope 128 / rope 64 / v 128), MoE 256 routed top-8 + 1 shared expert
(d_ff 2048 each), first 3 layers dense (d_ff 18432), vocab 129280
[arXiv:2412.19437].  Optimizer: Adafactor (factored state — f32 Adam
moments do not fit the production mesh; see DESIGN.md)."""
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab=129280, act="silu",
    attn_type="mla",
    mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                  kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_model=7168, d_ff=2048,
                  n_shared_experts=1, capacity_factor=1.25,
                  norm_topk_prob=True, router_scale=2.5),
    dense_prefix=3,
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"
OPTIMIZER = "adafactor"

MICROBATCHES = 4  # gradient accumulation (fits v5e HBM)
