"""musicgen-medium [audio] — 48L d1536 24H (MHA kv=24) d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens; codec frontend is a stub providing
precomputed frame embeddings [arXiv:2306.05284]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab=2048, head_dim=64, act="gelu",
    frontend="audio",
    param_dtype="bfloat16", compute_dtype="bfloat16",
)

FAMILY = "transformer"
