"""Liveness layer: heartbeat files + watchdog raising the chaos surface.

Every chaos-lane recovery path (:mod:`repro.runtime.fault_tolerance`,
the serve drain-reshard loop) fires on :class:`~repro.runtime.chaos.
CollectiveTimeout` / :class:`~repro.runtime.chaos.RankLost` — but until
the multiprocess lane those exceptions were only ever *injected* by a
:class:`~repro.runtime.chaos.FaultPlan`.  This module raises them from
genuine process liveness:

:class:`HeartbeatWriter`
    A daemon thread that atomically rewrites ``hb_<rank>.json`` every
    ``interval_s`` with (rank, pid, step, generation, wall time).  The
    thread keeps beating while the main thread is stuck inside a hung
    collective (native dispatch releases the GIL), so "process alive but
    wedged" and "process gone" are distinguishable from the outside.

:class:`LivenessMonitor`
    Classifies every peer's heartbeat file: fresh -> ``alive``; stale
    with a dead pid (or a ``leaving`` status) -> ``dead``; stale with a
    live pid (SIGSTOPped, wedged runtime) -> ``stalled``.  ``check()``
    converts the first non-alive peer into the existing fault surface —
    ``dead`` raises :class:`RankLost`, ``stalled`` raises
    :class:`CollectiveTimeout` — and ``guarded(fn, ...)`` runs one step
    on a worker thread while polling, so a *real* hang mid-collective
    (peer SIGKILLed between two ring sends) surfaces in ~1 s instead of
    after the XLA coordination service's ~40 s fatal teardown.

:class:`Watchdog`
    Background-thread wrapper over ``monitor.check()`` for tick loops
    that cannot poll inline (the serve engine between engine steps).

Everything is injectable (clock, pid prober, filesystem root), so the
classification matrix and both raise paths are unit-tested without
spawning processes; the genuine cross-process drills live in
``tests/multiprocess``.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
import time
from typing import Callable, Mapping

from repro.runtime.chaos import CollectiveTimeout, RankLost

log = logging.getLogger("repro.runtime")

#: heartbeat file name for one rank (all ranks share one directory)
HEARTBEAT_FMT = "hb_{rank}.json"

#: classification states returned by :meth:`LivenessMonitor.observe`
ALIVE, STARTING, STALLED, DEAD = "alive", "starting", "stalled", "dead"


@dataclasses.dataclass
class Heartbeat:
    """One rank's most recent liveness record."""

    rank: int
    pid: int
    time: float
    step: int = 0
    generation: int = 0
    status: str = "up"           # "up" | "leaving"

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def heartbeat_path(directory: str, rank: int) -> str:
    return os.path.join(directory, HEARTBEAT_FMT.format(rank=rank))


def write_heartbeat(directory: str, hb: Heartbeat) -> None:
    """Atomic single-file write: a reader never sees a torn record."""
    path = heartbeat_path(directory, hb.rank)
    tmp = f"{path}.tmp.{hb.pid}"
    with open(tmp, "w") as f:
        f.write(hb.to_json())
    os.replace(tmp, path)


def read_heartbeat(directory: str, rank: int) -> Heartbeat | None:
    """Best-effort read; missing/garbled files read as "no heartbeat yet"
    (a torn write is impossible, but a crashed writer can leave nothing)."""
    try:
        with open(heartbeat_path(directory, rank)) as f:
            return Heartbeat(**json.load(f))
    except (OSError, ValueError, TypeError):
        return None


def default_pid_alive(pid: int) -> bool:
    """Is ``pid`` running (including stopped)?  Signal 0 probes without
    delivering; only meaningful for processes on the same host — a
    multi-host deployment swaps in an ssh/agent prober here."""
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # exists, owned by someone else
        return True
    return True


class HeartbeatWriter:
    """Daemon thread beating ``hb_<rank>.json`` every ``interval_s``."""

    def __init__(self, directory: str, rank: int, *, generation: int = 0,
                 interval_s: float = 0.25, pid: int | None = None,
                 clock: Callable[[], float] = time.time):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.rank = rank
        self.generation = generation
        self.interval_s = interval_s
        self.pid = os.getpid() if pid is None else pid
        self.clock = clock
        self.step = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def beat(self, step: int | None = None, status: str = "up") -> None:
        if step is not None:
            self.step = int(step)
        write_heartbeat(self.directory, Heartbeat(
            rank=self.rank, pid=self.pid, time=self.clock(), step=self.step,
            generation=self.generation, status=status))

    def start(self) -> "HeartbeatWriter":
        self.beat()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"heartbeat-r{self.rank}")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.beat()

    def stop(self, status: str = "leaving") -> None:
        """Final beat with ``status`` so peers can tell a clean departure
        (elastic reshard exit) from a crash."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2 * self.interval_s)
            self._thread = None
        try:
            self.beat(status=status)
        except OSError:  # heartbeat dir torn down first: nothing to say
            pass

    def __enter__(self) -> "HeartbeatWriter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


@dataclasses.dataclass
class PeerState:
    """One peer's classification at one ``observe()`` instant."""

    rank: int
    state: str                   # ALIVE | STARTING | STALLED | DEAD
    age_s: float = 0.0
    pid: int | None = None
    step: int = 0


class LivenessMonitor:
    """Classify peers from their heartbeat files; raise the chaos surface.

    ``stall_after_s`` is the staleness deadline: a heartbeat older than
    this marks the peer non-alive (its writer thread beats every ~250 ms,
    so the default tolerates ~8 consecutive missed beats).  A non-alive
    peer whose pid is gone — or which wrote a ``leaving`` status — is
    ``DEAD`` (permanent, :class:`RankLost`); a non-alive peer whose pid
    still exists is ``STALLED`` (wedged or SIGSTOPped,
    :class:`CollectiveTimeout` — the transient restart path).  Peers
    that have not written a first heartbeat stay ``STARTING`` until
    ``start_grace_s`` (coordinator handshake + first compile), then
    count as dead.

    ``enabled`` gates ``check()``: workers arm the monitor after their
    first successful step so a long first compile on a loaded machine is
    never misread as a stall.
    """

    def __init__(self, directory: str, rank: int, world: int, *,
                 generation: int = 0, stall_after_s: float = 2.0,
                 start_grace_s: float = 120.0,
                 step_deadline_s: float | None = None,
                 pid_alive: Callable[[int], bool] = default_pid_alive,
                 clock: Callable[[], float] = time.time):
        self.directory = directory
        self.rank = rank
        self.world = world
        self.generation = generation
        self.stall_after_s = stall_after_s
        self.start_grace_s = start_grace_s
        self.step_deadline_s = step_deadline_s
        self.pid_alive = pid_alive
        self.clock = clock
        self.enabled = True
        self._t0 = clock()

    def _classify(self, rank: int, now: float) -> PeerState:
        hb = read_heartbeat(self.directory, rank)
        if hb is None or hb.generation < self.generation:
            state = STARTING if now - self._t0 < self.start_grace_s else DEAD
            return PeerState(rank=rank, state=state, age_s=now - self._t0)
        age = now - hb.time
        if hb.status != "up":
            return PeerState(rank=rank, state=DEAD, age_s=age, pid=hb.pid,
                             step=hb.step)
        if age <= self.stall_after_s:
            return PeerState(rank=rank, state=ALIVE, age_s=age, pid=hb.pid,
                             step=hb.step)
        state = STALLED if self.pid_alive(hb.pid) else DEAD
        return PeerState(rank=rank, state=state, age_s=age, pid=hb.pid,
                         step=hb.step)

    def observe(self) -> Mapping[int, PeerState]:
        """Classification for every peer rank (not this one)."""
        now = self.clock()
        return {r: self._classify(r, now)
                for r in range(self.world) if r != self.rank}

    def check(self) -> None:
        """Raise for the first lost/stalled peer.

        ``DEAD`` -> :class:`RankLost` (permanent: elastic shrink);
        ``STALLED`` -> :class:`CollectiveTimeout` (transient: coordinated
        restart).  Dead peers win over stalled ones — a dead rank is the
        stronger diagnosis and its recovery subsumes the restart."""
        if not self.enabled:
            return
        peers = self.observe()
        for st in peers.values():
            if st.state == DEAD:
                log.error("liveness: rank %d lost (pid %s, heartbeat "
                          "%.1fs stale)", st.rank, st.pid, st.age_s)
                raise RankLost(st.rank,
                               f"liveness: rank {st.rank} lost (heartbeat "
                               f"{st.age_s:.1f}s stale, pid gone)")
        for st in peers.values():
            if st.state == STALLED:
                log.error("liveness: rank %d stalled (pid %s alive, "
                          "heartbeat %.1fs stale)", st.rank, st.pid, st.age_s)
                raise CollectiveTimeout(
                    f"liveness: rank {st.rank} stalled (pid {st.pid} alive, "
                    f"heartbeat {st.age_s:.1f}s stale)")

    def guarded(self, fn: Callable, *args, deadline_s: float | None = None,
                poll_s: float = 0.05, **kwargs):
        """Run ``fn(*args)`` while polling peer liveness.

        The call runs on a daemon thread; the caller polls ``check()``
        while joining, so a hang inside a collective (the peer died
        between ring sends) raises within ~``poll_s`` of detection
        rather than blocking until the XLA runtime's fatal teardown.
        ``deadline_s`` (default :attr:`step_deadline_s`) additionally
        bounds the call even with every peer apparently healthy — the
        deadlocked-but-heartbeating case.

        On a liveness raise the worker thread is *abandoned* mid-call
        (it is wedged in native code and cannot be cancelled); the
        caller is expected to checkpoint nothing and exit the process —
        the elastic-respawn protocol in :mod:`repro.runtime.
        multiprocess`."""
        if deadline_s is None:
            deadline_s = self.step_deadline_s
        box: list = [None, None]   # [result, exception]
        done = threading.Event()

        def work():
            try:
                box[0] = fn(*args, **kwargs)
            except BaseException as e:  # surfaced on the caller thread
                box[1] = e
            finally:
                done.set()

        t = threading.Thread(target=work, daemon=True, name="guarded-step")
        start = self.clock()
        t.start()
        while not done.wait(poll_s):
            self.check()
            if deadline_s is not None and self.clock() - start > deadline_s:
                raise CollectiveTimeout(
                    f"step exceeded deadline {deadline_s:.1f}s with all "
                    f"peers heartbeating (deadlocked collective?)")
        if box[1] is not None:
            raise box[1]
        return box[0]


class Watchdog:
    """Background-thread watchdog for loops that cannot poll inline.

    Polls ``monitor.check()`` every ``poll_s``; the first raise is
    parked and re-raised from :meth:`maybe_raise` (call it once per
    tick) — the serve engine's drain loop does this between engine
    steps."""

    def __init__(self, monitor: LivenessMonitor, *, poll_s: float = 0.25):
        self.monitor = monitor
        self.poll_s = poll_s
        self.failure: Exception | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="watchdog")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self.monitor.check()
            except (RankLost, CollectiveTimeout) as e:
                self.failure = e
                return

    def maybe_raise(self) -> None:
        if self.failure is not None:
            raise self.failure

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2 * self.poll_s)
            self._thread = None

    def __enter__(self) -> "Watchdog":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
