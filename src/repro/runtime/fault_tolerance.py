"""Fault-tolerant training supervisor.

Wraps the step loop with: periodic (async) checkpoints, automatic
restore-and-retry on failure with bounded restarts, and a straggler
watchdog.  On a real cluster the inner failure is a lost host /
NCCL-equivalent timeout surfacing as a RuntimeError from the collective;
here any exception from the step function triggers the same path, which
is what the chaos tests inject.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Iterator

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    async_save: bool = True


class TrainSupervisor:
    """Drives (state, batch) -> (state, metrics) with checkpoint/restart."""

    def __init__(self, cfg: SupervisorConfig, step_fn: Callable,
                 state_shardings=None, skew_scheduler=None,
                 per_rank_times: Callable | str | None = None):
        """``skew_scheduler`` (a :class:`~repro.runtime.straggler.
        SkewScheduler`) closes the Fig. 14 loop: each step's wall time is
        fed to it (expanded to a per-rank vector by ``per_rank_times`` —
        by default the local time replicated, which keeps the rotation at
        0) and on a bucket change the supervisor swaps in the re-jitted
        step for the new schedule.  When set, it also *owns* the step
        function — ``step_fn`` is ignored in favor of
        ``skew_scheduler.fn()``.

        ``per_rank_times="process"`` installs the multi-host provider: a
        process all-gather of this supervisor's own straggler-monitor
        EWMA (:class:`~repro.runtime.straggler.ProcessTelemetry`), so the
        estimator runs on *measured* cross-rank times instead of injected
        ones."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.state_shardings = state_shardings
        self.manager = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep,
                                         async_save=cfg.async_save)
        self.straggler = StragglerMonitor()
        self.skew_scheduler = skew_scheduler
        if per_rank_times == "process":
            if skew_scheduler is None:
                raise ValueError("per_rank_times='process' needs a "
                                 "skew_scheduler (its estimator defines "
                                 "the world size)")
            from repro.runtime.straggler import ProcessTelemetry

            per_rank_times = ProcessTelemetry(
                self.straggler, skew_scheduler.estimator.world)
        self.per_rank_times = per_rank_times
        if skew_scheduler is not None:
            self.step_fn = skew_scheduler.fn()
        self.restarts = 0

    def _feed_skew(self, dt: float) -> None:
        sched = self.skew_scheduler
        if sched is None:
            return
        world = sched.estimator.world
        times = (self.per_rank_times(dt) if self.per_rank_times is not None
                 else [dt] * world)
        if sched.observe(times):
            log.info("skew bucket -> %d (axis %r); re-jitting schedules",
                     sched.bucket, sched.axis)
            self.step_fn = sched.fn()

    def maybe_restore(self, state):
        restored = self.manager.restore_latest(state, self.state_shardings)
        if restored is None:
            return state, 0
        new_state, step = restored
        log.info("restored checkpoint at step %d", step)
        return new_state, step

    def run(self, state, batches: Iterator, num_steps: int,
            start_step: int = 0, on_metrics: Callable | None = None):
        step = start_step
        state, ckpt_step = self.maybe_restore(state)
        step = max(step, ckpt_step)
        it = iter(batches)
        while step < num_steps:
            batch = next(it)
            t0 = time.monotonic()
            try:
                state, metrics = self.step_fn(state, batch)
                # touching a metric forces dispatch, surfacing async errors
                _ = float(metrics["loss"])
            except Exception as e:  # node failure path
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d", step, e,
                          self.restarts, self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, ckpt_step = self.maybe_restore(state)
                step = ckpt_step
                continue
            dt = time.monotonic() - t0
            self.straggler.record(dt)
            self._feed_skew(dt)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % self.cfg.checkpoint_every == 0:
                self.manager.save(step, state)
        self.manager.wait()
        return state, step
