"""Fault-tolerant training supervisor.

Wraps the step loop with: periodic (async) checkpoints, automatic
restore-and-retry on failure with exponentially backed-off restarts, a
restart budget that heals after sustained healthy running, batch replay
so a restored step sees the same data it saw before the failure, and a
straggler watchdog.  On a real cluster the inner failure is a lost host /
NCCL-equivalent timeout surfacing as a RuntimeError from the collective;
here any exception from the step function triggers the same path, which
is what the chaos tests inject (:mod:`repro.runtime.chaos`).

Failure taxonomy, mapped to recovery actions:

=============  =======================================  ==================
fault          surfaces as                              recovery
=============  =======================================  ==================
transient      ``CollectiveTimeout`` / any exception    backoff, restore
               from the step                            latest checkpoint,
                                                        replay batches
non-finite     ``NonFiniteLoss`` (NaN/inf loss — e.g.   same as transient;
loss           a corrupt wire payload)                  the poisoned state
                                                        is never saved
permanent      ``RankLost``                             ``on_rank_loss``
rank loss                                               shrinks the mesh,
                                                        reshards state,
                                                        replays the step
=============  =======================================  ==================
"""
from __future__ import annotations

import dataclasses
import logging
import math
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import ReplayBuffer
from repro.runtime.chaos import CollectiveTimeout, RankLost, wire_faults
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


class NonFiniteLoss(RuntimeError):
    """The step produced a NaN/inf loss — treated as a fault, not a result.

    The supervisor restores from the last checkpoint instead of letting a
    poisoned optimizer state propagate (and never checkpoints it)."""


@dataclasses.dataclass
class SupervisorConfig:
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    async_save: bool = True
    # Restart pacing: sleep min(backoff_max_s, backoff_base_s * 2**(k-1))
    # * (1 + backoff_jitter * U[0,1)) before the k-th consecutive restart
    # (jitter decorrelates a fleet of supervisors hammering shared storage).
    backoff_base_s: float = 0.1
    backoff_max_s: float = 30.0
    backoff_jitter: float = 0.25
    # Budget healing: after this many consecutive healthy steps, forgive
    # one restart — sporadic transient faults over a long run no longer
    # exhaust the same budget that guards against crash loops.
    heal_after: int = 25
    seed: int = 0


class TrainSupervisor:
    """Drives (state, batch) -> (state, metrics) with checkpoint/restart."""

    def __init__(self, cfg: SupervisorConfig, step_fn: Callable,
                 state_shardings=None, skew_scheduler=None,
                 per_rank_times: Callable | str | None = None,
                 fault_plan=None, degradation=None,
                 rebuild_step: Callable[[], Callable] | None = None,
                 on_rank_loss: Callable | None = None,
                 liveness=None,
                 sleep_fn: Callable[[float], None] = time.sleep):
        """``skew_scheduler`` (a :class:`~repro.runtime.straggler.
        SkewScheduler`) closes the Fig. 14 loop: each step's wall time is
        fed to it (expanded to a per-rank vector by ``per_rank_times`` —
        by default the local time replicated, which keeps the rotation at
        0) and on a bucket change the supervisor swaps in the re-jitted
        step for the new schedule.  When set, it also *owns* the step
        function — ``step_fn`` is ignored in favor of
        ``skew_scheduler.fn()``.

        ``per_rank_times="process"`` installs the multi-host provider: a
        process all-gather of this supervisor's own straggler-monitor
        EWMA (:class:`~repro.runtime.straggler.ProcessTelemetry`), so the
        estimator runs on *measured* cross-rank times instead of injected
        ones.

        Chaos/degradation wiring (all optional):

        ``fault_plan`` — a :class:`~repro.runtime.chaos.FaultPlan`; its
        events are injected at the matching step, each exactly once (the
        replay of a recovered step runs clean, so transient faults
        terminate).

        ``degradation`` — a :class:`~repro.core.degrade.DegradationPolicy`;
        failures strike the active op keys and quarantined families run
        their bulk collective until the cooldown releases them.

        ``rebuild_step`` — zero-arg callable returning a *freshly traced*
        jitted step (it must wrap the raw function in a new closure each
        call; re-jitting the same callable object can reuse the cached
        jaxpr and miss trace-time hooks).  Used to re-jit after a
        degradation change and to trace NaN-wire injection into a
        poisoned step.  Without it, degradation changes only apply to
        future traces and ``nan_wire`` events fall back to synthesizing a
        NaN loss (the observable effect of a poisoned all-reduce).

        ``on_rank_loss`` — ``(state, RankLost) -> (state, step_fn|None)``
        elastic handler: shrink the mesh, reshard ``state``, return the
        re-jitted step for the new topology.  ``None`` re-raises (rank
        loss is then fatal).

        ``liveness`` — a :class:`~repro.runtime.watchdog.LivenessMonitor`
        wired to *real* process heartbeats.  When set, every step first
        checks peer liveness and then runs under ``liveness.guarded`` —
        a genuine peer death or stall mid-collective surfaces as the
        same :class:`RankLost` / :class:`CollectiveTimeout` the chaos
        injector produces, through the same recovery paths.  In the
        multi-process deployment ``on_rank_loss`` is normally *not* set
        alongside this: an in-process shrink cannot survive a dead gloo
        world, so RankLost propagates to the worker, which exits for
        the elastic respawn (:mod:`repro.runtime.multiprocess`).  Note
        the guarded step runs on a side thread; with buffer donation a
        liveness raise abandons a step that may have consumed its
        inputs — callers on that path must restore or exit, never
        retry the same state in place.

        ``sleep_fn`` — injection point for the backoff clock (tests
        record delays instead of sleeping)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.state_shardings = state_shardings
        self.manager = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep,
                                         async_save=cfg.async_save)
        self.straggler = StragglerMonitor()
        self.skew_scheduler = skew_scheduler
        if per_rank_times == "process":
            if skew_scheduler is None:
                raise ValueError("per_rank_times='process' needs a "
                                 "skew_scheduler (its estimator defines "
                                 "the world size)")
            from repro.runtime.straggler import ProcessTelemetry

            per_rank_times = ProcessTelemetry(
                self.straggler, skew_scheduler.estimator.world)
        self.per_rank_times = per_rank_times
        if skew_scheduler is not None:
            self.step_fn = skew_scheduler.fn()
        self.fault_plan = fault_plan
        self.degradation = degradation
        self.rebuild_step = rebuild_step
        self.on_rank_loss = on_rank_loss
        self.liveness = liveness
        self.sleep_fn = sleep_fn
        self._rng = np.random.default_rng(cfg.seed)
        self._fired: set = set()   # (step, event) pairs already injected
        self.restarts = 0
        self.healthy_streak = 0
        self.backoffs: list[float] = []
        self.faults_injected = 0
        self.rank_losses = 0

    def _begin_trace(self) -> None:
        """Reset the degradation policy's active-key ledger before any
        fresh trace: the new trace repopulates it via ``effective_mode``,
        so a later ``record_failure(None)`` blames only ops that are
        actually live — not keys left over from retired traces."""
        if self.degradation is not None:
            self.degradation.begin_trace()

    def _feed_skew(self, dt: float) -> None:
        sched = self.skew_scheduler
        if sched is None:
            return
        world = sched.estimator.world
        times = (self.per_rank_times(dt) if self.per_rank_times is not None
                 else [dt] * world)
        if sched.observe(times):
            log.info("skew bucket -> %d (axis %r); re-jitting schedules",
                     sched.bucket, sched.axis)
            self._begin_trace()
            self.step_fn = sched.fn()

    def maybe_restore(self, state):
        restored = self.manager.restore_latest(state, self.state_shardings)
        if restored is None:
            return state, 0
        new_state, step = restored
        log.info("restored checkpoint at step %d", step)
        return new_state, step

    # -- fault injection -------------------------------------------------

    def _events_for(self, step: int):
        """This step's not-yet-fired plan events (replay runs clean)."""
        if self.fault_plan is None:
            return ()
        fresh = tuple(ev for ev in self.fault_plan.at(step)
                      if (step, ev) not in self._fired)
        for ev in fresh:
            self._fired.add((step, ev))
        return fresh

    def _poisoned_step(self, state, batch, ev):
        """Run one step with a NaN injected into the ``ev.nth_send``-th
        wire payload.  The injection is a trace-time hook, so the raw
        step must be re-traced inside the context — a cached jitted step
        would replay its clean jaxpr."""
        if self.rebuild_step is None:
            state, metrics = self.step_fn(state, batch)
            metrics = dict(metrics)
            metrics["loss"] = float("nan")
            return state, metrics
        with wire_faults(nth_send=ev.nth_send):
            self._begin_trace()
            fn = self.rebuild_step()
            return fn(state, batch)

    def _run_step(self, state, batch, events):
        nan_ev = None
        for ev in events:
            self.faults_injected += 1
            if ev.kind == "slow_link":
                self.sleep_fn(ev.delay_s)
            elif ev.kind == "rank_loss":
                raise RankLost(ev.rank)
            elif ev.kind in ("timeout", "rank_fail"):
                raise CollectiveTimeout(
                    f"injected {ev.kind} (rank {ev.rank})")
            else:  # nan_wire
                nan_ev = ev
        if nan_ev is not None:
            return self._poisoned_step(state, batch, nan_ev)
        if self.liveness is not None:
            # real liveness: refuse to enter a collective against a peer
            # already known dead, and poll heartbeats while inside one —
            # a genuine hang raises in ~poll interval instead of blocking
            # until the runtime's fatal teardown.
            self.liveness.check()
            return self.liveness.guarded(self.step_fn, state, batch)
        return self.step_fn(state, batch)

    def _save(self, step, state):
        """Checkpoint save under the liveness guard.

        On a multi-process mesh the save's host gather is itself a
        collective — a peer wedged (SIGSTOP) while we are inside it
        would hang the save until the XLA runtime's fatal teardown, so
        it runs guarded exactly like a step."""
        if self.liveness is not None:
            self.liveness.check()
            self.liveness.guarded(self.manager.save, step, state)
        else:
            self.manager.save(step, state)

    # -- recovery --------------------------------------------------------

    def _maybe_rebuild(self) -> None:
        """Re-jit after a quarantine-set change: cached traces bake in the
        mode that was live when they were traced."""
        if self.degradation is None or not self.degradation.consume_dirty():
            return
        if self.skew_scheduler is not None:
            self._begin_trace()
            self.skew_scheduler.invalidate()
            self.step_fn = self.skew_scheduler.fn()
        elif self.rebuild_step is not None:
            self._begin_trace()
            self.step_fn = self.rebuild_step()
        else:
            log.warning("degradation changed but no rebuild_step/"
                        "skew_scheduler: cached traces keep the old mode")

    def _backoff(self) -> None:
        delay = min(self.cfg.backoff_max_s,
                    self.cfg.backoff_base_s * 2.0 ** (self.restarts - 1))
        delay *= 1.0 + self.cfg.backoff_jitter * float(self._rng.random())
        self.backoffs.append(delay)
        self.sleep_fn(delay)

    def _handle_failure(self, step: int, e: Exception) -> None:
        self.restarts += 1
        self.healthy_streak = 0
        log.error("step %d failed (%s); restart %d/%d", step, e,
                  self.restarts, self.cfg.max_restarts)
        if self.degradation is not None:
            jailed = self.degradation.record_failure()
            if jailed:
                log.warning("quarantined to bulk collectives: %s", jailed)
            self._maybe_rebuild()
        if self.restarts > self.cfg.max_restarts:
            raise e
        self._backoff()

    # -- main loop -------------------------------------------------------

    def run(self, state, batches: Iterator, num_steps: int,
            start_step: int = 0, on_metrics: Callable | None = None):
        step = start_step
        state, ckpt_step = self.maybe_restore(state)
        step = max(step, ckpt_step)
        if not self.manager.all_steps():
            # Failures before the first periodic save need something to
            # restore onto — and with buffer donation the pre-step state
            # is unrecoverable in-process once a step has consumed it.
            self._save(step, state)
        last_saved = step
        replay = ReplayBuffer(batches, base_step=step)
        while step < num_steps:
            try:
                batch = replay.next_batch()
            except StopIteration:
                log.warning("data exhausted at step %d/%d; saving partial "
                            "run and draining", step, num_steps)
                if step != last_saved:
                    self._save(step, state)
                break
            events = self._events_for(step)
            t0 = time.monotonic()
            try:
                state, metrics = self._run_step(state, batch, events)
                # force the full metrics tree (not just the loss): any
                # leaf may carry an in-flight cross-process collective,
                # and the checkpoint gather below must not start while
                # one is still executing.  Also surfaces async errors
                # and gates on a finite loss.
                metrics = jax.block_until_ready(metrics)
                loss = float(metrics["loss"])
                if not math.isfinite(loss):
                    raise NonFiniteLoss(
                        f"loss={loss!r} at step {step}")
            except RankLost as e:
                self.rank_losses += 1
                if self.on_rank_loss is None:
                    raise
                log.error("rank %d lost at step %d; shrinking mesh",
                          e.rank, step)
                state, new_fn = self.on_rank_loss(state, e)
                if new_fn is not None:
                    self._begin_trace()
                    self.step_fn = new_fn
                replay.rewind(step)
                continue
            except Exception as e:  # node failure path
                self._handle_failure(step, e)
                state, ckpt_step = self.maybe_restore(state)
                step = ckpt_step
                replay.rewind(step)
                continue
            dt = time.monotonic() - t0
            self.straggler.record(dt)
            self._feed_skew(dt)
            self.healthy_streak += 1
            if self.degradation is not None:
                released = self.degradation.record_healthy()
                if released:
                    log.info("cooldown over; re-probing fused path for %s",
                             released)
                self._maybe_rebuild()
            if self.restarts > 0 and self.healthy_streak >= self.cfg.heal_after:
                self.restarts -= 1
                self.healthy_streak = 0
                log.info("sustained healthy run; restart budget healed "
                         "to %d/%d", self.restarts, self.cfg.max_restarts)
            step += 1
            if on_metrics is not None:
                on_metrics(step, metrics)
            if step % self.cfg.checkpoint_every == 0:
                self._save(step, state)
                last_saved = step
                replay.commit(step)
        self.manager.wait()
        return state, step
