"""Real multi-process scale-out: subprocess driver + worker bootstrap.

Everything before this module exercised the recovery stack inside one
process on one host's 8-device mesh.  Here the process boundary is
genuine: a :class:`MultiprocessDriver` spawns N coordinator-wired CPU
workers (``jax.distributed.initialize`` + gloo cross-process
collectives, ``--xla_force_host_platform_device_count`` local devices
each), collects per-process logs and exit codes, and supervises the
*elastic respawn protocol*:

1. Workers heartbeat (:mod:`repro.runtime.watchdog`) and run every step
   under the liveness monitor.  A SIGKILLed peer is detected in ~1 s —
   long before the XLA coordination service's ~40 s fatal teardown —
   and surfaces as :class:`~repro.runtime.chaos.RankLost`; a stalled
   (SIGSTOPped / wedged) peer surfaces as
   :class:`~repro.runtime.chaos.CollectiveTimeout`.
2. The worker exits with a *protocol code*: :data:`EXIT_RESHARD` (peer
   permanently lost — relaunch me on the shrunk world) or
   :data:`EXIT_RESTART` (transient stall — relaunch the same world).
   In-process survival is impossible on a dead gloo world: the runtime
   cannot tear down a distributed client whose peer is gone without a
   fatal abort, so recovery is respawn-based (the torchelastic model).
3. The driver reaps stragglers, allocates a fresh coordinator port, and
   relaunches the next *generation* with a dense rank assignment.
   Workers restore from the shared checkpoint directory + the
   deterministic seeded batch stream (the cross-process analogue of
   :class:`~repro.data.pipeline.ReplayBuffer`), so a recovered run's
   final state is pinned bit-identical against a fault-free run on the
   shrunk mesh — the invariant ``tests/multiprocess`` enforces.

Worker-side helpers encode the placement rules a multi-process mesh
needs on the gloo CPU backend (validated empirically, see
``tests/multiprocess``):

* pin ``jax.default_device`` to a local device — rank > 0's default is
  otherwise a *remote* device and eager constants race cross-process
  transfers against collectives;
* build global arrays by host-staging (``device_put`` from numpy places
  local shards only); resharding committed device arrays across
  processes through gloo is not supported;
* gather non-fully-addressable arrays via
  ``multihost_utils.process_allgather(tiled=True)`` (the checkpoint
  path does this automatically).

This module must stay importable without touching the jax backend:
``jax`` is imported lazily so workers can call :func:`configure` (which
sets ``XLA_FLAGS``) after importing it.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import signal
import socket
import subprocess
import sys
import threading
import time
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.perfmodel import DCN, HardwareModel
from repro.runtime.watchdog import (HeartbeatWriter, LivenessMonitor,
                                    read_heartbeat)

log = logging.getLogger("repro.runtime")

#: worker exit codes — the driver's respawn protocol
EXIT_OK = 0
EXIT_RESTART = 16   # transient stall (CollectiveTimeout): same-world respawn
EXIT_RESHARD = 17   # permanent peer loss (RankLost): shrunk-world respawn

_ENV_PREFIX = "REPRO_MP_"


def pick_free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclasses.dataclass
class WorkerEnv:
    """Per-worker contract, shipped through the environment."""

    rank: int
    world: int
    coordinator: str
    generation: int = 0
    heartbeat_dir: str = ""
    local_devices: int = 4
    extra: dict = dataclasses.field(default_factory=dict)

    def to_env(self) -> dict[str, str]:
        return {
            f"{_ENV_PREFIX}RANK": str(self.rank),
            f"{_ENV_PREFIX}WORLD": str(self.world),
            f"{_ENV_PREFIX}COORD": self.coordinator,
            f"{_ENV_PREFIX}GEN": str(self.generation),
            f"{_ENV_PREFIX}HBDIR": self.heartbeat_dir,
            f"{_ENV_PREFIX}LOCAL_DEVICES": str(self.local_devices),
            f"{_ENV_PREFIX}EXTRA": json.dumps(self.extra),
        }

    @classmethod
    def from_env(cls, env: Mapping[str, str] | None = None) -> "WorkerEnv":
        env = os.environ if env is None else env
        return cls(
            rank=int(env[f"{_ENV_PREFIX}RANK"]),
            world=int(env[f"{_ENV_PREFIX}WORLD"]),
            coordinator=env[f"{_ENV_PREFIX}COORD"],
            generation=int(env.get(f"{_ENV_PREFIX}GEN", "0")),
            heartbeat_dir=env.get(f"{_ENV_PREFIX}HBDIR", ""),
            local_devices=int(env.get(f"{_ENV_PREFIX}LOCAL_DEVICES", "4")),
            extra=json.loads(env.get(f"{_ENV_PREFIX}EXTRA", "{}")),
        )


# -- worker side -----------------------------------------------------------

def configure(cfg: WorkerEnv, *, platform: str = "cpu",
              collectives: str = "gloo") -> None:
    """Point the (not yet initialized) backend at this worker's slice.

    Must run before any jax device/backend touch.  ``XLA_FLAGS`` is
    *replaced*, not appended — the driver's own device-count flag must
    not leak into workers."""
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={cfg.local_devices}")
    import jax

    if platform:
        jax.config.update("jax_platforms", platform)
    if platform == "cpu" and cfg.world > 1:
        jax.config.update("jax_cpu_collectives_implementation", collectives)


@dataclasses.dataclass
class WorkerRuntime:
    """Live per-worker handles returned by :func:`init_worker`."""

    cfg: WorkerEnv
    writer: HeartbeatWriter
    monitor: LivenessMonitor
    _default_device_ctx: object = None

    # -- placement helpers (the gloo-safe recipes) ----------------------
    def global_put(self, tree, shardings):
        """Place a pytree with global shardings by host-staging each leaf.

        Host-staging is mandatory twice over on the gloo CPU backend:
        resharding a *committed* device array across processes is not
        supported, and even ``device_put`` from numpy onto a
        non-addressable sharding would run a per-leaf broadcast
        collective (jax's equal-value check) — so placement goes through
        the collective-free :func:`~repro.checkpoint.checkpointer.
        host_to_device` path."""
        import jax

        from repro.checkpoint.checkpointer import host_to_device

        return jax.tree.map(
            lambda x, s: host_to_device(
                np.asarray(jax.device_get(x)), s), tree, shardings)

    def host_gather(self, tree):
        """Full host value of every leaf, gathering non-addressable
        shards through one replicated-output computation (collective:
        every process must call it)."""
        from repro.checkpoint.checkpointer import tree_to_host

        return tree_to_host(tree)

    def barrier(self, name: str = "barrier") -> None:
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices(name)

    def diagnose(self, exc: BaseException, *, extra_wait_s: float = 3.0):
        """Translate a transport-level failure into the liveness verdict.

        A peer that dies *inside* a collective surfaces first as a raw
        XLA/gloo error ("connection closed by peer") — often before its
        heartbeat goes stale.  Rather than crash on the transport error,
        poll the watchdog for up to one staleness deadline (plus grace):
        if it classifies a peer DEAD or STALLED, raise the corresponding
        :class:`RankLost`/:class:`CollectiveTimeout` so the caller takes
        the elastic-respawn path; otherwise re-raise the original error.
        """
        from repro.runtime.chaos import CollectiveTimeout, RankLost

        deadline = time.monotonic() + self.monitor.stall_after_s + extra_wait_s
        enabled, self.monitor.enabled = self.monitor.enabled, True
        try:
            while time.monotonic() < deadline:
                self.monitor.check()   # raises RankLost/CollectiveTimeout
                time.sleep(0.1)
        except (RankLost, CollectiveTimeout) as verdict:
            raise verdict from exc
        finally:
            self.monitor.enabled = enabled
        raise exc

    # -- lifecycle ------------------------------------------------------
    def leave(self, code: int = EXIT_OK, status: str = "leaving") -> None:
        """Terminate this worker with a protocol exit code.

        ``os._exit`` on purpose: after a peer death the distributed
        client cannot be shut down cleanly (the shutdown barrier would
        hang, then abort), and on a healthy world the final barrier has
        already ordered everything we care about."""
        sys.stdout.flush()
        sys.stderr.flush()
        self.writer.stop(status=status)
        os._exit(code)


def init_worker(cfg: WorkerEnv, *, initialization_timeout: int = 60,
                stall_after_s: float = 2.0,
                step_deadline_s: float | None = None) -> WorkerRuntime:
    """Wire this process into the distributed world and start liveness.

    The returned monitor starts *disarmed* (``enabled=False``): arm it
    after the first successful step so first-compile time can never be
    misread as a peer stall."""
    configure(cfg)
    import jax

    from repro.launch.distributed import initialize_distributed

    if cfg.world > 1:
        initialize_distributed(cfg.coordinator, cfg.world, cfg.rank,
                               initialization_timeout=initialization_timeout)
    # rank > 0's default device would be process 0's first device — every
    # eager constant would land remotely and race the collectives.
    dd = jax.default_device(jax.local_devices()[0])
    dd.__enter__()
    writer = HeartbeatWriter(cfg.heartbeat_dir or ".", cfg.rank,
                             generation=cfg.generation).start()
    monitor = LivenessMonitor(cfg.heartbeat_dir or ".", cfg.rank, cfg.world,
                              generation=cfg.generation,
                              stall_after_s=stall_after_s,
                              step_deadline_s=step_deadline_s)
    monitor.enabled = False
    return WorkerRuntime(cfg=cfg, writer=writer, monitor=monitor,
                         _default_device_ctx=dd)


# -- driver side -----------------------------------------------------------

@dataclasses.dataclass
class ProcHandle:
    rank: int
    popen: subprocess.Popen
    log_path: str
    reaped_by_driver: bool = False

    @property
    def returncode(self):
        return self.popen.returncode


@dataclasses.dataclass
class GenerationResult:
    generation: int
    world: int
    codes: dict            # rank -> exit code (negative = killed by signal)
    duration_s: float
    heartbeat_dir: str


@dataclasses.dataclass
class ElasticReport:
    """Outcome of :meth:`MultiprocessDriver.run_elastic`."""

    completed: bool
    generations: list
    timeline: list         # (event, detail, wall_time) tuples

    def events(self, kind: str):
        return [t for t in self.timeline if t[0] == kind]


class MultiprocessDriver:
    """Spawn, watch, reap, and elastically respawn worker generations.

    ``worker_argv`` is the worker command after the interpreter (script
    path + args).  Per-generation artifacts land under ``workdir``:
    ``logs/g<gen>_r<rank>.log`` and heartbeat dir ``hb_g<gen>``.

    The driver is also the *coordinator-side watchdog*: while waiting on
    a generation it polls worker heartbeats and pids, and once any
    worker has exited abnormally it gives the remainder ``hang_grace_s``
    to finish their own detection before reaping them (SIGCONT+SIGKILL —
    a SIGSTOPped straggler would otherwise hold the generation open
    forever)."""

    def __init__(self, worker_argv: Sequence[str], nproc: int, *,
                 devices_per_proc: int = 4, workdir: str = ".",
                 extra: dict | None = None,
                 env: Mapping[str, str] | None = None,
                 hang_grace_s: float = 30.0):
        self.worker_argv = list(worker_argv)
        self.nproc = nproc
        self.devices_per_proc = devices_per_proc
        self.workdir = workdir
        self.extra = dict(extra or {})
        self.base_env = dict(os.environ if env is None else env)
        self.hang_grace_s = hang_grace_s
        self.procs: list[ProcHandle] = []
        self.generation = -1
        self.heartbeat_dir = ""
        self.timeline: list = []
        os.makedirs(os.path.join(workdir, "logs"), exist_ok=True)

    # -- spawn ----------------------------------------------------------
    def launch_generation(self, generation: int, world: int,
                          extra: dict | None = None) -> None:
        if any(p.popen.poll() is None for p in self.procs):
            raise RuntimeError("previous generation still running")
        self.generation = generation
        self.heartbeat_dir = os.path.join(self.workdir, f"hb_g{generation}")
        os.makedirs(self.heartbeat_dir, exist_ok=True)
        coordinator = f"127.0.0.1:{pick_free_port()}"
        self.procs = []
        self._mark("launch", {"generation": generation, "world": world})
        for rank in range(world):
            cfg = WorkerEnv(rank=rank, world=world, coordinator=coordinator,
                            generation=generation,
                            heartbeat_dir=self.heartbeat_dir,
                            local_devices=self.devices_per_proc,
                            extra={**self.extra, **(extra or {})})
            env = dict(self.base_env)
            env.pop("XLA_FLAGS", None)   # workers set their own device count
            env.update(cfg.to_env())
            src = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
            env["PYTHONPATH"] = os.pathsep.join(
                [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                         if p])
            log_path = os.path.join(self.workdir, "logs",
                                    f"g{generation}_r{rank}.log")
            f = open(log_path, "w")
            popen = subprocess.Popen(
                [sys.executable, "-u"] + self.worker_argv,
                stdout=f, stderr=subprocess.STDOUT, env=env)
            f.close()
            self.procs.append(ProcHandle(rank=rank, popen=popen,
                                         log_path=log_path))

    # -- observe / fault ------------------------------------------------
    def _mark(self, event: str, detail) -> None:
        self.timeline.append((event, detail, time.time()))

    def heartbeat_step(self, rank: int) -> int | None:
        hb = read_heartbeat(self.heartbeat_dir, rank)
        return None if hb is None else hb.step

    def wait_for_step(self, rank: int, step: int,
                      timeout_s: float = 300.0) -> int:
        """Block until ``rank``'s heartbeat reports ``step`` or later."""
        deadline = time.time() + timeout_s
        while time.time() < deadline:
            seen = self.heartbeat_step(rank)
            if seen is not None and seen >= step:
                return seen
            if self.procs[rank].popen.poll() is not None:
                raise RuntimeError(
                    f"rank {rank} exited (code {self.procs[rank].returncode})"
                    f" before reaching step {step}")
            time.sleep(0.05)
        raise TimeoutError(f"rank {rank} never reached step {step} "
                           f"within {timeout_s:.0f}s")

    def kill(self, rank: int, sig: int = signal.SIGKILL) -> float:
        """Signal one worker; returns the wall time of delivery."""
        self.procs[rank].popen.send_signal(sig)
        t = time.time()
        self._mark("kill", {"generation": self.generation, "rank": rank,
                            "signal": int(sig)})
        return t

    def kill_at_step(self, rank: int, step: int,
                     sig: int = signal.SIGKILL,
                     timeout_s: float = 300.0) -> float:
        self.wait_for_step(rank, step, timeout_s)
        return self.kill(rank, sig)

    # -- reap -----------------------------------------------------------
    def _reap(self, proc: ProcHandle) -> None:
        for sig in (signal.SIGCONT, signal.SIGKILL):
            try:
                proc.popen.send_signal(sig)
            except ProcessLookupError:
                pass
        proc.popen.wait()
        proc.reaped_by_driver = True
        self._mark("reap", {"generation": self.generation,
                            "rank": proc.rank})

    def wait_generation(self, timeout_s: float = 600.0) -> GenerationResult:
        """Wait for every worker to exit, reaping stragglers.

        Once any worker exits abnormally (protocol code, crash, or
        kill), the rest get ``hang_grace_s`` to run their own liveness
        detection and leave; whoever is still up after that (e.g. a
        SIGSTOPped rank) is reaped by the driver."""
        t0 = time.time()
        abnormal_at: float | None = None
        while True:
            running = [p for p in self.procs if p.popen.poll() is None]
            if not running:
                break
            codes = [p.returncode for p in self.procs
                     if p.popen.poll() is not None]
            if abnormal_at is None and any(c != EXIT_OK for c in codes):
                abnormal_at = time.time()
            now = time.time()
            if now - t0 > timeout_s:
                for p in running:
                    self._reap(p)
                raise TimeoutError(
                    f"generation {self.generation} exceeded {timeout_s:.0f}s "
                    f"({len(running)} workers still up)")
            if abnormal_at is not None and now - abnormal_at > self.hang_grace_s:
                for p in running:
                    log.warning("reaping rank %d (no exit %0.fs after first "
                                "abnormal exit)", p.rank, self.hang_grace_s)
                    self._reap(p)
                break
            time.sleep(0.1)
        result = GenerationResult(
            generation=self.generation,
            world=len(self.procs),
            codes={p.rank: p.returncode for p in self.procs},
            duration_s=time.time() - t0,
            heartbeat_dir=self.heartbeat_dir)
        self._mark("generation_end", {"generation": self.generation,
                                      "codes": dict(result.codes)})
        return result

    # -- the elastic respawn loop ---------------------------------------
    def run_elastic(self, *, max_generations: int = 4,
                    gen_timeout_s: float = 600.0,
                    faults: Mapping[int, Callable] | None = None,
                    on_generation_end: Callable | None = None) -> ElasticReport:
        """Generation loop implementing the respawn protocol.

        ``faults`` maps a generation index to a callable run on a side
        thread after that generation launches (e.g. ``lambda d:
        d.kill_at_step(1, 3)``) — the genuine-fault injection point.
        ``on_generation_end(driver, result)`` runs between generations
        (tests use it to snapshot the checkpoint directory for the
        fault-free reference run).

        All workers exiting :data:`EXIT_OK` completes the run.  Any
        :data:`EXIT_RESHARD` shrinks the next world to the count of
        cooperating survivors (resharders + clean finishers); otherwise
        any :data:`EXIT_RESTART` relaunches the same world.  Any other
        combination (every worker crashed/killed) aborts."""
        world = self.nproc
        generations: list[GenerationResult] = []
        for gen in range(max_generations):
            self.launch_generation(gen, world)
            fault = (faults or {}).get(gen)
            fault_thread = None
            if fault is not None:
                fault_thread = threading.Thread(
                    target=fault, args=(self,), daemon=True,
                    name=f"fault-g{gen}")
                fault_thread.start()
            result = self.wait_generation(gen_timeout_s)
            generations.append(result)
            if fault_thread is not None:
                fault_thread.join(timeout=10)
            if on_generation_end is not None:
                on_generation_end(self, result)
            codes = result.codes.values()
            if all(c == EXIT_OK for c in codes):
                return ElasticReport(completed=True, generations=generations,
                                     timeline=list(self.timeline))
            next_world = next_generation_world(result.codes)
            if next_world is None:
                break
            world = next_world
        return ElasticReport(completed=False, generations=generations,
                             timeline=list(self.timeline))


def next_generation_world(codes: Mapping[int, int]) -> int | None:
    """Respawn decision from one generation's exit codes.

    Pure so the protocol is unit-testable: resharders shrink the world
    to the cooperating-survivor count, restarters keep it, and a
    generation with no protocol exits at all (everyone crashed or was
    killed) returns None — nothing left to respawn around."""
    vals = list(codes.values())
    # Anyone who exited through the protocol (or drained cleanly) is a
    # live process the next generation can be built around — including a
    # restart voter when a peer's stronger reshard diagnosis wins.
    survivors = sum(1 for c in vals
                    if c in (EXIT_OK, EXIT_RESHARD, EXIT_RESTART))
    if any(c == EXIT_RESHARD for c in vals):
        return survivors if survivors > 0 else None
    if any(c == EXIT_RESTART for c in vals):
        return len(vals)
    return None


# -- measured cross-process link model -------------------------------------

def fit_alpha_beta(sizes_bytes: Sequence[float],
                   times_s: Sequence[float]) -> tuple[float, float]:
    """Least-squares alpha-beta fit ``t = alpha + beta * bytes``.

    Returns ``(alpha, beta)`` with both clamped non-negative (timing
    noise on small payloads can drive the unconstrained fit negative)."""
    b = np.asarray(sizes_bytes, np.float64)
    t = np.asarray(times_s, np.float64)
    A = np.stack([np.ones_like(b), b], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, t, rcond=None)
    return float(max(alpha, 0.0)), float(max(beta, 1e-15))


def measured_hardware_model(sizes_bytes, times_s, *,
                            base: HardwareModel = DCN) -> HardwareModel:
    """A :class:`HardwareModel` whose link constants come from measured
    ring times (compute-side constants carry over from ``base`` — a
    link measurement says nothing about the chip)."""
    alpha, beta = fit_alpha_beta(sizes_bytes, times_s)
    return dataclasses.replace(base, ici_bw=1.0 / beta, ici_lat=alpha)


def measure_ring(mesh, axis: str, sizes_bytes: Sequence[int], *,
                 iters: int = 5, warmup: int = 2) -> list[float]:
    """Median all-reduce time over one mesh axis per payload size.

    The payload is sharded over ``axis`` and summed over that dimension
    with a replicated output — XLA lowers this to the axis ring
    all-reduce, crossing the process boundary when ``axis`` spans
    processes.  Returns seconds per call, one per payload size."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental import multihost_utils

    from repro.checkpoint.checkpointer import host_to_device

    k = mesh.shape[axis]
    out: list[float] = []
    for nbytes in sizes_bytes:
        n = max(k, int(nbytes) // 4 // k * k)
        x_np = np.ones((k, n // k), np.float32)
        # collective-free placement: a raw device_put onto a sharding
        # spanning processes runs jax's equal-value broadcast, whose
        # gloo messages can interleave with the barrier below
        x = jax.block_until_ready(
            host_to_device(x_np, NamedSharding(mesh, P(axis, None))))
        f = jax.jit(lambda v: jnp.sum(v, axis=0),
                    out_shardings=NamedSharding(mesh, P(None)))
        for _ in range(warmup):
            f(x).block_until_ready()
        multihost_utils.sync_global_devices(f"ring_{nbytes}")
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            f(x).block_until_ready()
            ts.append(time.perf_counter() - t0)
        out.append(float(np.median(ts)))
    return out
